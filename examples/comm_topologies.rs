//! Communication study across the paper's four edge-network structures
//! (Fig 4), including the discrete-event latency extension.
//!
//! Pure coordination — no model training, runs in milliseconds:
//!
//! ```bash
//! cargo run --release --example comm_topologies
//! ```

use edgeflow::config::Algorithm;
use edgeflow::fl::experiments::fig4;
use edgeflow::runtime::manifest::Manifest;
use edgeflow::util::human_bytes;
use edgeflow::util::table::{Align, Table};

fn main() -> edgeflow::Result<()> {
    edgeflow::util::logging::init(false);
    // Parameter count comes from the real artifact manifest when present;
    // falls back to the paper-scale CNN (~1M params) otherwise.
    let param_count = Manifest::load("artifacts")
        .and_then(|m| m.variant("fashion_mlp").map(|v| v.param_count()))
        .unwrap_or(1_000_000);
    println!(
        "model transfer size: {} ({param_count} f32 parameters)\n",
        human_bytes((param_count * 4) as u64)
    );

    let algs = [
        Algorithm::FedAvg,
        Algorithm::HierFl,
        Algorithm::SeqFl,
        Algorithm::EdgeFlowRand,
        Algorithm::EdgeFlowSeq,
    ];
    let (table, results) = fig4(param_count, 10, 10, 200, &algs, 0, 0)?;
    println!("{}", table.render());

    // Per-participant fairness view (HierFL trains all 100 clients/round).
    let mut t = Table::new(&[
        "Topology",
        "Algorithm",
        "byte-hops/participant",
        "mean latency (s)",
    ])
    .title("Per-participant load + simulated transfer latency")
    .align(0, Align::Left)
    .align(1, Align::Left);
    for r in &results {
        t.row(&[
            r.topology.name().to_string(),
            r.algorithm.name().to_string(),
            format!("{:.3e}", r.byte_hops_per_participant()),
            format!("{:.4}", r.round_latency_s),
        ]);
    }
    println!("{}", t.render());

    // The §V headline: EdgeFLow's savings band vs FedAvg.
    println!("EdgeFLowSeq communication savings vs FedAvg:");
    for r in results
        .iter()
        .filter(|r| r.algorithm == Algorithm::EdgeFlowSeq)
    {
        println!(
            "  {:<18} {:>5.1}%",
            r.topology.name(),
            (1.0 - r.vs_fedavg) * 100.0
        );
    }
    Ok(())
}
