//! Quickstart: train EdgeFLow for a handful of rounds and print the
//! accuracy + communication summary.
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```

use edgeflow::config::{preset, Algorithm};
use edgeflow::fl::runner::Runner;

fn main() -> edgeflow::Result<()> {
    edgeflow::util::logging::init(false);

    // Start from a paper preset and scale it to a ~30 s CPU run.
    let mut cfg = preset("table1_fashion_iid")?;
    cfg.algorithm = Algorithm::EdgeFlowSeq;
    cfg.rounds = 30;
    cfg.eval_every = 5;
    cfg.samples_per_client = 100;
    cfg.test_samples = 400;

    println!("config: {}", cfg.to_json().pretty());
    let mut runner = Runner::new(cfg, "artifacts")?;
    let report = runner.run()?;

    println!("\n=== quickstart result ===");
    println!("algorithm        : {}", report.algorithm);
    println!("rounds           : {}", report.rounds);
    println!("final accuracy   : {:.2}%", report.final_accuracy * 100.0);
    println!("best accuracy    : {:.2}%", report.best_accuracy * 100.0);
    println!("final train loss : {:.4}", report.final_loss);
    println!(
        "communication    : {} byte-hops total",
        report.total_byte_hops
    );
    println!("\naccuracy curve (round, accuracy):");
    for (round, acc) in report.metrics.accuracy_curve() {
        println!("  {round:>4}  {:.2}%", acc * 100.0);
    }
    Ok(())
}
