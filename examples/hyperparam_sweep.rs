//! Fig 3 companion: sweep cluster size N_m and local epochs K under the
//! NIID B distribution, and juxtapose the measured accuracies with the
//! predictions of Theorem 1's bound (Eq. 8).
//!
//! ```bash
//! make artifacts
//! cargo run --release --example hyperparam_sweep
//! ```

use std::sync::Arc;

use edgeflow::fl::experiments::{fig3a, fig3b, SuiteOptions};
use edgeflow::fl::theory::{bound, k_scan, TheoryParams};
use edgeflow::runtime::backend::TrainBackend;
use edgeflow::runtime::executor::Engine;
use edgeflow::util::table::{Align, Table};

fn main() -> edgeflow::Result<()> {
    edgeflow::util::logging::init(false);
    let engine: Arc<dyn TrainBackend> = Arc::new(Engine::load("artifacts")?);
    let opts = SuiteOptions {
        rounds: 40,
        samples_per_client: 100,
        test_samples: 400,
        eval_every: 10,
        seed: 0,
        lr: 1e-3,
        // Sweep points are independent: fan them out across all cores.
        workers: 0,
        ..SuiteOptions::default()
    };

    // ---- Fig 3(a): cluster size ---------------------------------------
    println!("Fig 3(a): EdgeFLowSeq under NIID B, varying N_m\n");
    let nms = [5usize, 10, 20, 50];
    let runs_a = fig3a(&engine, &opts, &nms)?;
    let mut ta = Table::new(&["N_m", "clusters M", "final acc %", "best acc %"])
        .align(0, Align::Right);
    for (n_m, rep) in &runs_a {
        ta.row(&[
            n_m.to_string(),
            (100 / n_m).to_string(),
            format!("{:.2}", rep.final_accuracy * 100.0),
            format!("{:.2}", rep.best_accuracy * 100.0),
        ]);
    }
    println!("{}", ta.render());

    // Theory: the variance term shrinks with N_m.
    println!("Theorem 1 variance term (2/T)·Σ Lησ²/N_m per cluster size:");
    for &n_m in &nms {
        let p = TheoryParams {
            l: 1.0,
            g2: 1.0,
            sigma2: 1.0,
            init_gap: 1.0,
            eta: 0.01,
            k: 5,
            t: opts.rounds,
            lambda2: vec![0.1],
            n_m: vec![n_m],
        };
        println!("  N_m={n_m:<3} variance={:.6}", bound(&p).variance);
    }

    // ---- Fig 3(b): local epochs ---------------------------------------
    println!("\nFig 3(b): EdgeFLowSeq under NIID B, varying K\n");
    let ks = [1usize, 2, 5, 10];
    let runs_b = fig3b(&engine, &opts, &ks)?;
    let mut tb = Table::new(&["K", "final acc %", "best acc %"]).align(0, Align::Right);
    for (k, rep) in &runs_b {
        tb.row(&[
            k.to_string(),
            format!("{:.2}", rep.final_accuracy * 100.0),
            format!("{:.2}", rep.best_accuracy * 100.0),
        ]);
    }
    println!("{}", tb.render());

    // Theory: Eq. 8 is non-monotonic in K.
    let base = TheoryParams {
        l: 1.0,
        g2: 5.0,
        sigma2: 1.0,
        init_gap: 1.0,
        eta: 0.02,
        k: 5,
        t: opts.rounds,
        lambda2: vec![0.1],
        n_m: vec![10],
    };
    println!("Theorem 1 total bound over K (note the interior minimum):");
    for (k, total) in k_scan(&base, 12) {
        println!("  K={k:<3} bound={total:.4}");
    }
    Ok(())
}
