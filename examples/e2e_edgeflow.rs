//! End-to-end driver: the full EdgeFLow system on a real (synthetic)
//! workload, proving all three layers compose.
//!
//! Trains the paper's federation (N = 100 clients, M = 10 edge clusters,
//! K = 5, B = 64, Adam) for a few hundred rounds with EdgeFLowSeq,
//! EdgeFLowRand and FedAvg under the NIID A distribution, logging the loss
//! curve and accuracy every few rounds, then prints the communication
//! comparison.  Results are written to `results/e2e_*.csv` and summarized
//! in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example e2e_edgeflow              # full (~10 min)
//! EDGEFLOW_E2E_FAST=1 cargo run --release --example e2e_edgeflow  # ~1 min
//! ```

use std::sync::Arc;

use edgeflow::config::{Algorithm, DatasetKind, Distribution, ExperimentConfig};
use edgeflow::fl::runner::Runner;
use edgeflow::runtime::executor::Engine;
use edgeflow::util::table::{Align, Table};

fn main() -> edgeflow::Result<()> {
    edgeflow::util::logging::init(false);
    let fast = std::env::var("EDGEFLOW_E2E_FAST").as_deref() == Ok("1");
    let rounds = if fast { 40 } else { 300 };

    std::fs::create_dir_all("results")?;
    let engine = Arc::new(Engine::load("artifacts")?);

    let base = ExperimentConfig {
        name: "e2e".into(),
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::NiidA,
        model: "fashion_mlp".into(),
        clients: 100,
        clusters: 10,
        local_steps: 5,
        batch_size: 64,
        rounds,
        samples_per_client: 120,
        test_samples: 1000,
        eval_every: if fast { 5 } else { 10 },
        lr: 1e-3,
        optimizer: "adam".into(),
        seed: 0,
        ..ExperimentConfig::default()
    };

    let mut summary = Table::new(&[
        "algorithm",
        "final acc %",
        "best acc %",
        "final loss",
        "byte-hops",
        "train s",
    ])
    .title(&format!(
        "e2e: N=100 M=10 K=5 B=64 Adam, NIID A, {rounds} rounds"
    ))
    .align(0, Align::Left);

    for alg in [
        Algorithm::EdgeFlowSeq,
        Algorithm::EdgeFlowRand,
        Algorithm::FedAvg,
    ] {
        let mut cfg = base.clone();
        cfg.algorithm = alg;
        cfg.name = format!("e2e_{}", alg.name());
        println!("=== {} ===", cfg.name);
        let mut runner = Runner::with_engine(engine.clone(), cfg.clone())?;
        let report = runner.run()?;

        // Loss curve to stdout (coarse) + CSV (full).
        println!("loss curve (every ~10% of rounds):");
        let stride = (rounds / 10).max(1);
        for r in report.metrics.rounds.iter().step_by(stride) {
            println!("  round {:>4}  loss {:.4}", r.round, r.train_loss);
        }
        let path = format!("results/{}.csv", cfg.name);
        report.metrics.to_csv().save(&path)?;
        println!("wrote {path}\n");

        let train_s: f64 = report
            .phase_seconds
            .iter()
            .find(|(n, _)| n == "train")
            .map(|(_, s)| *s)
            .unwrap_or(0.0);
        summary.row(&[
            report.algorithm.to_string(),
            format!("{:.2}", report.final_accuracy * 100.0),
            format!("{:.2}", report.best_accuracy * 100.0),
            format!("{:.4}", report.final_loss),
            format!("{:.3e}", report.total_byte_hops as f64),
            format!("{train_s:.1}"),
        ]);
    }

    println!("{}", summary.render());
    println!("(CSV curves in results/; see EXPERIMENTS.md for the recorded run)");
    Ok(())
}
