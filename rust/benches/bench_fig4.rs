//! Bench: regenerate Fig 4 — per-round communication load of FedAvg /
//! HierFL / SeqFL / EdgeFLow{Rand,Seq} across the four edge-network
//! structures, plus the §V "50-80% reduction" headline check and the DES
//! latency extension.
//!
//! `cargo bench --bench bench_fig4` (coordination only — no training).

use edgeflow::config::{Algorithm, TopologyKind};
use edgeflow::fl::experiments::fig4;
use edgeflow::runtime::manifest::Manifest;
use edgeflow::util::timer::Timer;

fn main() {
    edgeflow::util::logging::init(false);
    let fast = std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1");
    let rounds = if fast { 20 } else { 200 };
    // Parameter count from the artifacts when present; paper-scale CNN
    // otherwise (the ratios are parameter-count-invariant).
    let param_count = Manifest::load("artifacts")
        .and_then(|m| m.variant("fashion_mlp").map(|v| v.param_count()))
        .unwrap_or(1_000_000);

    let algs = [
        Algorithm::FedAvg,
        Algorithm::HierFl,
        Algorithm::SeqFl,
        Algorithm::EdgeFlowRand,
        Algorithm::EdgeFlowSeq,
        Algorithm::EdgeFlowHop,
    ];
    let mut timer = Timer::new();
    let workers = edgeflow::bench::env_usize("EDGEFLOW_WORKERS", 1);
    let (table, results) =
        fig4(param_count, 10, 10, rounds, &algs, 0, workers).expect("fig4");
    timer.lap("fig4");
    println!("{}", table.render());

    println!("EdgeFLowSeq savings vs FedAvg (paper §V claims 50-80% on complex structures):");
    for kind in TopologyKind::ALL {
        let r = results
            .iter()
            .find(|r| r.topology == kind && r.algorithm == Algorithm::EdgeFlowSeq)
            .unwrap();
        println!(
            "  {:<18} {:>5.1}% saved   (mean transfer latency {:.4}s)",
            kind.name(),
            (1.0 - r.vs_fedavg) * 100.0,
            r.round_latency_s
        );
    }
    println!(
        "\npaper shape: savings grow with structural depth — depth_linear > \
         hybrid > breadth_parallel > simple."
    );
    println!(
        "\nbench fig4/total                      wall={:.2}s ({} algs x 4 topologies x {rounds} rounds)",
        timer.get("fig4").as_secs_f64(),
        algs.len()
    );
}
