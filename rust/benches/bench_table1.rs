//! Bench: regenerate Table I (accuracy of FedAvg / EdgeFLowRand /
//! EdgeFLowSeq across dataset x distribution cells).
//!
//! `cargo bench --bench bench_table1` — full grid (~minutes on one core).
//! Env knobs: `EDGEFLOW_BENCH_FAST=1` for the 2-cell smoke grid,
//! `EDGEFLOW_T1_ROUNDS` to override the per-cell round count.

use std::sync::Arc;

use edgeflow::fl::experiments::{table1, SuiteOptions};
use edgeflow::runtime::backend::TrainBackend;
use edgeflow::runtime::executor::Engine;
use edgeflow::util::timer::Timer;

fn main() {
    edgeflow::util::logging::init(false);
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table1: run `make artifacts` first — skipping");
        return;
    }
    let fast = std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1");
    // Default 30 rounds/cell keeps the 15-cell grid ~10 min on one core;
    // raise EDGEFLOW_T1_ROUNDS toward paper scale when you have the time.
    let rounds =
        edgeflow::bench::env_usize("EDGEFLOW_T1_ROUNDS", if fast { 10 } else { 30 });

    let engine: Arc<dyn TrainBackend> =
        Arc::new(Engine::load("artifacts").expect("engine"));
    let workers = edgeflow::bench::env_usize("EDGEFLOW_WORKERS", 1);
    let opts = SuiteOptions {
        rounds,
        samples_per_client: 120,
        test_samples: 500,
        eval_every: rounds / 4,
        seed: 0,
        lr: 1e-3,
        workers,
        ..SuiteOptions::default()
    };
    let mut timer = Timer::new();
    let (table, cells) = table1(&engine, &opts, fast).expect("table1");
    timer.lap("table1");

    println!("{}", table.render());
    println!("paper reference (real datasets, full training budget):");
    println!("  FedAvg       Fashion 90.60/86.89  CIFAR 88.66/77.04/71.04");
    println!("  EdgeFLowRand Fashion 90.13/87.97  CIFAR 89.16/80.26/73.14");
    println!("  EdgeFLowSeq  Fashion 90.53/87.50  CIFAR 88.99/81.58/73.36");
    println!(
        "\nshape check: under NIID the EdgeFLow variants should lead FedAvg; \
         under IID the three should be close."
    );

    // Communication side-by-side for the same runs.
    println!("\nper-cell communication (byte-hops over {rounds} rounds):");
    for c in &cells {
        println!(
            "  {:<14} {:<8} {:<14} {:>14}",
            c.dataset.name(),
            c.distribution.name(),
            c.algorithm.name(),
            c.byte_hops
        );
    }
    println!(
        "\nbench table1/total                    wall={:.1}s cells={}",
        timer.get("table1").as_secs_f64(),
        cells.len()
    );
}
