//! Bench: regenerate Fig 3 — EdgeFLow accuracy curves under NIID B for
//! (a) cluster sizes N_m in {5, 10, 20, 50} and (b) local epochs
//! K in {1, 2, 5, 10}, with smoothed series like the paper's plots.
//!
//! `cargo bench --bench bench_fig3`; `EDGEFLOW_BENCH_FAST=1` shrinks the
//! grids; `EDGEFLOW_F3_ROUNDS` overrides the round count.

use std::sync::Arc;

use edgeflow::fl::experiments::{fig3a, fig3b, SuiteOptions};
use edgeflow::metrics::smooth;
use edgeflow::runtime::backend::TrainBackend;
use edgeflow::runtime::executor::Engine;
use edgeflow::util::timer::Timer;

fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (lo, hi) = values.iter().fold((f64::MAX, f64::MIN), |(l, h), &v| {
        (l.min(v), h.max(v))
    });
    values
        .iter()
        .map(|&v| {
            let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
            BARS[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn main() {
    edgeflow::util::logging::init(false);
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_fig3: run `make artifacts` first — skipping");
        return;
    }
    let fast = std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1");
    // Default 24 rounds: the CIFAR MLP runs ~300 ms/local-update on this
    // one-core testbed and Fig 3 sweeps up to N_m=50 updates per round;
    // raise EDGEFLOW_F3_ROUNDS for paper-scale curves.
    let rounds =
        edgeflow::bench::env_usize("EDGEFLOW_F3_ROUNDS", if fast { 12 } else { 24 });
    let engine: Arc<dyn TrainBackend> =
        Arc::new(Engine::load("artifacts").expect("engine"));
    let workers = edgeflow::bench::env_usize("EDGEFLOW_WORKERS", 1);
    let opts = SuiteOptions {
        rounds,
        samples_per_client: 120,
        test_samples: 400,
        eval_every: (rounds / 12).max(1),
        seed: 0,
        lr: 1e-3,
        workers,
        ..SuiteOptions::default()
    };
    let mut timer = Timer::new();

    let nms: &[usize] = if fast { &[10, 50] } else { &[5, 10, 20] };
    println!("Fig 3(a): accuracy vs rounds, cluster size sweep (NIID B)");
    for (n_m, rep) in fig3a(&engine, &opts, nms).expect("fig3a") {
        let curve: Vec<f64> = rep
            .metrics
            .accuracy_curve()
            .iter()
            .map(|&(_, a)| a)
            .collect();
        let sm = smooth(&curve, 3);
        println!(
            "  N_m={n_m:<3} final={:>6.2}%  {}",
            rep.final_accuracy * 100.0,
            sparkline(&sm)
        );
    }
    timer.lap("fig3a");
    println!(
        "  paper shape: larger N_m converges faster AND higher (Thm 1's \
         variance term shrinks with N_m)\n"
    );

    let ks: &[usize] = if fast { &[1, 5] } else { &[1, 2, 5, 10] };
    println!("Fig 3(b): accuracy vs rounds, local-epoch sweep (NIID B)");
    for (k, rep) in fig3b(&engine, &opts, ks).expect("fig3b") {
        let curve: Vec<f64> = rep
            .metrics
            .accuracy_curve()
            .iter()
            .map(|&(_, a)| a)
            .collect();
        let sm = smooth(&curve, 3);
        println!(
            "  K={k:<3}   final={:>6.2}%  {}",
            rep.final_accuracy * 100.0,
            sparkline(&sm)
        );
    }
    timer.lap("fig3b");
    println!(
        "  paper shape: K improvements are non-proportional (K sits in both \
         numerator and denominator of Eq. 8)"
    );
    println!(
        "\nbench fig3/total                      a={:.1}s b={:.1}s",
        timer.get("fig3a").as_secs_f64(),
        timer.get("fig3b").as_secs_f64()
    );
}
