//! Micro-benchmarks for the coordinator hot paths (EXPERIMENTS.md §Perf):
//! aggregation bandwidth, PJRT literal round-trips, local_update / eval
//! execution latency, batch gathering, partition construction, routing,
//! and the DES event loop.
//!
//! `cargo bench --bench bench_micro`; `EDGEFLOW_BENCH_FAST=1` for smoke.

use std::sync::Arc;

use edgeflow::bench::{black_box, Bencher};
use edgeflow::config::{DatasetKind, Distribution, TopologyKind};
use edgeflow::data::loader::ClientLoader;
use edgeflow::data::partition::build_federation;
use edgeflow::fl::aggregate::{mean_into, weighted_mean_into};
use edgeflow::netsim::NetSim;
use edgeflow::rng::Rng;
use edgeflow::runtime::executor::Engine;
use edgeflow::topology::builder::{build, TopologyParams};
use edgeflow::topology::route::RouteTable;

fn bench_aggregation(b: &Bencher) {
    // The Eq. 3 hot path: average N_m states of P f32s.
    for (n_m, p) in [(10usize, 109_386usize), (10, 1_000_000), (50, 109_386)] {
        let mut rng = Rng::new(1);
        let sources: Vec<Vec<f32>> = (0..n_m)
            .map(|_| (0..p).map(|_| rng.f32()).collect())
            .collect();
        let refs: Vec<&[f32]> = sources.iter().map(|v| v.as_slice()).collect();
        let mut dst = vec![0f32; p];
        let m = b.bench(&format!("aggregate/mean {n_m}x{p}"), || {
            mean_into(black_box(&mut dst), black_box(&refs));
        });
        let bytes = (n_m + 1) * p * 4;
        println!(
            "    -> {:.2} GB/s effective",
            bytes as f64 / m.mean_s / 1e9
        );
        let w: Vec<f64> = (0..n_m).map(|i| 1.0 + i as f64).collect();
        b.bench(&format!("aggregate/weighted {n_m}x{p}"), || {
            weighted_mean_into(black_box(&mut dst), black_box(&refs), black_box(&w));
        });
    }
}

fn bench_partition(b: &Bencher) {
    b.bench("partition/niid_a 100x120", || {
        let fed = build_federation(
            DatasetKind::SynthFashion,
            &Distribution::NiidA,
            100,
            10,
            120,
            100,
            7,
        )
        .unwrap();
        black_box(fed.clients.len());
    });
}

fn bench_loader(b: &Bencher) {
    let fed = build_federation(
        DatasetKind::SynthFashion,
        &Distribution::NiidA,
        100,
        10,
        120,
        100,
        7,
    )
    .unwrap();
    let loader = ClientLoader::new(3, 64);
    b.bench("loader/gather K=5 B=64 28x28", || {
        let batch = loader.local_batches(&fed.train, &fed.clients[17], 4, 5);
        black_box(batch.y.len());
    });
}

fn bench_routing(b: &Bencher) {
    let topo = build(&TopologyParams::new(TopologyKind::Hybrid, 10, 10)).unwrap();
    let rt = RouteTable::hops(&topo);
    let clients = topo.clients();
    let cloud = topo.cloud().unwrap();
    let mut i = 0;
    b.bench("route/dijkstra client->cloud (121 nodes)", || {
        let c = clients[i % clients.len()];
        i += 1;
        black_box(rt.path(c, cloud).unwrap().len());
    });
}

fn bench_netsim(b: &Bencher) {
    let topo = build(&TopologyParams::new(TopologyKind::Hybrid, 10, 10)).unwrap();
    let rt = RouteTable::latency(&topo);
    let clients = topo.clients();
    b.bench("netsim/1000 transfers hybrid", || {
        let mut sim = NetSim::new(&topo);
        let mut rng = Rng::new(11);
        for i in 0..1000 {
            let a = clients[rng.below(clients.len())];
            let bnode = clients[rng.below(clients.len())];
            sim.submit(&rt, a, bnode, 437_544, i as f64 * 1e-4).unwrap();
        }
        black_box(sim.run().len());
    });
}

fn bench_runtime(b: &Bencher) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("  (skipping runtime benches: run `make artifacts`)");
        return;
    }
    let engine = Arc::new(Engine::load("artifacts").expect("engine"));
    let fed = build_federation(
        DatasetKind::SynthFashion,
        &Distribution::Iid,
        10,
        2,
        120,
        200,
        3,
    )
    .unwrap();
    let loader = ClientLoader::new(3, 64);

    for (opt, k) in [("sgd", 1usize), ("adam", 5)] {
        let lu = engine.local_update("fashion_mlp", opt, k).unwrap();
        let state = engine.init_state("fashion_mlp", opt).unwrap();
        let batch = loader.local_batches(&fed.train, &fed.clients[0], 0, k);
        b.bench(&format!("runtime/local_update mlp {opt} K={k}"), || {
            let (s, l) = lu.run(black_box(&state), black_box(&batch), 1e-3).unwrap();
            black_box((s.data[0], l));
        });
    }

    let ev = engine.eval("fashion_mlp", "sgd").unwrap();
    let state = engine.init_state("fashion_mlp", "sgd").unwrap();
    b.bench("runtime/eval 200 samples mlp", || {
        let (l, a) = ev.run_dataset(black_box(&state), &fed.test).unwrap();
        black_box((l, a));
    });

    // CNN backend ablation: lax.conv lowering vs im2col+matmul lowering
    // (identical parameter layouts; see EXPERIMENTS.md §Perf — 6.3x vs lax, 92x vs pallas-interpret).
    let slow = Bencher {
        min_iters: 2,
        max_iters: 10,
        budget: std::time::Duration::from_secs(8),
        warmup: 1,
    };
    for variant in ["fashion_cnn_slim_fast", "fashion_cnn_slim_jnp"] {
        if std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1")
            && variant.ends_with("_jnp")
        {
            continue; // the lax.conv path alone takes ~30 s/iter
        }
        if !engine.manifest.variants.contains_key(variant) {
            continue;
        }
        let lu = engine.local_update(variant, "adam", 5).unwrap();
        let state = engine.init_state(variant, "adam").unwrap();
        let batch = loader.local_batches(&fed.train, &fed.clients[1], 0, 5);
        slow.bench(&format!("runtime/local_update {variant} adam K=5"), || {
            let (s, l) = lu.run(black_box(&state), black_box(&batch), 1e-3).unwrap();
            black_box((s.data[0], l));
        });
    }
}

fn main() {
    edgeflow::util::logging::init(false);
    let b = Bencher::from_env();
    println!("== aggregation (Eq. 3 hot path) ==");
    bench_aggregation(&b);
    println!("== data layer ==");
    bench_partition(&b);
    bench_loader(&b);
    println!("== topology / netsim ==");
    bench_routing(&b);
    bench_netsim(&b);
    println!("== PJRT runtime ==");
    bench_runtime(&b);
}
