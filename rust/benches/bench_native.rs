//! Bench: native-engine batched kernels — the blocked-GEMM batch path
//! vs the pre-kernel per-sample scalar baseline on `fashion_mlp`.
//!
//! `cargo bench --bench bench_native`.  Asserts the batched path is
//! strictly faster than the per-sample baseline (the whole point of
//! promoting `runtime::native` to a performance engine) and records the
//! speedup in the output; the CNN section reports the im2col conv
//! throughput for inspection.  Env knobs: `EDGEFLOW_BENCH_FAST=1`
//! (smoke).

use edgeflow::bench::{black_box, Bencher};
use edgeflow::rng::Rng;
use edgeflow::runtime::native::models::{
    loss_and_grads, loss_and_grads_per_sample, Arch, Model, Workspace,
};

fn randvec(n: usize, seed: u64, lo: f64, hi: f64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.range(lo, hi) as f32).collect()
}

fn labels(n: usize, classes: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(classes) as i32).collect()
}

/// One batch of dense random pixels — no zeros, so the per-sample
/// baseline's zero-skip never fires and the comparison is fair.
fn batch_for(model: &Model, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    (
        randvec(b * model.input(), seed, 0.05, 1.0),
        labels(b, model.classes, seed ^ 0xB00),
    )
}

fn bench_mlp_vs_per_sample(bencher: &Bencher, batch: usize) -> f64 {
    // The production native MLP: 784 -> 64 -> 10.
    let model =
        Model { arch: Arch::Mlp { hidden: 64 }, image: (28, 28, 1), classes: 10 };
    let n = model.param_elems();
    let params = randvec(n, 7, -0.1, 0.1);
    let (x, y) = batch_for(&model, batch, 11);

    // Both paths must agree before either is worth timing.
    let mut ws = Workspace::new(&model, batch);
    let mut g_batch = vec![0f32; n];
    let lb = loss_and_grads(&model, &params, &x, &y, Some(&mut g_batch), &mut ws);
    let mut g_ref = vec![0f32; n];
    let lr = loss_and_grads_per_sample(&model, &params, &x, &y, Some(&mut g_ref));
    assert!((lb - lr).abs() <= 1e-5 + 1e-5 * lr.abs(), "loss {lb} vs {lr}");
    for (i, (&a, &b)) in g_batch.iter().zip(&g_ref).enumerate() {
        assert!((a - b).abs() <= 1e-5 + 1e-3 * b.abs(), "grad {i}: {a} vs {b}");
    }

    let mut grads = vec![0f32; n];
    let base = bencher.bench(&format!("native/mlp_per_sample b={batch}"), || {
        grads.fill(0.0);
        let l =
            loss_and_grads_per_sample(&model, &params, &x, &y, Some(&mut grads));
        black_box(l);
    });
    let batched = bencher.bench(&format!("native/mlp_batched    b={batch}"), || {
        grads.fill(0.0);
        let l = loss_and_grads(&model, &params, &x, &y, Some(&mut grads), &mut ws);
        black_box(l);
    });
    base.p50_s / batched.p50_s
}

fn bench_cnn_throughput(bencher: &Bencher, batch: usize) {
    // The native CNN (im2col conv -> pool -> dense): no per-sample
    // baseline ever existed for it, so this is a throughput report.
    let model = Model {
        arch: Arch::Cnn { channels: 8, hidden: 64 },
        image: (28, 28, 1),
        classes: 10,
    };
    let params = randvec(model.param_elems(), 17, -0.1, 0.1);
    let (x, y) = batch_for(&model, batch, 19);
    let mut ws = Workspace::new(&model, batch);
    let mut grads = vec![0f32; model.param_elems()];
    let m = bencher.bench(&format!("native/cnn_batched    b={batch}"), || {
        grads.fill(0.0);
        let l = loss_and_grads(&model, &params, &x, &y, Some(&mut grads), &mut ws);
        black_box(l);
    });
    println!(
        "native cnn fwd+bwd throughput: {:.0} samples/s (batch {batch})",
        m.per_second(batch)
    );
}

fn main() {
    let bencher = Bencher::from_env();

    println!("native engine: blocked-GEMM batch path vs per-sample baseline\n");
    let mut speedup_64 = 0.0;
    for batch in [16usize, 64] {
        let speedup = bench_mlp_vs_per_sample(&bencher, batch);
        println!("native mlp batched-vs-per-sample speedup: {speedup:.2}x (batch {batch})\n");
        if batch == 64 {
            speedup_64 = speedup;
        }
    }
    // The acceptance gate: at the production batch size the blocked-GEMM
    // path must beat the per-sample scalar baseline.
    assert!(
        speedup_64 > 1.0,
        "batched path must be faster than the per-sample baseline at b=64, \
         got {speedup_64:.2}x"
    );

    bench_cnn_throughput(&bencher, 32);
}
