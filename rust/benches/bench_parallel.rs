//! Bench: parallel round execution — `runtime::pool` fan-out speedup and
//! worker-count invariance.
//!
//! `cargo bench --bench bench_parallel`.  The synthetic section always
//! runs; the round-loop section needs `make artifacts`.  Env knobs:
//! `EDGEFLOW_BENCH_FAST=1` (smoke), `EDGEFLOW_BP_ROUNDS` (round count of
//! the artifact section).

use std::sync::Arc;
use std::time::Instant;

use edgeflow::bench::black_box;
use edgeflow::config::{Algorithm, DatasetKind, Distribution, ExperimentConfig};
use edgeflow::fl::aggregate::{par_reduce_states_weighted, reduce_states_weighted};
use edgeflow::fl::runner::Runner;
use edgeflow::rng::Rng;
use edgeflow::runtime::executor::Engine;
use edgeflow::runtime::manifest::{TensorSpec, VariantSpec};
use edgeflow::runtime::params::{ModelState, StateLayout};
use edgeflow::runtime::pool::WorkerPool;

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];

/// A synthetic layout with one big parameter tensor, so the reduction
/// benches run without artifacts.
fn synth_layout(elems: usize) -> std::sync::Arc<StateLayout> {
    let v = VariantSpec {
        name: "synth".into(),
        arch: "mlp".into(),
        image: (1, 1, 1),
        classes: 2,
        train_batch: 1,
        eval_batch: 1,
        k_values: vec![1],
        optimizers: vec!["sgd".into()],
        params: vec![TensorSpec { name: "w".into(), shape: vec![elems] }],
        bn_state: vec![],
        opt_state: std::collections::BTreeMap::from([("sgd".to_string(), vec![])]),
        init_blob: std::collections::BTreeMap::new(),
        eval_exe: "e".into(),
        local_update: std::collections::BTreeMap::new(),
    };
    StateLayout::new(&v, "sgd").unwrap()
}

fn synth_states(n: usize, elems: usize) -> Vec<(f64, ModelState)> {
    let l = synth_layout(elems);
    let mut rng = Rng::new(42);
    (0..n)
        .map(|_| {
            let mut s = ModelState::zeros(l.clone());
            for v in &mut s.data {
                *v = rng.f32();
            }
            (rng.f64() * 100.0 + 1.0, s)
        })
        .collect()
}

/// A CPU-bound stand-in for one client's local update (~a few ms).
fn synth_local_update(seed: u64, work: usize) -> f32 {
    let mut rng = Rng::new(seed);
    let mut acc = 0f32;
    for _ in 0..work {
        acc = acc.mul_add(0.999_9, rng.f32());
    }
    acc
}

fn bench_pool_fanout(fast: bool) {
    let jobs = 32usize;
    let work = if fast { 200_000 } else { 2_000_000 };
    let mut base_s = 0.0;
    println!("pool fan-out: {jobs} synthetic local updates");
    for workers in WORKER_COUNTS {
        let pool = WorkerPool::new(workers);
        let t = Instant::now();
        let out = pool.run(jobs, |i, _w| synth_local_update(i as u64, work));
        let dt = t.elapsed().as_secs_f64();
        black_box(out);
        if workers == 1 {
            base_s = dt;
        }
        println!(
            "bench pool/fanout workers={workers:<2}            wall={:.3}s speedup={:.2}x",
            dt,
            base_s / dt
        );
    }
}

fn bench_tree_reduction(fast: bool) {
    let (n, elems) = if fast { (10, 100_000) } else { (20, 1_000_000) };
    println!("\ntree reduction: {n} states x {elems} f32");
    let reference = reduce_states_weighted(synth_states(n, elems)).unwrap();
    let mut base_s = 0.0;
    for workers in WORKER_COUNTS {
        let pool = WorkerPool::new(workers);
        let states = synth_states(n, elems);
        let t = Instant::now();
        let (w, s) = par_reduce_states_weighted(states, &pool).unwrap();
        let dt = t.elapsed().as_secs_f64();
        assert_eq!(w.to_bits(), reference.0.to_bits());
        assert_eq!(s.data, reference.1.data, "tree must be worker-invariant");
        if workers == 1 {
            base_s = dt;
        }
        println!(
            "bench reduce/tree workers={workers:<2}            wall={:.3}s speedup={:.2}x (bit-identical)",
            dt,
            base_s / dt
        );
    }
}

fn bench_round_loop(fast: bool) {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("\nbench_parallel round loop: run `make artifacts` first — skipping");
        return;
    }
    let rounds =
        edgeflow::bench::env_usize("EDGEFLOW_BP_ROUNDS", if fast { 4 } else { 12 });
    let engine = Arc::new(Engine::load("artifacts").expect("engine"));
    let mk = |workers: usize| ExperimentConfig {
        name: format!("bp_w{workers}"),
        algorithm: Algorithm::EdgeFlowSeq,
        dataset: DatasetKind::SynthFashion,
        distribution: Distribution::NiidA,
        model: "fashion_mlp".into(),
        clients: 20,
        clusters: 2, // N_m = 10 concurrent local updates per round
        rounds,
        samples_per_client: 80,
        test_samples: 200,
        eval_every: rounds,
        seed: 7,
        workers,
        ..ExperimentConfig::default()
    };
    println!("\nround loop: edgeflow_seq, 10 clients/round, {rounds} rounds");
    let mut base_s = 0.0;
    let mut reference: Option<(Vec<u64>, Vec<f32>)> = None;
    for workers in WORKER_COUNTS {
        let mut runner =
            Runner::with_engine(engine.clone(), mk(workers)).expect("runner");
        let t = Instant::now();
        let report = runner.run().expect("run");
        let dt = t.elapsed().as_secs_f64();
        // Loss bit patterns + final state bytes: the determinism contract.
        let losses: Vec<u64> = report
            .metrics
            .rounds
            .iter()
            .map(|r| r.train_loss.to_bits())
            .collect();
        let state = runner.state().data.clone();
        match &reference {
            None => reference = Some((losses, state)),
            Some((l0, s0)) => {
                assert_eq!(&losses, l0, "losses diverged at workers={workers}");
                assert_eq!(&state, s0, "state diverged at workers={workers}");
            }
        }
        if workers == 1 {
            base_s = dt;
        }
        println!(
            "bench round_loop workers={workers:<2}             wall={:.3}s speedup={:.2}x (byte-identical report)",
            dt,
            base_s / dt
        );
    }
}

fn main() {
    edgeflow::util::logging::init(false);
    let fast = std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1");
    bench_pool_fanout(fast);
    bench_tree_reduction(fast);
    bench_round_loop(fast);
}
