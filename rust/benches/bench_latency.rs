//! Bench: latency-aware round scheduling — per-round network makespan of
//! the EdgeFLow migration schedules (Sequential vs HopAware vs
//! LatencyAware) on the Hybrid topology, driven through the persistent
//! DES exactly like the runner drives it.
//!
//! `cargo bench --bench bench_latency`.  Env knobs:
//! `EDGEFLOW_BENCH_FAST=1` (smoke), `EDGEFLOW_BL_ROUNDS` (round count).
//!
//! No artifacts needed: this is pure coordination (plans + transfers).

use edgeflow::config::{
    Algorithm, DatasetKind, Distribution, ExperimentConfig, TopologyKind,
};
use edgeflow::data::partition::build_federation;
use edgeflow::fl::comm::{record_round, CommOptions};
use edgeflow::fl::strategy::Strategy;
use edgeflow::netsim::NetSim;
use edgeflow::topology::accounting::CommAccountant;
use edgeflow::topology::builder::{build, TopologyParams};
use edgeflow::topology::route::RouteTable;

const CLUSTERS: usize = 12;
const CLIENTS_PER_CLUSTER: usize = 4;
const MODEL_BYTES: u64 = 1_600_000; // ~400k f32 parameters

struct ScheduleStats {
    mean_makespan_s: f64,
    worst_makespan_s: f64,
    clock_s: f64,
}

/// Drive `alg` for `rounds` rounds through a persistent sim on `params`'
/// topology, mirroring the runner: each round submits at the carried
/// clock, drains, and records its makespan.
fn run_schedule(
    alg: Algorithm,
    rounds: usize,
    params: &TopologyParams,
) -> ScheduleStats {
    let clients = CLUSTERS * CLIENTS_PER_CLUSTER;
    let fed = build_federation(
        DatasetKind::SynthFashion,
        &Distribution::Iid,
        clients,
        CLUSTERS,
        10,
        10,
        0,
    )
    .expect("federation");
    let topo = build(params).expect("topology");
    let routes = RouteTable::hops(&topo);
    // Like the runner: the DES rides bandwidth-aware transfer-time
    // routes sized to the migrating model, so the latency-aware
    // schedule's probes predict exactly what its migrations pay.
    let sim_routes = RouteTable::transfer_time(&topo, MODEL_BYTES);
    let cfg = ExperimentConfig {
        algorithm: alg,
        clients,
        clusters: CLUSTERS,
        samples_per_client: 64,
        ..ExperimentConfig::default()
    };
    let mut strat = Strategy::for_config(&cfg, &fed, &topo, MODEL_BYTES);
    let mut acc = CommAccountant::new();
    let mut sim = NetSim::new(&topo);
    let mut total = 0.0f64;
    let mut worst = 0.0f64;
    for t in 0..rounds {
        let plan = strat.plan_round(t, &fed, Some(&sim));
        let start = sim.now_s();
        record_round(
            &plan,
            &topo,
            &routes,
            &mut acc,
            MODEL_BYTES,
            t,
            CommOptions::default(),
            Some((&mut sim, &sim_routes, start)),
        )
        .expect("record_round");
        let makespan = sim
            .run()
            .iter()
            .map(|o| o.delivered_s)
            .fold(start, f64::max)
            - start;
        total += makespan;
        worst = worst.max(makespan);
    }
    ScheduleStats {
        mean_makespan_s: total / rounds as f64,
        worst_makespan_s: worst,
        clock_s: sim.now_s(),
    }
}

const SCHEDULES: [(Algorithm, &str); 3] = [
    (Algorithm::EdgeFlowSeq, "sequential"),
    (Algorithm::EdgeFlowHop, "hop_aware"),
    (Algorithm::EdgeFlowLatency, "latency_aware"),
];

fn bench_section(
    title: &str,
    rounds: usize,
    params: &TopologyParams,
) -> Vec<(Algorithm, ScheduleStats)> {
    println!(
        "{title}: {CLUSTERS} clusters x {CLIENTS_PER_CLUSTER} clients, \
         {rounds} rounds, {MODEL_BYTES} B model"
    );
    let mut out = Vec::new();
    for (alg, label) in SCHEDULES {
        let s = run_schedule(alg, rounds, params);
        println!(
            "bench latency/{label:<14} mean_makespan={:.4}s worst={:.4}s \
             sim_clock={:.2}s",
            s.mean_makespan_s, s.worst_makespan_s, s.clock_s
        );
        out.push((alg, s));
    }
    println!();
    out
}

fn mean_of(stats: &[(Algorithm, ScheduleStats)], alg: Algorithm) -> f64 {
    stats
        .iter()
        .find(|(a, _)| *a == alg)
        .map(|(_, s)| s.mean_makespan_s)
        .unwrap()
}

fn main() {
    edgeflow::util::logging::init(false);
    let fast = std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1");
    let rounds =
        edgeflow::bench::env_usize("EDGEFLOW_BL_ROUNDS", if fast { 24 } else { 96 });

    // Paper defaults: radio uploads dominate the round, so all three
    // tours share the upload-bound makespan — the latency-aware schedule
    // must never do worse than the fixed cycle.
    let default_params =
        TopologyParams::new(TopologyKind::Hybrid, CLUSTERS, CLIENTS_PER_CLUSTER);
    let stats = bench_section("hybrid / default links", rounds, &default_params);
    let seq = mean_of(&stats, Algorithm::EdgeFlowSeq);
    let lat = mean_of(&stats, Algorithm::EdgeFlowLatency);
    assert!(
        lat <= seq + 1e-9,
        "latency-aware mean makespan {lat} must be <= sequential {seq}"
    );
    println!(
        "latency_aware/sequential mean makespan ratio: {:.4} (<= 1 required)\n",
        lat / seq
    );

    // Stress: slow inter-BS channels and fast radio, so the *migration*
    // dominates the round and the choice of tour actually moves the
    // clock.  Reported for inspection (greedy tours are not provably
    // optimal, so no hard gate here).
    let mut stressed =
        TopologyParams::new(TopologyKind::Hybrid, CLUSTERS, CLIENTS_PER_CLUSTER);
    stressed.radio_mbps = 10_000.0;
    stressed.edge_mbps = 50.0;
    bench_section("hybrid / migration-bound links", rounds, &stressed);
}
