//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and executes
//! them from the coordinator hot path.  Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, orders,
//!   executable table) written by `python/compile/aot.py`.
//! * [`params`] — flat f32 model state (params ++ BN stats ++ optimizer
//!   state) with blob I/O matching the manifest layout.
//! * [`executor`] — the `xla` crate wrapper: HLO text ->
//!   `HloModuleProto::from_text_file` -> `PjRtClient::compile` ->
//!   `execute`, with compiled-executable caching.

pub mod executor;
pub mod manifest;
pub mod params;
pub mod pool;

pub use executor::{Engine, EvalExe, LocalUpdateExe};
pub use manifest::{Manifest, TensorSpec, VariantSpec};
pub use params::ModelState;
pub use pool::WorkerPool;
