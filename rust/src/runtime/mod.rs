//! Execution engines behind the pluggable [`backend`] trait layer.
//!
//! * [`backend`] — [`backend::TrainBackend`] / [`backend::LocalUpdateHandle`] /
//!   [`backend::EvalHandle`]: everything the round loop needs from an
//!   engine, object-safe and `Send + Sync`.  `backend::backend_for`
//!   builds the engine a config selects (`engine: xla|native`).
//! * [`executor`] — the XLA/PJRT engine: loads the AOT artifacts
//!   (`make artifacts`), HLO text -> `HloModuleProto::from_text_file` ->
//!   `PjRtClient::compile` -> `execute`, with compiled-executable
//!   caching.  Python never runs here.
//! * [`native`] — the pure-Rust in-process engine: batched
//!   forward/backward on blocked-GEMM kernels (`native::kernels`) for
//!   multinomial logistic regression, a one-hidden-layer MLP, and an
//!   im2col conv/pool CNN (`native::models`), with SGD, heavy-ball
//!   momentum, and Adam (`native::optim`).  No artifacts, no Python —
//!   the engine CI's end-to-end jobs train with.
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, orders,
//!   executable table) written by `python/compile/aot.py`.
//! * [`params`] — flat f32 model state (params ++ BN stats ++ optimizer
//!   state) with blob I/O; shared by both engines, so aggregation,
//!   migration and checkpointing stay engine-agnostic.
//! * [`pool`] — the scoped worker pool the round loop fans out over.

pub mod backend;
pub mod executor;
pub mod manifest;
pub mod native;
pub mod params;
pub mod pool;

pub use backend::{
    backend_for, backend_for_kind, EvalHandle, LocalUpdateHandle, TrainBackend,
};
pub use executor::{Engine, EvalExe, LocalUpdateExe};
pub use manifest::{Manifest, TensorSpec, VariantSpec};
pub use native::NativeBackend;
pub use params::ModelState;
pub use pool::WorkerPool;
