//! The pluggable training-engine abstraction.
//!
//! Everything the round loop needs from an execution engine fits three
//! object-safe traits:
//!
//! * [`TrainBackend`] — engine construction surface: validate a config
//!   against the engine's model contract, produce the initial
//!   [`ModelState`], and hand out per-worker local-update / eval handles.
//! * [`LocalUpdateHandle`] — run K local SGD steps for one client
//!   (`state + [K, B, ...] batches + lr -> (new state, mean loss)`).
//!   [`crate::runtime::pool::WorkerPool`] gives every worker its own
//!   handle, so implementations must be internally synchronized
//!   (`Send + Sync`), never mutated through `&self`.
//! * [`EvalHandle`] — evaluate a model over a dataset
//!   (`-> (mean loss, accuracy)`).
//!
//! Two engines implement the contract:
//!
//! * `engine: xla` — [`crate::runtime::executor::Engine`], the AOT
//!   XLA/PJRT path (requires `make artifacts`).
//! * `engine: native` — [`crate::runtime::native::NativeBackend`], the
//!   pure-Rust in-process trainer (no artifacts, runs anywhere):
//!   batched forward/backward on blocked-GEMM kernels for the
//!   linear/MLP/CNN variants with sgd, momentum, and adam.
//!
//! Both are deterministic in `(seed, client, round)` and bit-identical
//! at any worker count: a handle's `run` is a pure function of its
//! inputs, and the fixed-order reduction in [`crate::fl::aggregate`]
//! does the rest.

use std::sync::Arc;

use crate::config::{EngineKind, ExperimentConfig};
use crate::data::dataset::{Batch, Dataset};
use crate::runtime::params::ModelState;
use crate::util::error::Result;

/// A training engine: validates configs, initializes model state, and
/// hands out execution handles.  Object-safe; shared across the round
/// loop's worker threads behind an `Arc`.
pub trait TrainBackend: Send + Sync {
    /// Engine label for logs and error messages ("xla" | "native").
    fn name(&self) -> &'static str;

    /// Validate a config against this engine's model/optimizer contract
    /// (the XLA engine cross-checks the artifact manifest; the native
    /// engine checks its built-in variant table).
    fn validate(&self, cfg: &ExperimentConfig) -> Result<()>;

    /// Initial model state for (variant, optimizer).  Deterministic:
    /// every call returns bit-identical state.
    fn init_state(&self, variant: &str, opt: &str) -> Result<ModelState>;

    /// A local-update handle for K steps of batch size `b` (one per pool
    /// worker; implementations may share compiled executables behind the
    /// handle).
    fn local_update(
        &self,
        variant: &str,
        opt: &str,
        k: usize,
        b: usize,
    ) -> Result<Box<dyn LocalUpdateHandle>>;

    /// An evaluation handle for the variant.
    fn eval(&self, variant: &str, opt: &str) -> Result<Box<dyn EvalHandle>>;
}

/// Executes one client's local update: K steps over a gathered
/// `[K, B, ...]` super-batch.  Must be a pure function of its arguments
/// (no interior state that affects results) — the worker-count
/// determinism contract depends on it.
pub trait LocalUpdateHandle: Send + Sync {
    /// `state` + batches + learning rate -> (new state, mean train loss).
    fn run(&self, state: &ModelState, batch: &Batch, lr: f32) -> Result<(ModelState, f32)>;
}

/// Evaluates a model over a whole dataset.
pub trait EvalHandle: Send + Sync {
    /// Returns `(mean loss, accuracy)` over `ds`.
    fn run_dataset(&self, state: &ModelState, ds: &Dataset) -> Result<(f64, f64)>;
}

/// Build the backend an [`EngineKind`] names.  `artifacts_dir` is only
/// touched by the XLA path — the native engine needs no files at all.
pub fn backend_for_kind(
    kind: EngineKind,
    artifacts_dir: &str,
) -> Result<Arc<dyn TrainBackend>> {
    Ok(match kind {
        EngineKind::Xla => {
            Arc::new(crate::runtime::executor::Engine::load(artifacts_dir)?)
        }
        EngineKind::Native => Arc::new(crate::runtime::native::NativeBackend::new()),
    })
}

/// Build the backend a config selects (`cfg.engine`).
pub fn backend_for(
    cfg: &ExperimentConfig,
    artifacts_dir: &str,
) -> Result<Arc<dyn TrainBackend>> {
    backend_for_kind(cfg.engine, artifacts_dir)
}

// The pool shares backends and handles across threads; the trait bounds
// (`Send + Sync`) make that a compile-time requirement for every
// implementation, exactly like the concrete-type assertion in
// `runtime::executor`.
fn _assert_object_types_thread_safe() {
    #[allow(clippy::extra_unused_type_parameters)]
    fn check<T: Send + Sync + ?Sized>() {}
    check::<dyn TrainBackend>();
    check::<dyn LocalUpdateHandle>();
    check::<dyn EvalHandle>();
}
