//! `artifacts/manifest.json` parsing — the contract with the AOT pipeline.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// One tensor's name and shape, in manifest order.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn nelems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1) // scalar () = 1
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| Error::Json("tensor shape must be an array".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Json("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name: v.str_field("name")?.to_string(), shape })
    }
}

/// One model variant's artifact description.
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub arch: String,
    /// (H, W, C)
    pub image: (usize, usize, usize),
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub k_values: Vec<usize>,
    pub optimizers: Vec<String>,
    pub params: Vec<TensorSpec>,
    pub bn_state: Vec<TensorSpec>,
    /// optimizer name -> state tensors
    pub opt_state: BTreeMap<String, Vec<TensorSpec>>,
    /// optimizer name -> init blob file name
    pub init_blob: BTreeMap<String, String>,
    /// eval executable file name
    pub eval_exe: String,
    /// optimizer -> "k<K>_b<B>" -> local_update executable file name
    pub local_update: BTreeMap<String, BTreeMap<String, String>>,
}

impl VariantSpec {
    /// Trainable parameter element count (the paper's "parameters
    /// uploaded"; excludes BN stats and optimizer state).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(TensorSpec::nelems).sum()
    }

    /// BN state element count.
    pub fn bn_count(&self) -> usize {
        self.bn_state.iter().map(TensorSpec::nelems).sum()
    }

    /// Optimizer state element count.
    pub fn opt_count(&self, opt: &str) -> Result<usize> {
        Ok(self
            .opt_state
            .get(opt)
            .ok_or_else(|| Error::Artifact(format!("no optimizer {opt:?} in {}", self.name)))?
            .iter()
            .map(TensorSpec::nelems)
            .sum())
    }

    /// Full state layout (params ++ bn ++ opt) as one tensor list.
    pub fn state_layout(&self, opt: &str) -> Result<Vec<TensorSpec>> {
        let mut v = self.params.clone();
        v.extend(self.bn_state.iter().cloned());
        v.extend(
            self.opt_state
                .get(opt)
                .ok_or_else(|| {
                    Error::Artifact(format!("no optimizer {opt:?} in {}", self.name))
                })?
                .iter()
                .cloned(),
        );
        Ok(v)
    }

    /// Local-update executable file for (opt, k).
    pub fn local_update_file(&self, opt: &str, k: usize) -> Result<&str> {
        let key = format!("k{k}_b{}", self.train_batch);
        self.local_update
            .get(opt)
            .and_then(|m| m.get(&key))
            .map(|s| s.as_str())
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "variant {} has no local_update for opt={opt} {key} \
                     (available: {:?})",
                    self.name,
                    self.local_update.get(opt).map(|m| m.keys().collect::<Vec<_>>())
                ))
            })
    }

    fn from_json(name: &str, v: &Json) -> Result<VariantSpec> {
        let image = v
            .req("image")?
            .as_arr()
            .ok_or_else(|| Error::Json("image must be an array".into()))?;
        if image.len() != 3 {
            return Err(Error::Json("image must have 3 dims".into()));
        }
        let dim = |i: usize| -> Result<usize> {
            image[i].as_usize().ok_or_else(|| Error::Json("bad image dim".into()))
        };
        let tensor_list = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| Error::Json(format!("{key} must be an array")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let execs = v.req("executables")?;
        let mut local_update = BTreeMap::new();
        if let Some(obj) = execs.req("local_update")?.as_obj() {
            for (opt, table) in obj {
                let mut m = BTreeMap::new();
                if let Some(t) = table.as_obj() {
                    for (k, f) in t {
                        m.insert(
                            k.clone(),
                            f.as_str()
                                .ok_or_else(|| Error::Json("bad exe path".into()))?
                                .to_string(),
                        );
                    }
                }
                local_update.insert(opt.clone(), m);
            }
        }
        let mut opt_state = BTreeMap::new();
        if let Some(obj) = v.req("opt_state")?.as_obj() {
            for (opt, list) in obj {
                let tensors = list
                    .as_arr()
                    .ok_or_else(|| Error::Json("opt_state must hold arrays".into()))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                opt_state.insert(opt.clone(), tensors);
            }
        }
        let mut init_blob = BTreeMap::new();
        if let Some(obj) = v.req("init_blob")?.as_obj() {
            for (opt, f) in obj {
                init_blob.insert(
                    opt.clone(),
                    f.as_str().ok_or_else(|| Error::Json("bad blob path".into()))?.to_string(),
                );
            }
        }
        Ok(VariantSpec {
            name: name.to_string(),
            arch: v.str_field("arch")?.to_string(),
            image: (dim(0)?, dim(1)?, dim(2)?),
            classes: v.usize_field("classes")?,
            train_batch: v.usize_field("train_batch")?,
            eval_batch: v.usize_field("eval_batch")?,
            k_values: v
                .req("k_values")?
                .as_arr()
                .ok_or_else(|| Error::Json("k_values must be an array".into()))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| Error::Json("bad k".into())))
                .collect::<Result<Vec<_>>>()?,
            optimizers: v
                .req("optimizers")?
                .as_arr()
                .ok_or_else(|| Error::Json("optimizers must be an array".into()))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::Json("bad optimizer".into()))
                })
                .collect::<Result<Vec<_>>>()?,
            params: tensor_list("params")?,
            bn_state: tensor_list("bn_state")?,
            opt_state,
            init_blob,
            eval_exe: execs.str_field("eval")?.to_string(),
            local_update,
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub backend: String,
    pub seed: u64,
    pub variants: BTreeMap<String, VariantSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let mut variants = BTreeMap::new();
        if let Some(obj) = v.req("variants")?.as_obj() {
            for (name, spec) in obj {
                variants.insert(name.clone(), VariantSpec::from_json(name, spec)?);
            }
        }
        Ok(Manifest {
            dir,
            backend: v.str_field("backend")?.to_string(),
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "unknown model variant {name:?} (available: {:?})",
                self.variants.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// Absolute path of an artifact file.
    pub fn file(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "backend": "pallas", "seed": 0, "version": 1,
      "variants": {
        "tiny": {
          "arch": "mlp", "image": [4, 4, 1], "classes": 10,
          "train_batch": 8, "eval_batch": 16, "k_values": [1, 5],
          "optimizers": ["sgd"],
          "params": [
            {"name": "fc0_w", "shape": [16, 10]},
            {"name": "fc0_b", "shape": [10]}
          ],
          "bn_state": [],
          "opt_state": {"sgd": []},
          "init_blob": {"sgd": "tiny_sgd_init.bin"},
          "executables": {
            "eval": "tiny_eval_b16.hlo.txt",
            "local_update": {"sgd": {"k1_b8": "a.hlo.txt", "k5_b8": "b.hlo.txt"}}
          }
        }
      }
    }"#;

    fn manifest() -> Manifest {
        let dir = std::env::temp_dir().join("edgeflow_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = manifest();
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.image, (4, 4, 1));
        assert_eq!(v.param_count(), 170);
        assert_eq!(v.bn_count(), 0);
        assert_eq!(v.opt_count("sgd").unwrap(), 0);
        assert_eq!(v.local_update_file("sgd", 5).unwrap(), "b.hlo.txt");
        assert!(v.local_update_file("sgd", 7).is_err());
        assert!(v.local_update_file("adam", 5).is_err());
        assert!(m.variant("missing").is_err());
    }

    #[test]
    fn state_layout_concatenates() {
        let m = manifest();
        let v = m.variant("tiny").unwrap();
        let layout = v.state_layout("sgd").unwrap();
        assert_eq!(layout.len(), 2);
        assert_eq!(layout[0].name, "fc0_w");
    }

    #[test]
    fn scalar_tensor_counts_one() {
        let t = TensorSpec { name: "t".into(), shape: vec![] };
        assert_eq!(t.nelems(), 1);
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }
}
