//! Native optimizers: SGD, heavy-ball momentum, and Adam.
//!
//! Every optimizer's state lives in the flat model state's **optimizer
//! region**, element-aligned behind the params — momentum's velocity as
//! one mirrored tensor per parameter, Adam's first and second moments
//! as two mirrored runs plus the scalar step counter `adam_t` — so it
//! aggregates (Eq. 3), migrates, and checkpoints with the model exactly
//! like the XLA path's optimizer state, with no optimizer-specific code
//! anywhere downstream.  [`OptKind::state_tensors`] is the layout
//! contract; [`OptKind::apply`] is one optimizer step in place.

use crate::runtime::manifest::TensorSpec;
use crate::util::error::{Error, Result};

/// Momentum coefficient of the heavy-ball `momentum` optimizer.
pub const MOMENTUM_MU: f32 = 0.9;
/// Adam hyperparameters (the paper's/XLA path's defaults).
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Which optimizer a native local update applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptKind {
    /// `θ -= η·g`.
    Sgd,
    /// Heavy ball: `v = µv + g; θ -= η·v` (µ = 0.9).
    Momentum,
    /// Adam with bias correction (β1 = 0.9, β2 = 0.999, ε = 1e-8).
    Adam,
}

impl OptKind {
    pub fn parse(s: &str) -> Result<OptKind> {
        match s {
            "sgd" => Ok(OptKind::Sgd),
            "momentum" => Ok(OptKind::Momentum),
            "adam" => Ok(OptKind::Adam),
            other => Err(Error::Config(format!(
                "native engine supports optimizer sgd|momentum|adam, got {other:?}"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptKind::Sgd => "sgd",
            OptKind::Momentum => "momentum",
            OptKind::Adam => "adam",
        }
    }

    /// Optimizer-state tensors appended after the params in the flat
    /// state layout.  Momentum mirrors the param tensors once
    /// (velocity); Adam mirrors them twice (first then second moments,
    /// the XLA artifact's `adam_m_*`/`adam_v_*` naming) and appends the
    /// scalar step counter `adam_t`, so bias correction survives
    /// migration and checkpoint/resume.
    pub fn state_tensors(&self, params: &[TensorSpec]) -> Vec<TensorSpec> {
        match self {
            OptKind::Sgd => Vec::new(),
            OptKind::Momentum => params
                .iter()
                .map(|t| TensorSpec {
                    name: format!("v_{}", t.name),
                    shape: t.shape.clone(),
                })
                .collect(),
            OptKind::Adam => {
                let mut v: Vec<TensorSpec> = params
                    .iter()
                    .map(|t| TensorSpec {
                        name: format!("adam_m_{}", t.name),
                        shape: t.shape.clone(),
                    })
                    .collect();
                v.extend(params.iter().map(|t| TensorSpec {
                    name: format!("adam_v_{}", t.name),
                    shape: t.shape.clone(),
                }));
                v.push(TensorSpec { name: "adam_t".into(), shape: vec![] });
                v
            }
        }
    }

    /// Element count of the optimizer region for `n_params` parameter
    /// elements.
    pub fn state_elems(&self, n_params: usize) -> usize {
        match self {
            OptKind::Sgd => 0,
            OptKind::Momentum => n_params,
            OptKind::Adam => 2 * n_params + 1,
        }
    }

    /// One optimizer step in place: `state` is the flat model state
    /// (params `[..n_params]` directly followed by this optimizer's
    /// region — native models carry no BN tensors in between), `grads`
    /// the parameter gradients.
    pub fn apply(&self, n_params: usize, state: &mut [f32], grads: &[f32], lr: f32) {
        debug_assert_eq!(grads.len(), n_params);
        debug_assert_eq!(state.len(), n_params + self.state_elems(n_params));
        let (params, opt) = state.split_at_mut(n_params);
        match self {
            OptKind::Sgd => {
                for (p, &g) in params.iter_mut().zip(grads) {
                    *p -= lr * g;
                }
            }
            OptKind::Momentum => {
                for ((p, v), &g) in params.iter_mut().zip(opt.iter_mut()).zip(grads) {
                    *v = MOMENTUM_MU * *v + g;
                    *p -= lr * *v;
                }
            }
            OptKind::Adam => {
                let (m, rest) = opt.split_at_mut(n_params);
                let (v, t) = rest.split_at_mut(n_params);
                // The step counter is fractional-valued on purpose: Eq. 3
                // averages it like any other state element, and clients
                // folded late (straggler re-inclusion) can leave it
                // between integers.
                t[0] += 1.0;
                let bc1 = 1.0 - ADAM_B1.powf(t[0]);
                let bc2 = 1.0 - ADAM_B2.powf(t[0]);
                for (((p, mi), vi), &g) in params
                    .iter_mut()
                    .zip(m.iter_mut())
                    .zip(v.iter_mut())
                    .zip(grads)
                {
                    *mi = ADAM_B1 * *mi + (1.0 - ADAM_B1) * g;
                    *vi = ADAM_B2 * *vi + (1.0 - ADAM_B2) * g * g;
                    let mhat = *mi / bc1;
                    let vhat = *vi / bc2;
                    *p -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params2() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "w".into(), shape: vec![2, 3] },
            TensorSpec { name: "b".into(), shape: vec![3] },
        ]
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for k in [OptKind::Sgd, OptKind::Momentum, OptKind::Adam] {
            assert_eq!(OptKind::parse(k.name()).unwrap(), k);
        }
        assert!(OptKind::parse("rmsprop").is_err());
    }

    #[test]
    fn state_tensor_layouts() {
        let p = params2();
        assert!(OptKind::Sgd.state_tensors(&p).is_empty());
        let mom = OptKind::Momentum.state_tensors(&p);
        assert_eq!(mom.len(), 2);
        assert_eq!(mom[0].name, "v_w");
        assert_eq!(mom[0].shape, vec![2, 3]);
        let adam = OptKind::Adam.state_tensors(&p);
        assert_eq!(adam.len(), 5);
        assert_eq!(adam[0].name, "adam_m_w");
        assert_eq!(adam[2].name, "adam_v_w");
        assert_eq!(adam[4].name, "adam_t");
        assert!(adam[4].shape.is_empty());
        assert_eq!(adam[4].nelems(), 1, "scalar step counter");
        assert_eq!(OptKind::Sgd.state_elems(9), 0);
        assert_eq!(OptKind::Momentum.state_elems(9), 9);
        assert_eq!(OptKind::Adam.state_elems(9), 19);
    }

    #[test]
    fn sgd_and_momentum_steps() {
        let g = [1.0f32, -2.0];
        let mut s = vec![0.5f32, 0.5];
        OptKind::Sgd.apply(2, &mut s, &g, 0.1);
        assert_eq!(s, vec![0.4, 0.7]);
        // Momentum: first step equals SGD (v = g), second compounds.
        let mut s = vec![0.5f32, 0.5, 0.0, 0.0];
        OptKind::Momentum.apply(2, &mut s, &g, 0.1);
        assert_eq!(&s[..2], &[0.4, 0.7]);
        assert_eq!(&s[2..], &[1.0, -2.0], "velocity = g after step one");
        OptKind::Momentum.apply(2, &mut s, &g, 0.1);
        // v = 0.9*g + g = 1.9*g; p -= 0.1 * 1.9 * g
        assert!((s[0] - (0.4 - 0.19)).abs() < 1e-6);
        assert!((s[1] - (0.7 + 0.38)).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_matches_closed_form() {
        // From zero moments, step one: m̂ = g, v̂ = g², so
        // θ -= lr·g/(|g| + ε) ≈ lr·sign(g).
        let g = [0.5f32, -0.25];
        let mut s = vec![1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        OptKind::Adam.apply(2, &mut s, &g, 0.01);
        assert!((s[0] - (1.0 - 0.01)).abs() < 1e-5, "{}", s[0]);
        assert!((s[1] - (1.0 + 0.01)).abs() < 1e-5, "{}", s[1]);
        // Moments and the step counter moved into the state.
        assert!((s[2] - 0.05).abs() < 1e-6, "m = (1-β1)g");
        assert!((s[4] - 0.5 * 0.5 * 0.001).abs() < 1e-7, "v = (1-β2)g²");
        assert_eq!(s[6], 1.0, "adam_t advanced");
        OptKind::Adam.apply(2, &mut s, &g, 0.01);
        assert_eq!(s[6], 2.0);
        // Constant gradient: bias-corrected step stays ≈ lr·sign(g).
        assert!((s[0] - (1.0 - 0.02)).abs() < 1e-4, "{}", s[0]);
    }

    #[test]
    fn adam_step_size_bounded_by_lr_for_constant_gradient() {
        // The signature Adam property: per-coordinate steps are ≈ lr
        // regardless of gradient magnitude.
        for scale in [1e-3f32, 1.0, 1e3] {
            let g = [scale, -scale];
            let mut s = vec![0.0f32, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
            OptKind::Adam.apply(2, &mut s, &g, 0.01);
            assert!((s[0] + 0.01).abs() < 1e-4, "scale {scale}: {}", s[0]);
            assert!((s[1] - 0.01).abs() < 1e-4, "scale {scale}: {}", s[1]);
        }
    }
}
