//! Native model zoo: architectures, parameter layouts, and the batched
//! forward/backward built on [`super::kernels`].
//!
//! Three architectures share one flat-parameter convention (tensors in
//! [`Model::param_tensors`] order, biases zero-initialized):
//!
//! * [`Arch::Linear`] — multinomial logistic regression
//!   (`softmax(xW + b)`).
//! * [`Arch::Mlp`] — one hidden ReLU layer
//!   (`softmax(relu(xW1 + b1)W2 + b2)`).
//! * [`Arch::Cnn`] — conv 3×3 SAME (im2col lowering) → ReLU → 2×2
//!   max-pool → dense ReLU layer → dense classifier: the native port of
//!   the XLA path's `*_cnn_slim_fast` design (conv as one
//!   `patches · W` GEMM).
//!
//! The batched path ([`loss_and_grads`]) runs the whole minibatch
//! through the blocked-GEMM kernels; [`loss_and_grads_per_sample`] is
//! the pre-kernel per-sample scalar path, kept (for the linear/MLP
//! architectures it used to serve) as the equivalence oracle in tests
//! and the baseline `benches/bench_native.rs` measures the batched
//! path against.

use crate::runtime::manifest::TensorSpec;
use crate::runtime::native::kernels;

/// Architecture of a native variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// `w [in, classes], b [classes]`.
    Linear,
    /// `w1 [in, hidden], b1, w2 [hidden, classes], b2`.
    Mlp { hidden: usize },
    /// `conv_w [3,3,cin,channels], conv_b, fc1_w [flat, hidden], fc1_b,
    /// fc2_w [hidden, classes], fc2_b` where
    /// `flat = (h/2)·(w/2)·channels` after the 2×2 pool.
    Cnn { channels: usize, hidden: usize },
}

/// Shape summary of one variant — everything forward/backward needs.
#[derive(Debug, Clone, Copy)]
pub struct Model {
    pub arch: Arch,
    /// (H, W, C) of the input images.
    pub image: (usize, usize, usize),
    pub classes: usize,
}

impl Model {
    /// Flattened input size per sample.
    pub fn input(&self) -> usize {
        let (h, w, c) = self.image;
        h * w * c
    }

    /// Post-pool flattened feature size of the CNN (0 otherwise).
    fn cnn_flat(&self) -> usize {
        match self.arch {
            Arch::Cnn { channels, .. } => {
                let (h, w, _) = self.image;
                (h / 2) * (w / 2) * channels
            }
            _ => 0,
        }
    }

    /// Trainable parameter element count.
    pub fn param_elems(&self) -> usize {
        self.param_tensors().iter().map(TensorSpec::nelems).sum()
    }

    /// Parameter tensor list, in flat-layout order.
    pub fn param_tensors(&self) -> Vec<TensorSpec> {
        let (_, _, cin) = self.image;
        let cls = self.classes;
        match self.arch {
            Arch::Linear => vec![
                TensorSpec { name: "w".into(), shape: vec![self.input(), cls] },
                TensorSpec { name: "b".into(), shape: vec![cls] },
            ],
            Arch::Mlp { hidden } => vec![
                TensorSpec { name: "w1".into(), shape: vec![self.input(), hidden] },
                TensorSpec { name: "b1".into(), shape: vec![hidden] },
                TensorSpec { name: "w2".into(), shape: vec![hidden, cls] },
                TensorSpec { name: "b2".into(), shape: vec![cls] },
            ],
            Arch::Cnn { channels, hidden } => vec![
                TensorSpec {
                    name: "conv_w".into(),
                    shape: vec![3, 3, cin, channels],
                },
                TensorSpec { name: "conv_b".into(), shape: vec![channels] },
                TensorSpec {
                    name: "fc1_w".into(),
                    shape: vec![self.cnn_flat(), hidden],
                },
                TensorSpec { name: "fc1_b".into(), shape: vec![hidden] },
                TensorSpec { name: "fc2_w".into(), shape: vec![hidden, cls] },
                TensorSpec { name: "fc2_b".into(), shape: vec![cls] },
            ],
        }
    }
}

/// Reusable scratch for the batched forward/backward of one
/// (model, max-batch) pair — allocated once per local-update or eval
/// call and reused across its steps/chunks, so the hot loop never
/// allocates.  Buffers a given architecture doesn't need stay empty.
pub struct Workspace {
    /// Row capacity the buffers are sized for.
    batch: usize,
    /// CNN: im2col patches `[b*h*w, 9*cin]`.
    patches: Vec<f32>,
    /// CNN: post-ReLU conv activations `[b*h*w, channels]`.
    conv: Vec<f32>,
    dconv: Vec<f32>,
    /// CNN: pooled features `[b, flat]` + argmax indices.
    pool: Vec<f32>,
    arg: Vec<u32>,
    dpool: Vec<f32>,
    /// MLP hidden / CNN fc1 post-ReLU activations `[b, hidden]`.
    hidden: Vec<f32>,
    dhidden: Vec<f32>,
    /// `[b, classes]`.
    logits: Vec<f32>,
    dlogits: Vec<f32>,
}

impl Workspace {
    pub fn new(model: &Model, batch: usize) -> Workspace {
        let (h, w, cin) = model.image;
        let cls = model.classes;
        let (patches, conv, pool, hid) = match model.arch {
            Arch::Linear => (0, 0, 0, 0),
            Arch::Mlp { hidden } => (0, 0, 0, batch * hidden),
            Arch::Cnn { channels, hidden } => (
                batch * h * w * 9 * cin,
                batch * h * w * channels,
                batch * model.cnn_flat(),
                batch * hidden,
            ),
        };
        Workspace {
            batch,
            patches: vec![0.0; patches],
            conv: vec![0.0; conv],
            dconv: vec![0.0; conv],
            pool: vec![0.0; pool],
            arg: vec![0; pool],
            dpool: vec![0.0; pool],
            hidden: vec![0.0; hid],
            dhidden: vec![0.0; hid],
            logits: vec![0.0; batch * cls],
            dlogits: vec![0.0; batch * cls],
        }
    }

    /// Logits of the last [`forward_into`] call (`bt` rows).
    pub fn logits(&self, bt: usize, classes: usize) -> &[f32] {
        &self.logits[..bt * classes]
    }
}

/// Batched forward pass for `bt` samples (`bt <=` the workspace's
/// capacity): fills `ws.logits[..bt*classes]` plus every intermediate
/// activation the backward pass reads.
pub fn forward_into(model: &Model, params: &[f32], x: &[f32], bt: usize, ws: &mut Workspace) {
    debug_assert!(bt <= ws.batch);
    debug_assert_eq!(x.len(), bt * model.input());
    debug_assert_eq!(params.len(), model.param_elems());
    let cls = model.classes;
    match model.arch {
        Arch::Linear => {
            let n_in = model.input();
            let (w, b) = params.split_at(n_in * cls);
            let logits = &mut ws.logits[..bt * cls];
            logits.fill(0.0);
            kernels::gemm(bt, n_in, cls, x, w, logits);
            kernels::bias_act(logits, bt, cls, b, false);
        }
        Arch::Mlp { hidden } => {
            let n_in = model.input();
            let (w1, rest) = params.split_at(n_in * hidden);
            let (b1, rest) = rest.split_at(hidden);
            let (w2, b2) = rest.split_at(hidden * cls);
            let h = &mut ws.hidden[..bt * hidden];
            h.fill(0.0);
            kernels::gemm(bt, n_in, hidden, x, w1, h);
            kernels::bias_act(h, bt, hidden, b1, true);
            let logits = &mut ws.logits[..bt * cls];
            logits.fill(0.0);
            kernels::gemm(bt, hidden, cls, h, w2, logits);
            kernels::bias_act(logits, bt, cls, b2, false);
        }
        Arch::Cnn { channels, hidden } => {
            let (h_img, w_img, cin) = model.image;
            let px = h_img * w_img;
            let ksz = 9 * cin;
            let flat = model.cnn_flat();
            let (conv_w, rest) = params.split_at(ksz * channels);
            let (conv_b, rest) = rest.split_at(channels);
            let (w1, rest) = rest.split_at(flat * hidden);
            let (b1, rest) = rest.split_at(hidden);
            let (w2, b2) = rest.split_at(hidden * cls);
            let patches = &mut ws.patches[..bt * px * ksz];
            kernels::im2col_3x3(x, bt, h_img, w_img, cin, patches);
            let conv = &mut ws.conv[..bt * px * channels];
            conv.fill(0.0);
            kernels::gemm(bt * px, ksz, channels, patches, conv_w, conv);
            kernels::bias_act(conv, bt * px, channels, conv_b, true);
            let pool = &mut ws.pool[..bt * flat];
            let arg = &mut ws.arg[..bt * flat];
            kernels::maxpool2x2(conv, bt, h_img, w_img, channels, pool, arg);
            let fc1 = &mut ws.hidden[..bt * hidden];
            fc1.fill(0.0);
            kernels::gemm(bt, flat, hidden, pool, w1, fc1);
            kernels::bias_act(fc1, bt, hidden, b1, true);
            let logits = &mut ws.logits[..bt * cls];
            logits.fill(0.0);
            kernels::gemm(bt, hidden, cls, fc1, w2, logits);
            kernels::bias_act(logits, bt, cls, b2, false);
        }
    }
}

/// Mean loss over one minibatch on the batched kernel path; when
/// `grads` is given (length [`Model::param_elems`], caller zeroes it),
/// accumulates `d(mean loss)/d(params)` into it.  `x` is `[bt, input]`
/// flat, `y` the `bt` labels.
pub fn loss_and_grads(
    model: &Model,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    mut grads: Option<&mut [f32]>,
    ws: &mut Workspace,
) -> f32 {
    let bt = y.len();
    let cls = model.classes;
    forward_into(model, params, x, bt, ws);
    let logits = &ws.logits[..bt * cls];
    let dlogits = &mut ws.dlogits[..bt * cls];
    let loss = kernels::softmax_xent_rows(logits, y, cls, dlogits) / bt as f32;
    let Some(g) = grads.as_deref_mut() else {
        return loss;
    };
    debug_assert_eq!(g.len(), model.param_elems());
    kernels::finish_dlogits(dlogits, y, cls);
    match model.arch {
        Arch::Linear => {
            let n_in = model.input();
            let (gw, gb) = g.split_at_mut(n_in * cls);
            kernels::gemm_tn(bt, n_in, cls, x, dlogits, gw);
            kernels::col_sums(dlogits, cls, gb);
        }
        Arch::Mlp { hidden } => {
            let n_in = model.input();
            let w2_off = n_in * hidden + hidden;
            let w2 = &params[w2_off..w2_off + hidden * cls];
            let (gw1, rest) = g.split_at_mut(n_in * hidden);
            let (gb1, rest) = rest.split_at_mut(hidden);
            let (gw2, gb2) = rest.split_at_mut(hidden * cls);
            let h = &ws.hidden[..bt * hidden];
            kernels::gemm_tn(bt, hidden, cls, h, dlogits, gw2);
            kernels::col_sums(dlogits, cls, gb2);
            let dh = &mut ws.dhidden[..bt * hidden];
            dh.fill(0.0);
            kernels::gemm_nt(bt, cls, hidden, dlogits, w2, dh);
            kernels::relu_mask(dh, h);
            kernels::gemm_tn(bt, n_in, hidden, x, dh, gw1);
            kernels::col_sums(dh, hidden, gb1);
        }
        Arch::Cnn { channels, hidden } => {
            let (h_img, w_img, cin) = model.image;
            let px = h_img * w_img;
            let ksz = 9 * cin;
            let flat = model.cnn_flat();
            let o_fc1 = ksz * channels + channels;
            let w1 = &params[o_fc1..o_fc1 + flat * hidden];
            let o_fc2 = o_fc1 + flat * hidden + hidden;
            let w2 = &params[o_fc2..o_fc2 + hidden * cls];
            let (gconv_w, rest) = g.split_at_mut(ksz * channels);
            let (gconv_b, rest) = rest.split_at_mut(channels);
            let (gw1, rest) = rest.split_at_mut(flat * hidden);
            let (gb1, rest) = rest.split_at_mut(hidden);
            let (gw2, gb2) = rest.split_at_mut(hidden * cls);
            // Dense head, exactly like the MLP backward.
            let fc1 = &ws.hidden[..bt * hidden];
            kernels::gemm_tn(bt, hidden, cls, fc1, dlogits, gw2);
            kernels::col_sums(dlogits, cls, gb2);
            let dfc1 = &mut ws.dhidden[..bt * hidden];
            dfc1.fill(0.0);
            kernels::gemm_nt(bt, cls, hidden, dlogits, w2, dfc1);
            kernels::relu_mask(dfc1, fc1);
            let pool = &ws.pool[..bt * flat];
            kernels::gemm_tn(bt, flat, hidden, pool, dfc1, gw1);
            kernels::col_sums(dfc1, hidden, gb1);
            // Back through pool (argmax scatter) and the conv ReLU.
            let dpool = &mut ws.dpool[..bt * flat];
            dpool.fill(0.0);
            kernels::gemm_nt(bt, hidden, flat, dfc1, w1, dpool);
            let conv = &ws.conv[..bt * px * channels];
            let dconv = &mut ws.dconv[..bt * px * channels];
            dconv.fill(0.0);
            kernels::maxpool2x2_backward(dpool, &ws.arg[..bt * flat], dconv);
            kernels::relu_mask(dconv, conv);
            // Conv weight gradient: the same im2col patches, transposed.
            let patches = &ws.patches[..bt * px * ksz];
            kernels::gemm_tn(bt * px, ksz, channels, patches, dconv, gconv_w);
            kernels::col_sums(dconv, channels, gconv_b);
            // The conv is the first layer: no input gradient needed.
        }
    }
    loss
}

// ------------------------------------------------------- per-sample path

/// Mean loss (and gradients, like [`loss_and_grads`]) on the
/// **pre-kernel per-sample scalar path** — one sample at a time, scalar
/// accumulation loops, no batching.  Supports the linear/MLP
/// architectures it used to serve; kept as the equivalence oracle for
/// the batched path's tests and the baseline `benches/bench_native.rs`
/// measures against.  Panics on the CNN (which never had a per-sample
/// implementation).
pub fn loss_and_grads_per_sample(
    model: &Model,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    mut grads: Option<&mut [f32]>,
) -> f32 {
    let (input, cls) = (model.input(), model.classes);
    let hidden = match model.arch {
        Arch::Linear => 0,
        Arch::Mlp { hidden } => hidden,
        Arch::Cnn { .. } => {
            // lint:allow(unwrap-in-library): documented contract of
            // this test/bench oracle — the CNN never had a per-sample
            // implementation, and a typed error would let the
            // equivalence tests silently skip it.
            panic!("per-sample baseline covers linear/mlp only")
        }
    };
    let batch = y.len();
    let inv_b = 1.0 / batch as f32;
    // Scratch hoisted out of the per-sample loop.
    let mut hid = vec![0f32; hidden];
    let mut logits = vec![0f32; cls];
    let mut dlogits = vec![0f32; cls];
    let mut dh = vec![0f32; hidden];
    let mut loss_sum = 0f32;
    for s in 0..batch {
        let xs = &x[s * input..(s + 1) * input];
        let ys = y[s] as usize;
        forward_per_sample(input, hidden, cls, params, xs, &mut hid, &mut logits);
        loss_sum += kernels::softmax_xent_rows(&logits, &y[s..s + 1], cls, &mut dlogits);
        let Some(g) = grads.as_deref_mut() else { continue };
        dlogits[ys] -= 1.0;
        for dl in dlogits.iter_mut() {
            *dl *= inv_b;
        }
        if hidden == 0 {
            let (gw, gb) = g.split_at_mut(input * cls);
            for (i, &xi) in xs.iter().enumerate() {
                // lint:allow(float-ordering): exact-zero sparsity skip
                // — only bit-zero inputs contribute nothing, a
                // tolerance would change the math.
                if xi == 0.0 {
                    continue;
                }
                let row = &mut gw[i * cls..(i + 1) * cls];
                for (gv, &dl) in row.iter_mut().zip(&dlogits) {
                    *gv += xi * dl;
                }
            }
            for (gv, &dl) in gb.iter_mut().zip(&dlogits) {
                *gv += dl;
            }
        } else {
            let (gw1, rest) = g.split_at_mut(input * hidden);
            let (gb1, rest) = rest.split_at_mut(hidden);
            let (gw2, gb2) = rest.split_at_mut(hidden * cls);
            let w2_off = input * hidden + hidden;
            let w2 = &params[w2_off..w2_off + hidden * cls];
            for (j, &hj) in hid.iter().enumerate() {
                let row = &w2[j * cls..(j + 1) * cls];
                let grow = &mut gw2[j * cls..(j + 1) * cls];
                let mut acc = 0f32;
                for ((gv, &wv), &dl) in grow.iter_mut().zip(row).zip(&dlogits) {
                    acc += wv * dl;
                    *gv += hj * dl;
                }
                dh[j] = if hj > 0.0 { acc } else { 0.0 };
            }
            for (gv, &dl) in gb2.iter_mut().zip(&dlogits) {
                *gv += dl;
            }
            for (i, &xi) in xs.iter().enumerate() {
                // lint:allow(float-ordering): exact-zero sparsity skip,
                // same as the linear arm above.
                if xi == 0.0 {
                    continue;
                }
                let row = &mut gw1[i * hidden..(i + 1) * hidden];
                for (gv, &dhj) in row.iter_mut().zip(&dh) {
                    *gv += xi * dhj;
                }
            }
            for (gv, &dhj) in gb1.iter_mut().zip(&dh) {
                *gv += dhj;
            }
        }
    }
    loss_sum * inv_b
}

/// Single-sample forward of the per-sample path (linear when
/// `hidden == 0`).
fn forward_per_sample(
    input: usize,
    hidden: usize,
    cls: usize,
    params: &[f32],
    x: &[f32],
    hid: &mut [f32],
    logits: &mut [f32],
) {
    if hidden == 0 {
        let w = &params[..input * cls];
        let b = &params[input * cls..];
        logits.copy_from_slice(b);
        for (i, &xi) in x.iter().enumerate() {
            // lint:allow(float-ordering): exact-zero sparsity skip —
            // only bit-zero inputs contribute nothing to the matmul.
            if xi == 0.0 {
                continue;
            }
            let row = &w[i * cls..(i + 1) * cls];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += xi * wv;
            }
        }
    } else {
        let (w1, rest) = params.split_at(input * hidden);
        let (b1, rest) = rest.split_at(hidden);
        let (w2, b2) = rest.split_at(hidden * cls);
        hid.copy_from_slice(b1);
        for (i, &xi) in x.iter().enumerate() {
            // lint:allow(float-ordering): exact-zero sparsity skip,
            // same as the linear arm above.
            if xi == 0.0 {
                continue;
            }
            let row = &w1[i * hidden..(i + 1) * hidden];
            for (h, &wv) in hid.iter_mut().zip(row) {
                *h += xi * wv;
            }
        }
        for h in hid.iter_mut() {
            if *h < 0.0 {
                *h = 0.0;
            }
        }
        logits.copy_from_slice(&b2[..cls]);
        for (j, &hj) in hid.iter().enumerate() {
            // lint:allow(float-ordering): ReLU writes exact 0.0 for
            // clipped units, so the bit-equality skip is lossless.
            if hj == 0.0 {
                continue;
            }
            let row = &w2[j * cls..(j + 1) * cls];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += hj * wv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn linear_model() -> Model {
        Model { arch: Arch::Linear, image: (2, 2, 1), classes: 3 }
    }

    fn mlp_model() -> Model {
        Model { arch: Arch::Mlp { hidden: 5 }, image: (2, 2, 1), classes: 3 }
    }

    fn cnn_model() -> Model {
        Model {
            arch: Arch::Cnn { channels: 3, hidden: 4 },
            image: (6, 6, 1),
            classes: 3,
        }
    }

    fn seeded_params(model: &Model, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..model.param_elems()).map(|_| rng.range(-0.5, 0.5) as f32).collect()
    }

    fn tiny_batch(model: &Model, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x = (0..b * model.input()).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let y = (0..b).map(|_| rng.below(model.classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn param_layouts_are_consistent() {
        let m = cnn_model();
        // conv 9·1·3 + 3, fc1 (3·3·3)·4 + 4, fc2 4·3 + 3
        assert_eq!(m.cnn_flat(), 27);
        assert_eq!(m.param_elems(), 27 + 3 + 108 + 4 + 12 + 3);
        let names: Vec<String> =
            m.param_tensors().into_iter().map(|t| t.name).collect();
        assert_eq!(
            names,
            vec!["conv_w", "conv_b", "fc1_w", "fc1_b", "fc2_w", "fc2_b"]
        );
        assert_eq!(linear_model().param_elems(), 4 * 3 + 3);
        assert_eq!(mlp_model().param_elems(), 4 * 5 + 5 + 5 * 3 + 3);
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Central finite differences over every parameter, for all
        // three architectures — the conv path (im2col + pool + two
        // dense layers) included.  The CNN gets a looser per-element
        // budget plus a tight relative-L2 bound: a ±eps perturbation
        // can cross a max-pool argmax or ReLU kink, which perturbs the
        // *numeric* estimate of a single coordinate without meaning the
        // analytic gradient is wrong; any real layout/sign bug still
        // blows both bounds by orders of magnitude.
        for model in [linear_model(), mlp_model(), cnn_model()] {
            let is_cnn = matches!(model.arch, Arch::Cnn { .. });
            let params = seeded_params(&model, 1);
            let (x, y) = tiny_batch(&model, 3, 2);
            let mut ws = Workspace::new(&model, 3);
            let mut grads = vec![0f32; model.param_elems()];
            loss_and_grads(&model, &params, &x, &y, Some(&mut grads), &mut ws);
            let eps = if is_cnn { 1e-3f32 } else { 2e-3f32 };
            let (tol_abs, tol_rel) = if is_cnn { (3e-2, 0.1) } else { (1e-2, 0.05) };
            let mut err2 = 0f64;
            let mut ref2 = 0f64;
            for i in 0..model.param_elems() {
                let mut plus = params.clone();
                plus[i] += eps;
                let mut minus = params.clone();
                minus[i] -= eps;
                let lp = loss_and_grads(&model, &plus, &x, &y, None, &mut ws);
                let lm = loss_and_grads(&model, &minus, &x, &y, None, &mut ws);
                let numeric = (lp - lm) / (2.0 * eps);
                err2 += ((numeric - grads[i]) as f64).powi(2);
                ref2 += (grads[i] as f64).powi(2);
                assert!(
                    (numeric - grads[i]).abs() <= tol_abs + tol_rel * grads[i].abs(),
                    "{:?} param {i}: numeric {numeric} vs analytic {}",
                    model.arch,
                    grads[i]
                );
            }
            assert!(
                err2.sqrt() <= 0.02 * ref2.sqrt().max(1.0),
                "{:?}: FD/analytic relative L2 error {} too large",
                model.arch,
                err2.sqrt() / ref2.sqrt().max(1.0)
            );
        }
    }

    #[test]
    fn batched_path_matches_per_sample_baseline() {
        // The blocked-GEMM path must compute the same loss and
        // gradients as the pre-kernel per-sample scalar path.
        for model in [linear_model(), mlp_model()] {
            let params = seeded_params(&model, 3);
            let (x, y) = tiny_batch(&model, 7, 4);
            let n = model.param_elems();
            let mut ws = Workspace::new(&model, 7);
            let mut g_batch = vec![0f32; n];
            let lb =
                loss_and_grads(&model, &params, &x, &y, Some(&mut g_batch), &mut ws);
            let mut g_ref = vec![0f32; n];
            let lr = loss_and_grads_per_sample(
                &model,
                &params,
                &x,
                &y,
                Some(&mut g_ref),
            );
            assert!(
                (lb - lr).abs() <= 1e-5 + 1e-5 * lr.abs(),
                "{:?} loss {lb} vs {lr}",
                model.arch
            );
            for (i, (&a, &b)) in g_batch.iter().zip(&g_ref).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-5 + 1e-4 * b.abs(),
                    "{:?} grad {i}: {a} vs {b}",
                    model.arch
                );
            }
        }
    }

    #[test]
    fn repeated_steps_on_one_batch_strictly_decrease_loss() {
        for model in [linear_model(), mlp_model(), cnn_model()] {
            let mut params = seeded_params(&model, 5);
            let (x, y) = tiny_batch(&model, 4, 6);
            let mut ws = Workspace::new(&model, 4);
            let mut grads = vec![0f32; model.param_elems()];
            let mut last = f32::INFINITY;
            for _ in 0..10 {
                grads.fill(0.0);
                let loss = loss_and_grads(
                    &model,
                    &params,
                    &x,
                    &y,
                    Some(&mut grads),
                    &mut ws,
                );
                assert!(loss < last, "{:?}: {loss} !< {last}", model.arch);
                last = loss;
                for (p, g) in params.iter_mut().zip(&grads) {
                    *p -= 0.1 * g;
                }
            }
        }
    }

    #[test]
    fn workspace_supports_partial_batches() {
        // Eval runs a trailing chunk smaller than capacity through the
        // same workspace; logits must match a fresh exact-size one.
        let model = cnn_model();
        let params = seeded_params(&model, 7);
        let (x, _y) = tiny_batch(&model, 2, 8);
        let mut big = Workspace::new(&model, 8);
        forward_into(&model, &params, &x, 2, &mut big);
        let mut exact = Workspace::new(&model, 2);
        forward_into(&model, &params, &x, 2, &mut exact);
        assert_eq!(
            big.logits(2, model.classes),
            exact.logits(2, model.classes)
        );
    }
}
