//! Pure-Rust in-process training engine (`engine: native`).
//!
//! A hand-written trainer over the same flat [`ModelState`]/
//! [`StateLayout`] the XLA path uses, so everything downstream — Eq. 3
//! aggregation, migration byte accounting, checkpointing — is
//! engine-agnostic.  The module family:
//!
//! * [`kernels`] — batch-level compute: blocked/register-tiled GEMM
//!   (plus transposed-A/B forms), fused bias+ReLU, the im2col conv
//!   lowering ported from the XLA path's `*_fast` design, max-pool, and
//!   row-wise softmax cross-entropy.  Forward/backward ride these
//!   instead of per-sample scalar loops.
//! * [`models`] — the architectures and their batched forward/backward:
//!   `*_linear` (multinomial logistic regression), `*_mlp` (one hidden
//!   ReLU layer), and `*_cnn_slim_fast` (conv 3×3 → ReLU → 2×2 max-pool
//!   → dense ReLU → classifier).  The pre-kernel per-sample path
//!   survives as the test oracle and `benches/bench_native.rs` baseline.
//! * [`optim`] — SGD, heavy-ball momentum, and Adam.  All optimizer
//!   state (velocity; Adam's two moment runs + step counter) lives in
//!   the state's optimizer region, so it aggregates, migrates, and
//!   checkpoints with the model unchanged.
//!
//! Everything here is a pure function of its inputs: weight init is
//! seeded per variant, minibatches come from the loader's
//! `(seed, client, round)` stream, and no interior state survives a
//! call — so runs are deterministic in `(seed, client, round)` and
//! bit-identical at any worker count.  No artifacts, no Python, no
//! files: this is the engine CI trains with.

pub mod kernels;
pub mod models;
pub mod optim;

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::dataset::{Batch, Dataset};
use crate::rng::Rng;
use crate::runtime::backend::{EvalHandle, LocalUpdateHandle, TrainBackend};
use crate::runtime::manifest::VariantSpec;
use crate::runtime::params::{ModelState, StateLayout};
use crate::util::error::{Error, Result};

use models::{Arch, Model, Workspace};
use optim::OptKind;

/// Hidden width of the `*_mlp` variants.
const MLP_HIDDEN: usize = 64;

/// Conv channels / dense hidden width of the `*_cnn_slim_fast`
/// variants (the XLA slim-CNN family's leading conv width and
/// `fc_hidden`).
const CNN_CHANNELS: usize = 8;
const CNN_HIDDEN: usize = 64;

/// Seed for the deterministic weight init (mixed with the variant name).
const INIT_SEED: u64 = 0x9A71_BE11;

/// Rows per forward chunk in whole-dataset eval.  Fixed, so eval is a
/// pure function of (state, dataset) regardless of dataset size.
const EVAL_CHUNK: usize = 64;

/// One entry of the built-in variant table.
#[derive(Debug, Clone, Copy)]
struct NativeVariant {
    name: &'static str,
    model: Model,
}

/// The built-in model zoo.  `fashion_*`/`cifar_*` variants share the
/// XLA manifest's names so configs can flip `engine` without renaming
/// models (`*_cnn_slim_fast` is the native port of the XLA im2col CNN
/// design — one conv block instead of six, same lowering).
fn variant(name: &str) -> Result<NativeVariant> {
    const CNN: Arch = Arch::Cnn { channels: CNN_CHANNELS, hidden: CNN_HIDDEN };
    const MLP: Arch = Arch::Mlp { hidden: MLP_HIDDEN };
    let (name, arch, image): (&'static str, Arch, (usize, usize, usize)) = match name {
        "fashion_linear" => ("fashion_linear", Arch::Linear, (28, 28, 1)),
        "fashion_mlp" => ("fashion_mlp", MLP, (28, 28, 1)),
        "cifar_linear" => ("cifar_linear", Arch::Linear, (32, 32, 3)),
        "cifar_mlp" => ("cifar_mlp", MLP, (32, 32, 3)),
        "fashion_cnn_slim_fast" => ("fashion_cnn_slim_fast", CNN, (28, 28, 1)),
        "cifar_cnn_slim_fast" => ("cifar_cnn_slim_fast", CNN, (32, 32, 3)),
        other => {
            return Err(Error::Config(format!(
                "native engine has no model variant {other:?} (available: \
                 fashion_linear, fashion_mlp, cifar_linear, cifar_mlp, \
                 fashion_cnn_slim_fast, cifar_cnn_slim_fast)"
            )))
        }
    };
    Ok(NativeVariant { name, model: Model { arch, image, classes: 10 } })
}

fn arch_name(arch: Arch) -> &'static str {
    match arch {
        Arch::Linear => "linear",
        Arch::Mlp { .. } => "mlp",
        Arch::Cnn { .. } => "cnn",
    }
}

/// Build the flat state layout (params ++ optimizer state) for
/// (variant, optimizer), reusing the manifest-side [`StateLayout`] so
/// blob I/O, aggregation and wire accounting need no native-specific
/// code.
fn layout_for(v: &NativeVariant, opt: &str) -> Result<(Arc<StateLayout>, OptKind)> {
    let kind = OptKind::parse(opt)?;
    let params = v.model.param_tensors();
    let opt_tensors = kind.state_tensors(&params);
    let (h, w, c) = v.model.image;
    let spec = VariantSpec {
        name: v.name.to_string(),
        arch: arch_name(v.model.arch).into(),
        image: (h, w, c),
        classes: v.model.classes,
        train_batch: 0,
        eval_batch: 0,
        k_values: Vec::new(),
        optimizers: vec!["sgd".into(), "momentum".into(), "adam".into()],
        params,
        bn_state: Vec::new(),
        opt_state: BTreeMap::from([(opt.to_string(), opt_tensors)]),
        init_blob: BTreeMap::new(),
        eval_exe: String::new(),
        local_update: BTreeMap::new(),
    };
    Ok((StateLayout::new(&spec, opt)?, kind))
}

/// The native engine.  Stateless — every handle it hands out is a pure
/// function, so one instance serves any number of concurrent runners.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn validate(&self, cfg: &ExperimentConfig) -> Result<()> {
        let v = variant(&cfg.model)?;
        if v.model.image != cfg.dataset.image() {
            return Err(Error::Config(format!(
                "model {} expects {:?} images but dataset {} yields {:?}",
                cfg.model,
                v.model.image,
                cfg.dataset.name(),
                cfg.dataset.image()
            )));
        }
        if v.model.classes != cfg.dataset.classes() {
            return Err(Error::Config(format!(
                "model {} has {} classes but dataset {} has {}",
                cfg.model,
                v.model.classes,
                cfg.dataset.name(),
                cfg.dataset.classes()
            )));
        }
        // Surfaces the unsupported-optimizer error at construction.
        layout_for(&v, &cfg.optimizer)?;
        Ok(())
    }

    fn init_state(&self, variant_name: &str, opt: &str) -> Result<ModelState> {
        let v = variant(variant_name)?;
        let (layout, _) = layout_for(&v, opt)?;
        let mut state = ModelState::zeros(layout.clone());
        // Xavier-uniform weights, zero biases, zero optimizer state —
        // seeded by the variant name only, so the same model starts from
        // the same weights under every optimizer and config seed (the
        // blob-init behavior of the XLA path).
        let mut seed = INIT_SEED;
        for b in v.name.bytes() {
            seed = seed.wrapping_mul(0x100000001B3).wrapping_add(b as u64);
        }
        let mut rng = Rng::new(seed);
        for (i, t) in layout.tensors[..layout.n_params].iter().enumerate() {
            if t.shape.len() < 2 {
                continue; // biases stay zero
            }
            // Weight tensors: dense `[fan_in, fan_out]`, conv HWIO
            // `[kh, kw, cin, cout]` — fan-in is everything but the last
            // axis, fan-out the last, so the conv gets the receptive
            // -field-scaled Xavier limit.
            // lint:allow(unwrap-in-library): the `shape.len() < 2`
            // guard above means the shape has a last axis.
            let fan_out = *t.shape.last().unwrap();
            let fan_in: usize = t.shape[..t.shape.len() - 1].iter().product();
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
            let off = layout.offsets[i];
            for e in 0..t.nelems() {
                state.data[off + e] = rng.range(-limit, limit) as f32;
            }
        }
        Ok(state)
    }

    fn local_update(
        &self,
        variant_name: &str,
        opt: &str,
        k: usize,
        b: usize,
    ) -> Result<Box<dyn LocalUpdateHandle>> {
        let v = variant(variant_name)?;
        let (layout, kind) = layout_for(&v, opt)?;
        if k == 0 || b == 0 {
            return Err(Error::Config("K and batch size must be positive".into()));
        }
        Ok(Box::new(NativeLocalUpdate { layout, model: v.model, opt: kind, k, b }))
    }

    fn eval(&self, variant_name: &str, opt: &str) -> Result<Box<dyn EvalHandle>> {
        let v = variant(variant_name)?;
        let (layout, _) = layout_for(&v, opt)?;
        Ok(Box::new(NativeEval { layout, model: v.model }))
    }
}

/// K local optimizer steps for one client, on the batched kernel path.
struct NativeLocalUpdate {
    layout: Arc<StateLayout>,
    model: Model,
    opt: OptKind,
    k: usize,
    b: usize,
}

impl LocalUpdateHandle for NativeLocalUpdate {
    fn run(&self, state: &ModelState, batch: &Batch, lr: f32) -> Result<(ModelState, f32)> {
        let input = self.model.input();
        if batch.x.len() != self.k * self.b * input || batch.y.len() != self.k * self.b {
            return Err(Error::Data(format!(
                "batch shape mismatch: x={} y={} want x={} y={}",
                batch.x.len(),
                batch.y.len(),
                self.k * self.b * input,
                self.k * self.b
            )));
        }
        if state.layout.total != self.layout.total {
            return Err(Error::Config(format!(
                "state has {} elements, native layout expects {}",
                state.layout.total, self.layout.total
            )));
        }
        let n_params = self.model.param_elems();
        let mut new_state = state.clone();
        let mut grads = vec![0f32; n_params];
        let mut ws = Workspace::new(&self.model, self.b);
        let mut loss_sum = 0f32;
        for step in 0..self.k {
            let x = &batch.x[step * self.b * input..(step + 1) * self.b * input];
            let y = &batch.y[step * self.b..(step + 1) * self.b];
            grads.fill(0.0);
            loss_sum += models::loss_and_grads(
                &self.model,
                &new_state.data[..n_params],
                x,
                y,
                Some(&mut grads),
                &mut ws,
            );
            self.opt.apply(n_params, &mut new_state.data, &grads, lr);
        }
        Ok((new_state, loss_sum / self.k as f32))
    }
}

/// Whole-dataset evaluation (forward only), in fixed-size batched
/// chunks through the same kernels training uses.
struct NativeEval {
    layout: Arc<StateLayout>,
    model: Model,
}

impl EvalHandle for NativeEval {
    fn run_dataset(&self, state: &ModelState, ds: &Dataset) -> Result<(f64, f64)> {
        let input = self.model.input();
        let cls = self.model.classes;
        if ds.sample_len() != input {
            return Err(Error::Data(format!(
                "dataset samples have {} values, model expects {}",
                ds.sample_len(),
                input
            )));
        }
        if state.layout.total != self.layout.total {
            return Err(Error::Config(format!(
                "state has {} elements, native layout expects {}",
                state.layout.total, self.layout.total
            )));
        }
        let params = &state.data[..self.model.param_elems()];
        let n = ds.len();
        let mut ws = Workspace::new(&self.model, EVAL_CHUNK);
        let mut xbuf = vec![0f32; EVAL_CHUNK * input];
        let mut ybuf = vec![0i32; EVAL_CHUNK];
        let mut probs = vec![0f32; EVAL_CHUNK * cls];
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut i = 0;
        while i < n {
            let bt = EVAL_CHUNK.min(n - i);
            for r in 0..bt {
                xbuf[r * input..(r + 1) * input].copy_from_slice(ds.pixels(i + r));
                ybuf[r] = ds.label(i + r) as i32;
            }
            models::forward_into(&self.model, params, &xbuf[..bt * input], bt, &mut ws);
            let logits = ws.logits(bt, cls);
            loss_sum += kernels::softmax_xent_rows(
                logits,
                &ybuf[..bt],
                cls,
                &mut probs[..bt * cls],
            ) as f64;
            for (row, &yi) in logits.chunks_exact(cls).zip(&ybuf[..bt]) {
                let mut best = 0;
                for c in 1..cls {
                    if row[c] > row[best] {
                        best = c;
                    }
                }
                if best == yi as usize {
                    correct += 1.0;
                }
            }
            i += bt;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, ExperimentConfig};

    fn tiny_batch(model: &Model, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x = (0..b * model.input()).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let y = (0..b).map(|_| rng.below(model.classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn init_state_is_deterministic_and_shaped() {
        let b = NativeBackend::new();
        let a = b.init_state("fashion_mlp", "momentum").unwrap();
        let c = b.init_state("fashion_mlp", "momentum").unwrap();
        assert_eq!(a.data, c.data);
        let n = variant("fashion_mlp").unwrap().model.param_elems();
        assert_eq!(n, 28 * 28 * MLP_HIDDEN + MLP_HIDDEN + MLP_HIDDEN * 10 + 10);
        assert_eq!(a.layout.param_elems(), n);
        // momentum doubles the state (velocity mirrors the params)
        assert_eq!(a.layout.total, 2 * n);
        // sgd carries no optimizer state, same param init
        let s = b.init_state("fashion_mlp", "sgd").unwrap();
        assert_eq!(s.layout.total, n);
        assert_eq!(&a.data[..n], &s.data[..]);
        // adam appends two moment runs plus the scalar step counter,
        // again over the identical param init
        let ad = b.init_state("fashion_mlp", "adam").unwrap();
        assert_eq!(ad.layout.total, 3 * n + 1);
        assert_eq!(&ad.data[..n], &s.data[..]);
        // optimizer regions start at zero
        assert!(a.data[n..].iter().all(|&v| v == 0.0));
        assert!(ad.data[n..].iter().all(|&v| v == 0.0));
        // weights are initialized, biases zero
        assert!(a.data[..n].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn cnn_init_covers_conv_weights() {
        let b = NativeBackend::new();
        let s = b.init_state("fashion_cnn_slim_fast", "adam").unwrap();
        // conv_w is 4-D [3, 3, 1, 8]: the generalized Xavier init must
        // reach it (a 2-D-only init would leave the conv layer dead).
        let conv_w = s.tensor(0);
        assert_eq!(conv_w.len(), 3 * 3 * 1 * CNN_CHANNELS);
        assert!(conv_w.iter().any(|&v| v != 0.0), "conv weights initialized");
        // receptive-field Xavier limit: sqrt(6 / (9·cin + cout))
        let limit = (6.0f64 / (9.0 + CNN_CHANNELS as f64)).sqrt() as f32;
        assert!(conv_w.iter().all(|&v| v.abs() <= limit));
        // biases zero; layout is params + 2·params + 1 under adam
        let n = variant("fashion_cnn_slim_fast").unwrap().model.param_elems();
        assert_eq!(s.layout.total, 3 * n + 1);
        assert!(s.tensor(1).iter().all(|&v| v == 0.0), "conv_b zero");
    }

    #[test]
    fn momentum_first_step_matches_sgd_then_diverges() {
        let b = NativeBackend::new();
        let sgd = b.local_update("fashion_linear", "sgd", 1, 2).unwrap();
        let mom = b.local_update("fashion_linear", "momentum", 1, 2).unwrap();
        let s_sgd = b.init_state("fashion_linear", "sgd").unwrap();
        let s_mom = b.init_state("fashion_linear", "momentum").unwrap();
        let model = variant("fashion_linear").unwrap().model;
        let (x, y) = tiny_batch(&model, 2, 9);
        let batch = Batch { x, y };
        let (a1, _) = sgd.run(&s_sgd, &batch, 0.1).unwrap();
        let (b1, _) = mom.run(&s_mom, &batch, 0.1).unwrap();
        let n = model.param_elems();
        assert_eq!(&a1.data[..n], &b1.data[..n], "first step: v = g");
        let (a2, _) = sgd.run(&a1, &batch, 0.1).unwrap();
        let (b2, _) = mom.run(&b1, &batch, 0.1).unwrap();
        assert_ne!(&a2.data[..n], &b2.data[..n], "second step: momentum kicks in");
    }

    #[test]
    fn adam_local_update_moves_params_and_counter() {
        let b = NativeBackend::new();
        let lu = b.local_update("fashion_mlp", "adam", 2, 4).unwrap();
        let s = b.init_state("fashion_mlp", "adam").unwrap();
        let model = variant("fashion_mlp").unwrap().model;
        let (x, y) = tiny_batch(&model, 2 * 4, 11);
        let batch = Batch { x, y };
        let (out, loss) = lu.run(&s, &batch, 1e-3).unwrap();
        assert!(loss.is_finite());
        let n = model.param_elems();
        assert_ne!(&out.data[..n], &s.data[..n], "params moved");
        // K = 2 steps advanced the trailing scalar step counter to 2.
        assert_eq!(out.data[out.layout.total - 1], 2.0, "adam_t after K steps");
        // both moment runs picked up gradient mass
        assert!(out.data[n..2 * n].iter().any(|&v| v != 0.0), "first moments");
        assert!(out.data[2 * n..3 * n].iter().any(|&v| v != 0.0), "second moments");
    }

    #[test]
    fn cnn_local_update_trains_every_layer() {
        let b = NativeBackend::new();
        let lu = b.local_update("fashion_cnn_slim_fast", "sgd", 1, 4).unwrap();
        let s = b.init_state("fashion_cnn_slim_fast", "sgd").unwrap();
        let model = variant("fashion_cnn_slim_fast").unwrap().model;
        let (x, y) = tiny_batch(&model, 4, 13);
        let (out, loss) = lu.run(&s, &Batch { x, y }, 0.01).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // Gradient reached the conv block, not just the dense head.
        assert_ne!(out.tensor(0), s.tensor(0), "conv_w moved");
        assert_ne!(out.tensor(1), s.tensor(1), "conv_b moved");
        assert_ne!(out.tensor(4), s.tensor(4), "fc2_w moved");
    }

    #[test]
    fn local_update_validates_batch_shape() {
        let b = NativeBackend::new();
        let lu = b.local_update("fashion_linear", "sgd", 2, 4).unwrap();
        let s = b.init_state("fashion_linear", "sgd").unwrap();
        let bad = Batch { x: vec![0.0; 10], y: vec![0; 8] };
        assert!(lu.run(&s, &bad, 0.1).is_err());
    }

    #[test]
    fn unknown_variant_and_optimizer_are_typed_errors() {
        let b = NativeBackend::new();
        // the six-conv XLA artifact name is not a native variant
        assert!(b.init_state("fashion_cnn_slim", "sgd").is_err());
        assert!(b.init_state("fashion_mlp", "rmsprop").is_err());
        let mut cfg = ExperimentConfig {
            model: "fashion_cnn_slim_fast".into(),
            optimizer: "adam".into(),
            ..ExperimentConfig::default()
        };
        assert!(b.validate(&cfg).is_ok(), "CNN + adam is native now");
        cfg.dataset = DatasetKind::SynthCifar; // model stays fashion_*
        assert!(b.validate(&cfg).is_err());
    }

    #[test]
    fn eval_matches_between_chunked_and_exact_sizes() {
        // Accuracy/loss must not depend on how the dataset divides into
        // eval chunks: 100 samples spans a full chunk plus a partial.
        let b = NativeBackend::new();
        let ev = b.eval("fashion_mlp", "sgd").unwrap();
        let s = b.init_state("fashion_mlp", "sgd").unwrap();
        let mut ds = Dataset::new(28, 28, 1, 10);
        let mut rng = Rng::new(17);
        for i in 0..100u32 {
            let px: Vec<f32> =
                (0..28 * 28).map(|_| rng.range(0.0, 1.0) as f32).collect();
            ds.push(&px, i % 10);
        }
        let (loss, acc) = ev.run_dataset(&s, &ds).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        let (loss2, acc2) = ev.run_dataset(&s, &ds).unwrap();
        assert_eq!(loss.to_bits(), loss2.to_bits(), "eval is deterministic");
        assert_eq!(acc.to_bits(), acc2.to_bits());
    }

    #[test]
    fn cnn_eval_runs_on_its_image_shape() {
        let b = NativeBackend::new();
        let ev = b.eval("cifar_cnn_slim_fast", "adam").unwrap();
        let s = b.init_state("cifar_cnn_slim_fast", "adam").unwrap();
        let mut ds = Dataset::new(32, 32, 3, 10);
        let px = vec![0.5f32; 32 * 32 * 3];
        for cls in 0..10u32 {
            ds.push(&px, cls);
        }
        let (loss, acc) = ev.run_dataset(&s, &ds).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
        // shape mismatch is a typed error
        let wrong = Dataset::new(28, 28, 1, 10);
        assert!(ev.run_dataset(&s, &wrong).is_err());
    }
}
