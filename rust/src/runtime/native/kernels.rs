//! Batch-level compute kernels for the native engine.
//!
//! The engine's forward/backward rides these instead of per-sample
//! scalar loops: a minibatch becomes a matrix and every hot operation
//! is a blocked GEMM plus a handful of fused element-wise passes, so
//! the compiler autovectorizes contiguous inner loops and the
//! per-sample interpretation overhead disappears.  Everything is plain
//! safe Rust over row-major `&[f32]` slices with **fixed accumulation
//! order** — results are a pure function of the inputs, which the
//! engine's `(seed, client, round)` determinism and the workers=1≡N
//! bit-identity contract ride on.
//!
//! * [`gemm`] — `C += A·B`, register-tiled `MR`×`NR` micro-kernel with
//!   a contiguous-axpy edge path (the blocked/tiled design of the XLA
//!   side's Pallas matmul, shrunk to CPU register blocking).
//! * [`gemm_nt`] — `C += A·Bᵀ` (transposed-B, row-dot-row): pushes
//!   gradients back through a layer without materializing `Wᵀ`.
//! * [`gemm_tn`] — `C += Aᵀ·B` (transposed-A, rank-1 updates): the
//!   weight-gradient form `gW = Xᵀ·dY`.
//! * [`bias_act`] — fused bias-add + optional ReLU, one pass.
//! * [`im2col_3x3`] — 3×3 SAME patch extraction (NHWC), the conv
//!   lowering ported from the XLA path's `*_fast` variants: the
//!   convolution becomes `patches · W`, one big GEMM instead of a
//!   4-deep loop nest.
//! * [`maxpool2x2`] / [`maxpool2x2_backward`] — 2×2 stride-2 max-pool
//!   with recorded argmax for the backward scatter.
//! * [`softmax_xent_rows`] / [`finish_dlogits`] — row-wise stable
//!   softmax cross-entropy whose probability buffer doubles as the
//!   dlogits buffer.
//! * [`col_sums`] / [`relu_mask`] — bias gradients and the ReLU
//!   subgradient mask.

/// Micro-kernel tile height: rows of A accumulated per tile.
const MR: usize = 4;
/// Micro-kernel tile width: columns of B/C held in the accumulators.
const NR: usize = 16;

/// `C[m,n] += A[m,k] · B[k,n]` (row-major).
///
/// The interior is covered by an `MR`×`NR` register tile accumulated
/// across the whole `k` extent: per `k` step one contiguous `NR`-wide
/// segment of B is loaded once and reused by `MR` rows of A, so the
/// C-row load/store traffic of a naive axpy formulation drops by a
/// factor of `MR` and the accumulators never leave registers.  Edge
/// rows/columns fall back to the axpy form (still contiguous in B and
/// C).  For every output element the `k` products accumulate in
/// ascending order on both paths, so the result is a pure function of
/// the inputs.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let m_main = m - m % MR;
    let n_main = n - n % NR;
    let mut i0 = 0;
    while i0 < m_main {
        let mut j0 = 0;
        while j0 < n_main {
            let mut acc = [[0f32; NR]; MR];
            for kk in 0..k {
                let mut brow = [0f32; NR];
                brow.copy_from_slice(&b[kk * n + j0..kk * n + j0 + NR]);
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = a[(i0 + r) * k + kk];
                    for (t, &bv) in accr.iter_mut().zip(brow.iter()) {
                        *t += av * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let ci = (i0 + r) * n + j0;
                for (cv, &t) in c[ci..ci + NR].iter_mut().zip(accr.iter()) {
                    *cv += t;
                }
            }
            j0 += NR;
        }
        if j0 < n {
            gemm_axpy_block(i0, i0 + MR, j0, n, k, a, b, c);
        }
        i0 += MR;
    }
    if i0 < m {
        gemm_axpy_block(i0, m, 0, n, k, a, b, c);
    }
}

/// Contiguous-axpy edge path of [`gemm`]: rows `i0..i1`, columns
/// `j0..n` of C (`n` is the full row stride of B and C).  Zero A
/// entries — common after ReLU — skip their whole axpy row.
fn gemm_axpy_block(
    i0: usize,
    i1: usize,
    j0: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            // lint:allow(float-ordering): exact-zero sparsity skip —
            // a zero multiplier contributes nothing to the axpy, and
            // a tolerance would change the result bits.
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n + j0..kk * n + n];
            let crow = &mut c[i * n + j0..i * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// `C[m,n] += A[m,k] · Bᵀ` with `B` stored `[n,k]` row-major.
///
/// Row-dot-row: both operands stream contiguously, so the backward
/// pass's `dX = dY·Wᵀ` needs no transposed copy of the weights.
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (cv, brow) in crow.iter_mut().zip(b.chunks_exact(k)) {
            let mut t = 0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                t += x * y;
            }
            *cv += t;
        }
    }
}

/// `C[m,n] += Aᵀ · B` with `A` stored `[kd,m]` row-major (`kd` is the
/// contraction extent, typically the batch).
///
/// The weight-gradient form `gW = Xᵀ·dY` as `kd` rank-1 updates, each
/// row a contiguous axpy; zero A entries (ReLU-sparse activations)
/// skip theirs.
pub fn gemm_tn(kd: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), kd * m);
    debug_assert_eq!(b.len(), kd * n);
    debug_assert_eq!(c.len(), m * n);
    for kk in 0..kd {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            // lint:allow(float-ordering): exact-zero sparsity skip,
            // same as gemm_axpy_block above.
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Fused bias-add + optional ReLU over `y [rows, n]`, one pass.
pub fn bias_act(y: &mut [f32], rows: usize, n: usize, bias: &[f32], relu: bool) {
    debug_assert_eq!(y.len(), rows * n);
    debug_assert_eq!(bias.len(), n);
    for row in y.chunks_exact_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            let t = *v + bv;
            *v = if relu && t < 0.0 { 0.0 } else { t };
        }
    }
}

/// 3×3 SAME im2col over NHWC input: `x [b,h,w,c]` →
/// `patches [b*h*w, 9*c]`, zero padding outside the image.  Patch
/// columns are `(ky, kx, c)`-major, matching a `[3,3,c,f]` HWIO weight
/// tensor flattened to `[9c, f]` — convolution is then one
/// `patches · W` GEMM (the design of the XLA path's `*_fast`
/// variants).
pub fn im2col_3x3(
    x: &[f32],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    patches: &mut [f32],
) {
    debug_assert_eq!(x.len(), bsz * h * w * c);
    debug_assert_eq!(patches.len(), bsz * h * w * 9 * c);
    let pw = 9 * c;
    patches.fill(0.0);
    for bi in 0..bsz {
        let xb = &x[bi * h * w * c..(bi + 1) * h * w * c];
        let pb = &mut patches[bi * h * w * pw..(bi + 1) * h * w * pw];
        for y in 0..h {
            for ky in 0..3usize {
                // Source row is y + ky - 1; skip the padded rows.
                if y + ky < 1 || y + ky > h {
                    continue;
                }
                let sy = y + ky - 1;
                for xx in 0..w {
                    for kx in 0..3usize {
                        if xx + kx < 1 || xx + kx > w {
                            continue;
                        }
                        let sx = xx + kx - 1;
                        let src = (sy * w + sx) * c;
                        let dst = (y * w + xx) * pw + (ky * 3 + kx) * c;
                        pb[dst..dst + c].copy_from_slice(&xb[src..src + c]);
                    }
                }
            }
        }
    }
}

/// 2×2 stride-2 max-pool (floor semantics) over NHWC `x [b,h,w,c]` →
/// `out [b, h/2, w/2, c]`.  `arg` records each output's flat source
/// index in `x` for the backward scatter; ties pick the first window
/// element in (top-left, top-right, bottom-left, bottom-right) order,
/// so the pooling is a pure function of its input.
pub fn maxpool2x2(
    x: &[f32],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
    arg: &mut [u32],
) {
    let (ph, pw) = (h / 2, w / 2);
    debug_assert_eq!(x.len(), bsz * h * w * c);
    debug_assert_eq!(out.len(), bsz * ph * pw * c);
    debug_assert_eq!(arg.len(), out.len());
    for bi in 0..bsz {
        for oy in 0..ph {
            for ox in 0..pw {
                for ch in 0..c {
                    let base = ((bi * h + 2 * oy) * w + 2 * ox) * c + ch;
                    let mut best_idx = base;
                    let mut best = x[base];
                    for (dy, dx) in [(0usize, 1usize), (1, 0), (1, 1)] {
                        let idx = ((bi * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ch;
                        if x[idx] > best {
                            best = x[idx];
                            best_idx = idx;
                        }
                    }
                    let o = ((bi * ph + oy) * pw + ox) * c + ch;
                    out[o] = best;
                    arg[o] = best_idx as u32;
                }
            }
        }
    }
}

/// Backward of [`maxpool2x2`]: scatter `dout` into the recorded argmax
/// positions of `dx` (caller zeroes `dx`).
pub fn maxpool2x2_backward(dout: &[f32], arg: &[u32], dx: &mut [f32]) {
    debug_assert_eq!(dout.len(), arg.len());
    for (&d, &i) in dout.iter().zip(arg) {
        dx[i as usize] += d;
    }
}

/// Row-wise numerically-stable softmax cross-entropy over
/// `logits [rows, classes]`: writes the softmax probabilities into
/// `dlogits` (the first half of the gradient — [`finish_dlogits`]
/// turns them into `(p - onehot)/rows`) and returns the **summed**
/// loss over the rows.
pub fn softmax_xent_rows(
    logits: &[f32],
    y: &[i32],
    classes: usize,
    dlogits: &mut [f32],
) -> f32 {
    debug_assert_eq!(logits.len(), y.len() * classes);
    debug_assert_eq!(dlogits.len(), logits.len());
    let mut loss_sum = 0f32;
    for ((lrow, prow), &yi) in logits
        .chunks_exact(classes)
        .zip(dlogits.chunks_exact_mut(classes))
        .zip(y)
    {
        let mut mx = lrow[0];
        for &l in &lrow[1..] {
            if l > mx {
                mx = l;
            }
        }
        let mut z = 0f32;
        for (p, &l) in prow.iter_mut().zip(lrow) {
            let e = (l - mx).exp();
            *p = e;
            z += e;
        }
        for p in prow.iter_mut() {
            *p /= z;
        }
        loss_sum += mx + z.ln() - lrow[yi as usize];
    }
    loss_sum
}

/// Finish the loss gradient started by [`softmax_xent_rows`]:
/// `dlogits = (softmax - onehot(y)) / rows`.
pub fn finish_dlogits(dlogits: &mut [f32], y: &[i32], classes: usize) {
    debug_assert_eq!(dlogits.len(), y.len() * classes);
    let inv = 1.0 / y.len() as f32;
    for (prow, &yi) in dlogits.chunks_exact_mut(classes).zip(y) {
        prow[yi as usize] -= 1.0;
        for p in prow.iter_mut() {
            *p *= inv;
        }
    }
}

/// `out[j] += Σ_i d[i,j]` over `d [rows, n]` — bias gradients from a
/// gradient matrix, rows accumulated in ascending order.
pub fn col_sums(d: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    for row in d.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Zero gradient entries whose activation was clamped by ReLU
/// (post-activation value 0 ⇒ subgradient 0, matching the per-sample
/// path's convention).
pub fn relu_mask(d: &mut [f32], act: &[f32]) {
    debug_assert_eq!(d.len(), act.len());
    for (dv, &av) in d.iter_mut().zip(act) {
        if av <= 0.0 {
            *dv = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
    }

    /// Textbook triple loop, k innermost — the equivalence oracle.
    fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        for i in 0..m {
            for j in 0..n {
                let mut t = 0f32;
                for kk in 0..k {
                    t += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] += t;
            }
        }
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-4 + 1e-4 * b.abs()
    }

    #[test]
    fn gemm_matches_naive_on_random_shapes() {
        // Shapes straddling the tile boundaries: pure-tile, pure-edge,
        // and mixed interiors all agree with the naive triple loop.
        let shapes = [
            (1usize, 1usize, 1usize),
            (4, 7, 16),
            (5, 9, 17),
            (8, 3, 8),
            (13, 31, 29),
            (16, 64, 32),
            (3, 11, 10),
        ];
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            let a = randvec(m * k, 100 + si as u64);
            let b = randvec(k * n, 200 + si as u64);
            let mut c = vec![0f32; m * n];
            let mut c_ref = vec![0f32; m * n];
            gemm(m, k, n, &a, &b, &mut c);
            gemm_ref(m, k, n, &a, &b, &mut c_ref);
            for (i, (&x, &y)) in c.iter().zip(&c_ref).enumerate() {
                assert!(close(x, y), "{m}x{k}x{n} elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_accumulates_into_existing_c() {
        let (m, k, n) = (6usize, 5usize, 18usize);
        let a = randvec(m * k, 1);
        let b = randvec(k * n, 2);
        let base = randvec(m * n, 3);
        let mut c = base.clone();
        let mut c_ref = base.clone();
        gemm(m, k, n, &a, &b, &mut c);
        gemm_ref(m, k, n, &a, &b, &mut c_ref);
        for (&x, &y) in c.iter().zip(&c_ref) {
            assert!(close(x, y), "{x} vs {y}");
        }
    }

    #[test]
    fn transposed_variants_match_naive() {
        let (m, k, n) = (7usize, 12usize, 19usize);
        let a = randvec(m * k, 4);
        let bt = randvec(n * k, 5); // B stored [n, k]
        let mut c = vec![0f32; m * n];
        gemm_nt(m, k, n, &a, &bt, &mut c);
        for i in 0..m {
            for j in 0..n {
                let mut t = 0f32;
                for kk in 0..k {
                    t += a[i * k + kk] * bt[j * k + kk];
                }
                assert!(close(c[i * n + j], t), "nt {i},{j}");
            }
        }
        let kd = 9usize;
        let at = randvec(kd * m, 6); // A stored [kd, m]
        let b2 = randvec(kd * n, 7);
        let mut c2 = vec![0f32; m * n];
        gemm_tn(kd, m, n, &at, &b2, &mut c2);
        for i in 0..m {
            for j in 0..n {
                let mut t = 0f32;
                for kk in 0..kd {
                    t += at[kk * m + i] * b2[kk * n + j];
                }
                assert!(close(c2[i * n + j], t), "tn {i},{j}");
            }
        }
    }

    #[test]
    fn bias_act_adds_and_clamps() {
        let mut y = vec![-1.0f32, 2.0, -3.0, 4.0];
        bias_act(&mut y, 2, 2, &[0.5, -0.5], true);
        assert_eq!(y, vec![0.0, 1.5, 0.0, 3.5]);
        let mut y = vec![-1.0f32, 2.0];
        bias_act(&mut y, 1, 2, &[0.5, -0.5], false);
        assert_eq!(y, vec![-0.5, 1.5]);
    }

    #[test]
    fn im2col_center_and_corner_patches() {
        // 1x3x3x1 image with distinct values 1..9.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut patches = vec![0f32; 9 * 9];
        im2col_3x3(&x, 1, 3, 3, 1, &mut patches);
        // Center pixel (1,1): the full image in (ky, kx) order.
        assert_eq!(
            &patches[4 * 9..5 * 9],
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]
        );
        // Top-left corner (0,0): the first row/column taps are padding.
        assert_eq!(
            &patches[0..9],
            &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]
        );
        // Bottom-right corner (2,2): last row/column taps are padding.
        assert_eq!(
            &patches[8 * 9..9 * 9],
            &[5.0, 6.0, 0.0, 8.0, 9.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn im2col_keeps_channels_contiguous() {
        // 1x2x2x2 image: patch columns must be (ky, kx, c)-major.
        let x = vec![1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut patches = vec![0f32; 4 * 18];
        im2col_3x3(&x, 1, 2, 2, 2, &mut patches);
        // Pixel (0,0): center tap (ky=1, kx=1) holds its own channels.
        let p = &patches[0..18];
        assert_eq!(&p[(3 + 1) * 2..(3 + 1) * 2 + 2], &[1.0, 10.0]);
        // Right neighbor (ky=1, kx=2) holds pixel (0,1).
        assert_eq!(&p[(3 + 2) * 2..(3 + 2) * 2 + 2], &[2.0, 20.0]);
        // Below neighbor (ky=2, kx=1) holds pixel (1,0).
        assert_eq!(&p[(6 + 1) * 2..(6 + 1) * 2 + 2], &[3.0, 30.0]);
    }

    #[test]
    fn maxpool_picks_max_and_backward_scatters() {
        // 1x4x4x1, values arranged so each 2x2 window has a distinct max.
        #[rustfmt::skip]
        let x = vec![
            1.0f32, 5.0,  2.0, 1.0,
            3.0,    4.0,  8.0, 2.0,
            9.0,    0.0,  1.0, 1.0,
            2.0,    6.0,  3.0, 7.0,
        ];
        let mut out = vec![0f32; 4];
        let mut arg = vec![0u32; 4];
        maxpool2x2(&x, 1, 4, 4, 1, &mut out, &mut arg);
        assert_eq!(out, vec![5.0, 8.0, 9.0, 7.0]);
        assert_eq!(arg, vec![1, 6, 8, 15]);
        let mut dx = vec![0f32; 16];
        maxpool2x2_backward(&[1.0, 2.0, 3.0, 4.0], &arg, &mut dx);
        assert_eq!(dx[1], 1.0);
        assert_eq!(dx[6], 2.0);
        assert_eq!(dx[8], 3.0);
        assert_eq!(dx[15], 4.0);
        assert_eq!(dx.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn maxpool_ties_pick_first_window_element() {
        let x = vec![2.0f32, 2.0, 2.0, 2.0];
        let mut out = vec![0f32; 1];
        let mut arg = vec![9u32; 1];
        maxpool2x2(&x, 1, 2, 2, 1, &mut out, &mut arg);
        assert_eq!(out[0], 2.0);
        assert_eq!(arg[0], 0, "deterministic tie-break");
    }

    #[test]
    fn softmax_rows_match_scalar_reference() {
        let logits = randvec(4 * 5, 11);
        let y = vec![0i32, 2, 4, 1];
        let mut dl = vec![0f32; 20];
        let sum = softmax_xent_rows(&logits, &y, 5, &mut dl);
        // Scalar re-derivation per row.
        let mut expect = 0f64;
        for (r, &yi) in y.iter().enumerate() {
            let row = &logits[r * 5..(r + 1) * 5];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let z: f32 = row.iter().map(|&l| (l - mx).exp()).sum();
            expect += (mx + z.ln() - row[yi as usize]) as f64;
            for (j, &l) in row.iter().enumerate() {
                let p = (l - mx).exp() / z;
                assert!(close(dl[r * 5 + j], p), "prob {r},{j}");
            }
            // Each row's probabilities sum to 1.
            let ps: f32 = dl[r * 5..(r + 1) * 5].iter().sum();
            assert!((ps - 1.0).abs() < 1e-5);
        }
        assert!(close(sum, expect as f32));
        finish_dlogits(&mut dl, &y, 5);
        // Each finished row sums to 0 (probabilities minus one-hot).
        for r in 0..4 {
            let s: f32 = dl[r * 5..(r + 1) * 5].iter().sum();
            assert!(s.abs() < 1e-6, "row {r} sums to {s}");
        }
    }

    #[test]
    fn col_sums_and_relu_mask() {
        let d = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out = vec![0f32; 2];
        col_sums(&d, 2, &mut out);
        assert_eq!(out, vec![9.0, 12.0]);
        let mut g = vec![1.0f32, 1.0, 1.0];
        relu_mask(&mut g, &[0.5, 0.0, 2.0]);
        assert_eq!(g, vec![1.0, 0.0, 1.0]);
    }
}
