//! Flat f32 model state: params ++ BN stats ++ optimizer state.
//!
//! The state is the unit that migrates between base stations in EdgeFLow
//! and is averaged by [`crate::fl::aggregate`]; it round-trips to the
//! little-endian blob format `aot.py` writes (`*_init.bin`).

use std::sync::Arc;

use crate::runtime::manifest::{TensorSpec, VariantSpec};
use crate::util::error::{Error, Result};

/// Immutable layout shared by all states of one (variant, optimizer).
#[derive(Debug, Clone)]
pub struct StateLayout {
    pub tensors: Vec<TensorSpec>,
    /// Number of leading tensors that are trainable parameters.
    pub n_params: usize,
    /// Number of BN tensors following the params.
    pub n_bn: usize,
    /// Element offset of each tensor in the flat buffer.
    pub offsets: Vec<usize>,
    /// Total element count.
    pub total: usize,
}

impl StateLayout {
    pub fn new(variant: &VariantSpec, opt: &str) -> Result<Arc<StateLayout>> {
        let tensors = variant.state_layout(opt)?;
        let mut offsets = Vec::with_capacity(tensors.len());
        let mut total = 0usize;
        for t in &tensors {
            offsets.push(total);
            total += t.nelems();
        }
        Ok(Arc::new(StateLayout {
            n_params: variant.params.len(),
            n_bn: variant.bn_state.len(),
            tensors,
            offsets,
            total,
        }))
    }

    /// Element count of the trainable parameters only.
    pub fn param_elems(&self) -> usize {
        self.tensors[..self.n_params].iter().map(TensorSpec::nelems).sum()
    }
}

/// One model replica's full mutable state.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub layout: Arc<StateLayout>,
    /// Flat storage in layout order.
    pub data: Vec<f32>,
}

impl ModelState {
    /// Zero-initialized state.
    pub fn zeros(layout: Arc<StateLayout>) -> ModelState {
        let n = layout.total;
        ModelState { layout, data: vec![0.0; n] }
    }

    /// Load from a little-endian f32 blob (the `*_init.bin` format).
    pub fn from_blob(layout: Arc<StateLayout>, bytes: &[u8]) -> Result<ModelState> {
        if bytes.len() != layout.total * 4 {
            return Err(Error::Artifact(format!(
                "init blob is {} bytes, layout expects {}",
                bytes.len(),
                layout.total * 4
            )));
        }
        let data = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(ModelState { layout, data })
    }

    /// Serialize to the blob format (identity round-trip with
    /// [`Self::from_blob`]).
    pub fn to_blob(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// View of tensor `i`.
    pub fn tensor(&self, i: usize) -> &[f32] {
        let off = self.layout.offsets[i];
        &self.data[off..off + self.layout.tensors[i].nelems()]
    }

    /// Flat view of the trainable parameters (leading region).
    pub fn params_flat(&self) -> &[f32] {
        &self.data[..self.layout.param_elems()]
    }

    /// L2 norm of the trainable parameters (diagnostics / theory probes).
    pub fn param_l2(&self) -> f64 {
        self.params_flat().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Squared L2 distance between two states' parameters.
    pub fn param_dist2(&self, other: &ModelState) -> f64 {
        self.params_flat()
            .iter()
            .zip(other.params_flat())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum()
    }

    /// Bytes on the wire when this model's *parameters* are transferred
    /// (the paper's communication unit: parameter count x 4 bytes).
    pub fn param_bytes(&self) -> u64 {
        (self.layout.param_elems() * 4) as u64
    }

    /// Raw bytes of the **full state** that migrates — params plus the
    /// BN and optimizer regions that travel with them (momentum
    /// velocity, Adam moments).  Documents the wire contract: the
    /// runner feeds this element count to the codec
    /// (`codec.wire_bytes(layout.total)`), so this equals the actual
    /// wire charge only under [`crate::fl::compress::Codec::None`];
    /// equal to [`Self::param_bytes`] under plain SGD on a BN-free
    /// model.
    pub fn state_bytes(&self) -> u64 {
        (self.layout.total * 4) as u64
    }

    /// All NaN/Inf checks for failure injection tests.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn layout() -> Arc<StateLayout> {
        let variant = VariantSpec {
            name: "t".into(),
            arch: "mlp".into(),
            image: (2, 2, 1),
            classes: 2,
            train_batch: 4,
            eval_batch: 4,
            k_values: vec![1],
            optimizers: vec!["sgd".into()],
            params: vec![
                TensorSpec { name: "w".into(), shape: vec![4, 2] },
                TensorSpec { name: "b".into(), shape: vec![2] },
            ],
            bn_state: vec![TensorSpec { name: "m".into(), shape: vec![2] }],
            opt_state: BTreeMap::from([("sgd".to_string(), vec![])]),
            init_blob: BTreeMap::new(),
            eval_exe: "e".into(),
            local_update: BTreeMap::new(),
        };
        StateLayout::new(&variant, "sgd").unwrap()
    }

    #[test]
    fn layout_offsets() {
        let l = layout();
        assert_eq!(l.total, 12);
        assert_eq!(l.offsets, vec![0, 8, 10]);
        assert_eq!(l.param_elems(), 10);
        assert_eq!(l.n_params, 2);
        assert_eq!(l.n_bn, 1);
    }

    #[test]
    fn blob_roundtrip() {
        let l = layout();
        let mut s = ModelState::zeros(l.clone());
        for (i, v) in s.data.iter_mut().enumerate() {
            *v = i as f32 * 0.5 - 2.0;
        }
        let blob = s.to_blob();
        let s2 = ModelState::from_blob(l, &blob).unwrap();
        assert_eq!(s.data, s2.data);
    }

    #[test]
    fn blob_size_checked() {
        let l = layout();
        assert!(ModelState::from_blob(l, &[0u8; 7]).is_err());
    }

    #[test]
    fn tensor_views() {
        let l = layout();
        let mut s = ModelState::zeros(l);
        s.data[8] = 7.0;
        assert_eq!(s.tensor(1), &[7.0, 0.0]);
        assert_eq!(s.params_flat().len(), 10);
        assert_eq!(s.param_bytes(), 40);
        // the BN tensor rides the wire too
        assert_eq!(s.state_bytes(), 48);
    }

    #[test]
    fn norms_and_distances() {
        let l = layout();
        let mut a = ModelState::zeros(l.clone());
        let b = ModelState::zeros(l);
        a.data[0] = 3.0;
        a.data[1] = 4.0;
        assert!((a.param_l2() - 5.0).abs() < 1e-12);
        assert!((a.param_dist2(&b) - 25.0).abs() < 1e-12);
        assert!(a.is_finite());
        a.data[2] = f32::NAN;
        assert!(!a.is_finite());
    }
}
