//! XLA/PJRT execution engine.
//!
//! Wraps the `xla` crate (PJRT C API): loads HLO **text** artifacts
//! (`HloModuleProto::from_text_file` reassigns instruction ids, which is
//! what makes jax>=0.5 output loadable on xla_extension 0.5.1), compiles
//! them once per (variant, optimizer, K) on the CPU client, and executes
//! them with model state + gathered minibatches.
//!
//! Executions may run concurrently: the PJRT C API contract requires
//! clients, loaded executables and buffers to be usable from multiple
//! threads, and `runtime::pool` exploits that by giving every worker its
//! own `LocalUpdateExe` handle (shared `Arc` executable, private
//! per-execution buffers).  The compile cache is behind a `Mutex`, so a
//! cache miss raced by two workers compiles twice and keeps one copy —
//! wasteful but correct.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::config::ExperimentConfig;
use crate::data::dataset::{Batch, Dataset};
use crate::runtime::backend::{EvalHandle, LocalUpdateHandle, TrainBackend};
use crate::runtime::manifest::Manifest;
use crate::runtime::params::{ModelState, StateLayout};
use crate::util::error::{Error, Result};

/// Compiled local-update executable for one (variant, optimizer, K).
pub struct LocalUpdateExe {
    exe: Arc<xla::PjRtLoadedExecutable>,
    client: xla::PjRtClient,
    pub layout: Arc<StateLayout>,
    pub k: usize,
    pub b: usize,
    pub image: (usize, usize, usize),
}

/// Compiled evaluation executable for one variant.
pub struct EvalExe {
    exe: Arc<xla::PjRtLoadedExecutable>,
    client: xla::PjRtClient,
    pub layout: Arc<StateLayout>,
    pub b: usize,
    pub image: (usize, usize, usize),
    /// Tensors fed to eval: params ++ bn (no optimizer state).
    n_eval_tensors: usize,
}

/// The runtime engine: PJRT client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// `runtime::pool` shares the engine and per-worker executable handles
// across threads, so `Engine`/`LocalUpdateExe`/`EvalExe` must be
// Send + Sync.  We deliberately do NOT write `unsafe impl`s: the auto
// traits must come from the backend's own types, and this machine check
// turns "swap in a thread-unsafe xla binding" into a compile error
// instead of silent UB.  A binding whose client handle is a non-atomic
// `Rc` (as in some xla-rs vintages) fails here — wrap or fix it (the
// PJRT C API itself is thread-safe) before raising `workers` above 1.
fn _assert_backend_thread_safe() {
    #[allow(clippy::extra_unused_type_parameters)]
    fn check<T: Send + Sync>() {}
    check::<Engine>();
    check::<LocalUpdateExe>();
    check::<EvalExe>();
}

// Inputs go host->device through `buffer_from_host_buffer` + `execute_b`
// with buffers we own (and Drop).  The `execute::<Literal>` convenience
// path in the embedded xla_extension 0.5.1 leaks its per-argument device
// transfers (~14 MB per local_update; see EXPERIMENTS.md §Perf L3 #3) —
// do not reintroduce it on the round path.

fn f32_buffer(
    client: &xla::PjRtClient,
    dims: &[usize],
    data: &[f32],
) -> Result<xla::PjRtBuffer> {
    client.buffer_from_host_buffer(data, dims, None).map_err(Into::into)
}

fn i32_buffer(
    client: &xla::PjRtClient,
    dims: &[usize],
    data: &[i32],
) -> Result<xla::PjRtBuffer> {
    client.buffer_from_host_buffer(data, dims, None).map_err(Into::into)
}

impl Engine {
    /// Create the PJRT CPU client and parse the artifact manifest.
    pub fn load(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client up: platform={} devices={} ({} variants)",
            client.platform_name(),
            client.device_count(),
            manifest.variants.len()
        );
        Ok(Engine { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    fn compile_file(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // lint:allow(unwrap-in-library): lock poisoning means a panic
        // already unwound another worker — propagating the panic here
        // is the correct response, not a typed error.
        if let Some(hit) = self.cache.lock().unwrap().get(file) {
            return Ok(hit.clone());
        }
        let path = self.manifest.file(file);
        // lint:allow(transitive-wall-clock): compile timing is log-only
        // and never enters reports or simulated time.
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        log::debug!("compiled {} in {:.2?}", file, t.elapsed());
        // lint:allow(unwrap-in-library): same poisoned-lock policy as
        // the cache probe above.
        self.cache.lock().unwrap().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Initial model state for (variant, optimizer) from the init blob.
    pub fn init_state(&self, variant: &str, opt: &str) -> Result<ModelState> {
        let v = self.manifest.variant(variant)?;
        let layout = StateLayout::new(v, opt)?;
        let blob_name = v.init_blob.get(opt).ok_or_else(|| {
            Error::Artifact(format!("variant {variant} has no init blob for {opt}"))
        })?;
        let bytes = std::fs::read(self.manifest.file(blob_name))?;
        ModelState::from_blob(layout, &bytes)
    }

    /// Compile (and cache) the local-update executable.
    pub fn local_update(&self, variant: &str, opt: &str, k: usize) -> Result<LocalUpdateExe> {
        let v = self.manifest.variant(variant)?;
        let file = v.local_update_file(opt, k)?.to_string();
        Ok(LocalUpdateExe {
            exe: self.compile_file(&file)?,
            client: self.client.clone(),
            layout: StateLayout::new(v, opt)?,
            k,
            b: v.train_batch,
            image: v.image,
        })
    }

    /// Compile (and cache) the eval executable.
    pub fn eval(&self, variant: &str, opt: &str) -> Result<EvalExe> {
        let v = self.manifest.variant(variant)?;
        let layout = StateLayout::new(v, opt)?;
        let n_eval_tensors = layout.n_params + layout.n_bn;
        Ok(EvalExe {
            exe: self.compile_file(&v.eval_exe.clone())?,
            client: self.client.clone(),
            layout,
            b: v.eval_batch,
            image: v.image,
            n_eval_tensors,
        })
    }
}

impl LocalUpdateExe {
    /// Run K local steps: `state` + `[K, B, ...]` batches -> (new state,
    /// mean loss).  Matches the io_contract in the manifest.
    pub fn run(&self, state: &ModelState, batch: &Batch, lr: f32) -> Result<(ModelState, f32)> {
        let (h, w, c) = self.image;
        let expect_x = self.k * self.b * h * w * c;
        if batch.x.len() != expect_x || batch.y.len() != self.k * self.b {
            return Err(Error::Artifact(format!(
                "batch shape mismatch: x={} y={} want x={} y={}",
                batch.x.len(),
                batch.y.len(),
                expect_x,
                self.k * self.b
            )));
        }
        let layout = &state.layout;
        let mut inputs = Vec::with_capacity(layout.tensors.len() + 3);
        for (i, t) in layout.tensors.iter().enumerate() {
            inputs.push(f32_buffer(&self.client, &t.shape, state.tensor(i))?);
        }
        inputs.push(f32_buffer(&self.client, &[self.k, self.b, h, w, c], &batch.x)?);
        inputs.push(i32_buffer(&self.client, &[self.k, self.b], &batch.y)?);
        inputs.push(f32_buffer(&self.client, &[], &[lr])?);

        let result = self.exe.execute_b(&inputs)?[0][0].to_literal_sync()?;
        let outputs = result.to_tuple()?;
        let want = layout.tensors.len() + 1;
        if outputs.len() != want {
            return Err(Error::Artifact(format!(
                "local_update returned {} outputs, want {want}",
                outputs.len()
            )));
        }
        let mut new_state = ModelState::zeros(state.layout.clone());
        let (loss_out, param_outs) = outputs.split_last().ok_or_else(|| {
            Error::Artifact("local_update executable returned no outputs".into())
        })?;
        for (i, out) in param_outs.iter().enumerate() {
            let off = layout.offsets[i];
            let n = layout.tensors[i].nelems();
            let vals = out.to_vec::<f32>()?;
            if vals.len() != n {
                return Err(Error::Artifact(format!(
                    "output tensor {i} has {} elems, want {n}",
                    vals.len()
                )));
            }
            new_state.data[off..off + n].copy_from_slice(&vals);
        }
        let loss = loss_out.get_first_element::<f32>()?;
        Ok((new_state, loss))
    }
}

impl EvalExe {
    /// Evaluate one batch: returns (loss_sum, correct_count) over the
    /// first `real` rows (callers pad the final partial batch).
    pub fn run(&self, state: &ModelState, batch: &Batch) -> Result<(f32, f32)> {
        let (h, w, c) = self.image;
        if batch.y.len() != self.b || batch.x.len() != self.b * h * w * c {
            return Err(Error::Artifact(format!(
                "eval batch mismatch: got {} rows, executable wants {}",
                batch.y.len(),
                self.b
            )));
        }
        let mut inputs = Vec::with_capacity(self.n_eval_tensors + 2);
        for i in 0..self.n_eval_tensors {
            inputs.push(f32_buffer(&self.client, &self.layout.tensors[i].shape, state.tensor(i))?);
        }
        inputs.push(f32_buffer(&self.client, &[self.b, h, w, c], &batch.x)?);
        inputs.push(i32_buffer(&self.client, &[self.b], &batch.y)?);
        let result = self.exe.execute_b(&inputs)?[0][0].to_literal_sync()?;
        let (loss_sum, correct) = result.to_tuple2()?;
        Ok((
            loss_sum.get_first_element::<f32>()?,
            correct.get_first_element::<f32>()?,
        ))
    }

    /// Evaluate a whole dataset in fixed-size batches (padding the tail
    /// with repeats that are subtracted from the counts).
    pub fn run_dataset(&self, state: &ModelState, ds: &Dataset) -> Result<(f64, f64)> {
        let n = ds.len();
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut i = 0;
        while i < n {
            let hi = (i + self.b).min(n);
            let idx: Vec<usize> = (i..hi).collect();
            if idx.len() == self.b {
                let batch = ds.gather(&idx);
                let (l, c) = self.run(state, &batch)?;
                loss_sum += l as f64;
                correct += c as f64;
            } else {
                // Padded tail: evaluate padded batch, then subtract the
                // padding rows' contribution by evaluating them implicitly
                // via a second padded batch trick is overkill — instead
                // evaluate row-exactly using the padded batch and the known
                // pad row (last real sample repeated).
                let (batch, real) = ds.gather_padded(&idx, self.b);
                let (l_all, c_all) = self.run(state, &batch)?;
                // Padding rows are copies of the last real row; compute that
                // row's single-sample loss/correct by evaluating a batch of
                // just it (padded fully with itself).
                let last = vec![idx[idx.len() - 1]; 1];
                let (batch1, _) = ds.gather_padded(&last, self.b);
                let (l_one, c_one) = self.run(state, &batch1)?;
                let pad = (self.b - real) as f32;
                loss_sum += (l_all - l_one / self.b as f32 * pad) as f64;
                correct += (c_all - c_one / self.b as f32 * pad) as f64;
            }
            i = hi;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}

// ------------------------------------------------- backend trait glue
//
// The XLA engine is one implementation of the pluggable
// `runtime::backend` contract; `engine: native` is the other.  The
// inherent methods above keep their concrete return types (benches and
// diagnostics use them directly); the trait impl boxes them for the
// engine-agnostic round loop.

impl TrainBackend for Engine {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// Cross-validate a config against the artifact contract: the AOT
    /// executables bake in batch size, K and image shape.
    fn validate(&self, cfg: &ExperimentConfig) -> Result<()> {
        let variant = self.manifest.variant(&cfg.model)?;
        if variant.train_batch != cfg.batch_size {
            return Err(Error::Config(format!(
                "batch_size {} != artifact train batch {} for {}",
                cfg.batch_size, variant.train_batch, cfg.model
            )));
        }
        if !variant.k_values.contains(&cfg.local_steps) {
            return Err(Error::Config(format!(
                "K={} has no artifact for {} (available: {:?}) — extend \
                 BUILD_MATRIX in python/compile/aot.py",
                cfg.local_steps, cfg.model, variant.k_values
            )));
        }
        if variant.image != cfg.dataset.image() {
            return Err(Error::Config(format!(
                "model {} expects {:?} images but dataset {} yields {:?}",
                cfg.model,
                variant.image,
                cfg.dataset.name(),
                cfg.dataset.image()
            )));
        }
        Ok(())
    }

    fn init_state(&self, variant: &str, opt: &str) -> Result<ModelState> {
        Engine::init_state(self, variant, opt)
    }

    fn local_update(
        &self,
        variant: &str,
        opt: &str,
        k: usize,
        b: usize,
    ) -> Result<Box<dyn LocalUpdateHandle>> {
        let exe = Engine::local_update(self, variant, opt, k)?;
        if exe.b != b {
            return Err(Error::Config(format!(
                "artifact for {variant} trains batch {} but the config asks \
                 for {b}",
                exe.b
            )));
        }
        Ok(Box::new(exe))
    }

    fn eval(&self, variant: &str, opt: &str) -> Result<Box<dyn EvalHandle>> {
        Ok(Box::new(Engine::eval(self, variant, opt)?))
    }
}

impl LocalUpdateHandle for LocalUpdateExe {
    fn run(&self, state: &ModelState, batch: &Batch, lr: f32) -> Result<(ModelState, f32)> {
        LocalUpdateExe::run(self, state, batch, lr)
    }
}

impl EvalHandle for EvalExe {
    fn run_dataset(&self, state: &ModelState, ds: &Dataset) -> Result<(f64, f64)> {
        EvalExe::run_dataset(self, state, ds)
    }
}
