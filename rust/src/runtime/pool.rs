//! Scoped worker pool for parallel round execution.
//!
//! Fans a fixed job list out across OS threads with a shared atomic
//! cursor: worker `w` repeatedly claims the next unclaimed job index and
//! writes its result into that job's slot, so the caller always receives
//! results in **job order** regardless of how the scheduler interleaves
//! workers.  Combined with the fixed-order reduction in
//! [`crate::fl::aggregate`], every consumer of the pool is bit-identical
//! at any worker count — parallelism changes wall-clock time, never
//! results.
//!
//! The pool is deliberately unpooled: threads are spawned per [`WorkerPool::run`]
//! call via `std::thread::scope`.  Spawn cost (~tens of µs) is noise next
//! to the jobs this crate runs (XLA local updates are ~hundreds of ms),
//! and scoped threads let jobs borrow the caller's data (the shared
//! global model, the federation, per-worker executables) without `Arc`
//! plumbing or `'static` bounds.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::error::Result;

/// A fixed-width fan-out pool.  `workers == 1` degenerates to an inline
/// sequential loop (no threads, no synchronization) — the "sequential
/// path" other code compares against is literally this same code.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// `workers` threads; `0` means one per available core.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        WorkerPool { workers }
    }

    /// Resolved worker count (>= 1).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `n_jobs` jobs of `f(job_idx, worker_idx)`; returns results in
    /// job order.  `worker_idx` is in `0..workers()` and lets callers
    /// index per-worker resources (e.g. one `LocalUpdateExe` each).
    ///
    /// A panicking job propagates the panic to the caller (via
    /// `std::thread::scope`) after the remaining workers finish their
    /// current jobs.
    pub fn run<T, F>(&self, n_jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        if self.workers <= 1 || n_jobs <= 1 {
            return (0..n_jobs).map(|i| f(i, 0)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> =
            (0..n_jobs).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for w in 0..self.workers.min(n_jobs) {
                let next = &next;
                let slots = &slots;
                let f = &f;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_jobs {
                        break;
                    }
                    let out = f(i, w);
                    // lint:allow(unwrap-in-library): each slot is
                    // locked exactly once (job index i is claimed by
                    // one worker via fetch_add), so the lock cannot
                    // be poisoned or contended.
                    *slots[i].lock().unwrap() = Some(out);
                });
            }
        });
        slots
            .into_iter()
            // lint:allow(unwrap-in-library): a panicking job already
            // propagated through thread::scope before this line, so
            // every surviving slot is unpoisoned and filled.
            .map(|m| m.into_inner().unwrap().expect("pool job completed"))
            .collect()
    }

    /// [`Self::run`] wrapped in a wall-clock fan-out span: the whole
    /// dispatch (spawn, all jobs, join) is emitted as one `pool`-category
    /// span on the `main` lane with the job and worker counts attached.
    /// Per-job timing stays the caller's concern — jobs that want their
    /// own spans measure inside `f` and emit after the join so the event
    /// stream remains deterministic at any worker count.
    pub fn run_spanned<T, F>(
        &self,
        tracer: &crate::obs::Tracer,
        name: &str,
        n_jobs: usize,
        f: F,
    ) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        use crate::obs::TraceLevel;
        let mark = tracer.mark_if(TraceLevel::Phase);
        let out = self.run(n_jobs, f);
        tracer.span(
            TraceLevel::Phase,
            "pool",
            name,
            "main",
            mark,
            None,
            vec![
                ("jobs", n_jobs.into()),
                ("workers", self.workers.min(n_jobs.max(1)).into()),
            ],
        );
        out
    }

    /// [`Self::run`] for fallible jobs, with early cancel: once any job
    /// fails, jobs that have not started yet are skipped (workers
    /// already mid-job finish theirs).  The error surfaced is the first
    /// one **in job order among the jobs that actually ran** — with
    /// `workers == 1` that is exactly the first failure, like a plain
    /// `?` loop; with more workers a racing later failure may be the
    /// one reported when an earlier job was skipped.  Success results
    /// are complete and in job order either way.
    pub fn try_run<T, F>(&self, n_jobs: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, usize) -> Result<T> + Sync,
    {
        let failed = AtomicBool::new(false);
        let results = self.run(n_jobs, |i, w| {
            if failed.load(Ordering::Relaxed) {
                return None;
            }
            let r = f(i, w);
            if r.is_err() {
                failed.store(true, Ordering::Relaxed);
            }
            Some(r)
        });
        let mut out = Vec::with_capacity(n_jobs);
        let mut first_err = None;
        for r in results {
            match r {
                Some(Ok(t)) => out.push(t),
                Some(Err(e)) => {
                    first_err = Some(e);
                    break;
                }
                // A skipped slot implies some job recorded an Err; keep
                // walking to surface that real error, not a generic one.
                None => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::Error;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_come_back_in_job_order() {
        for workers in [1, 2, 4, 9] {
            let pool = WorkerPool::new(workers);
            let out = pool.run(23, |i, _w| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        let out = pool.run(100, |i, _w| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 100);
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn worker_index_stays_in_range() {
        let pool = WorkerPool::new(3);
        let seen = pool.run(50, |_i, w| w);
        assert!(seen.iter().all(|&w| w < 3));
    }

    #[test]
    fn zero_resolves_to_available_cores() {
        let pool = WorkerPool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn zero_jobs_is_fine() {
        let pool = WorkerPool::new(4);
        let out: Vec<usize> = pool.run(0, |i, _| i);
        assert!(out.is_empty());
    }

    #[test]
    fn try_run_reports_first_executed_failure() {
        // Sequentially the first failing index is reported exactly; in
        // parallel, cancellation may skip an earlier failing job, so any
        // of the injected errors is acceptable — but never a swallowed
        // or fabricated one.
        for workers in [1usize, 4] {
            let pool = WorkerPool::new(workers);
            let r: Result<Vec<usize>> = pool.try_run(10, |i, _w| {
                if i == 3 || i == 7 {
                    Err(Error::Data(format!("job {i}")))
                } else {
                    Ok(i)
                }
            });
            match r {
                Err(Error::Data(msg)) => {
                    if workers == 1 {
                        assert_eq!(msg, "job 3");
                    } else {
                        assert!(msg == "job 3" || msg == "job 7", "{msg}");
                    }
                }
                other => panic!("expected an injected error, got {other:?}"),
            }
        }
    }

    #[test]
    fn try_run_short_circuits_sequentially() {
        let pool = WorkerPool::new(1);
        let hits = AtomicUsize::new(0);
        let r: Result<Vec<usize>> = pool.try_run(10, |i, _w| {
            hits.fetch_add(1, Ordering::Relaxed);
            if i == 2 {
                Err(Error::Data("boom".into()))
            } else {
                Ok(i)
            }
        });
        assert!(r.is_err());
        assert_eq!(hits.load(Ordering::Relaxed), 3, "jobs after the failure ran");
    }

    #[test]
    fn run_spanned_emits_one_fanout_span() {
        use crate::obs::test_sink::MemSink;
        use crate::obs::{TraceLevel, Tracer};
        use std::sync::Arc;

        let sink = Arc::new(MemSink::default());
        let tracer =
            Tracer::with_sink(Box::new(sink.clone()), TraceLevel::Full, "t");
        let pool = WorkerPool::new(2);
        let out = pool.run_spanned(&tracer, "local_update", 5, |i, _w| i);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        let lines = sink.lines.lock().unwrap();
        let spans: Vec<_> = lines
            .iter()
            .filter(|j| j.get("ev").and_then(crate::util::json::Json::as_str) == Some("span"))
            .collect();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("cat").and_then(crate::util::json::Json::as_str), Some("pool"));
        assert_eq!(spans[0].get("name").and_then(crate::util::json::Json::as_str), Some("local_update"));
        let attrs = spans[0].get("attrs").expect("attrs");
        assert_eq!(attrs.get("jobs").and_then(crate::util::json::Json::as_u64), Some(5));
        assert_eq!(attrs.get("workers").and_then(crate::util::json::Json::as_u64), Some(2));
        drop(lines);
        // disabled tracer: same results, no events, no clock reads
        let off = Tracer::off();
        let out = pool.run_spanned(&off, "local_update", 3, |i, _w| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn parallel_equals_sequential_for_pure_jobs() {
        let f = |i: usize, _w: usize| (i as f64).sqrt().sin();
        let seq = WorkerPool::new(1).run(200, f);
        let par = WorkerPool::new(8).run(200, f);
        // Bit-identical: same jobs, same per-job computation, order
        // restored by slot index.
        assert_eq!(seq, par);
    }
}
