//! Pure-Rust in-process training engine (`engine: native`).
//!
//! A hand-written forward/backward trainer over the same flat
//! [`ModelState`]/[`StateLayout`] the XLA path uses, so everything
//! downstream — Eq. 3 aggregation, migration byte accounting,
//! checkpointing — is engine-agnostic.  Two architectures:
//!
//! * `*_linear` — multinomial logistic regression (`softmax(xW + b)`).
//! * `*_mlp` — one hidden ReLU layer (`softmax(relu(xW1 + b1)W2 + b2)`).
//!
//! Optimizers: plain SGD and heavy-ball momentum (`v = µv + g`,
//! `θ -= η·v`, µ = 0.9); the velocity rides in the state's optimizer
//! region so it migrates and checkpoints with the model, exactly like
//! the XLA path's Adam moments.
//!
//! Everything here is a pure function of its inputs: weight init is
//! seeded per variant, minibatches come from the loader's
//! `(seed, client, round)` stream, and no interior state survives a
//! call — so runs are deterministic in `(seed, client, round)` and
//! bit-identical at any worker count.  No artifacts, no Python, no
//! files: this is the engine CI trains with.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::dataset::{Batch, Dataset};
use crate::rng::Rng;
use crate::runtime::backend::{EvalHandle, LocalUpdateHandle, TrainBackend};
use crate::runtime::manifest::{TensorSpec, VariantSpec};
use crate::runtime::params::{ModelState, StateLayout};
use crate::util::error::{Error, Result};

/// Momentum coefficient for the `momentum` optimizer.
const MOMENTUM: f32 = 0.9;

/// Hidden width of the `*_mlp` variants.
const MLP_HIDDEN: usize = 64;

/// Seed for the deterministic weight init (mixed with the variant name).
const INIT_SEED: u64 = 0x9A71_BE11;

/// Architecture of a native variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Arch {
    /// Multinomial logistic regression: `w [in, classes], b [classes]`.
    Linear,
    /// One hidden ReLU layer:
    /// `w1 [in, hidden], b1 [hidden], w2 [hidden, classes], b2 [classes]`.
    Mlp { hidden: usize },
}

/// Shape summary of one variant (everything forward/backward needs).
#[derive(Debug, Clone, Copy)]
struct Dims {
    input: usize,
    /// 0 for the linear architecture.
    hidden: usize,
    classes: usize,
}

impl Dims {
    fn param_elems(&self) -> usize {
        if self.hidden == 0 {
            self.input * self.classes + self.classes
        } else {
            self.input * self.hidden
                + self.hidden
                + self.hidden * self.classes
                + self.classes
        }
    }
}

/// One entry of the built-in variant table.
#[derive(Debug, Clone)]
struct NativeVariant {
    name: &'static str,
    arch: Arch,
    image: (usize, usize, usize),
    classes: usize,
}

impl NativeVariant {
    fn dims(&self) -> Dims {
        let (h, w, c) = self.image;
        Dims {
            input: h * w * c,
            hidden: match self.arch {
                Arch::Linear => 0,
                Arch::Mlp { hidden } => hidden,
            },
            classes: self.classes,
        }
    }
}

/// The built-in model zoo.  `fashion_*` variants share the XLA manifest's
/// names so configs can flip `engine` without renaming models.
fn variant(name: &str) -> Result<NativeVariant> {
    let v = match name {
        "fashion_linear" => NativeVariant {
            name: "fashion_linear",
            arch: Arch::Linear,
            image: (28, 28, 1),
            classes: 10,
        },
        "fashion_mlp" => NativeVariant {
            name: "fashion_mlp",
            arch: Arch::Mlp { hidden: MLP_HIDDEN },
            image: (28, 28, 1),
            classes: 10,
        },
        "cifar_linear" => NativeVariant {
            name: "cifar_linear",
            arch: Arch::Linear,
            image: (32, 32, 3),
            classes: 10,
        },
        "cifar_mlp" => NativeVariant {
            name: "cifar_mlp",
            arch: Arch::Mlp { hidden: MLP_HIDDEN },
            image: (32, 32, 3),
            classes: 10,
        },
        other => {
            return Err(Error::Config(format!(
                "native engine has no model variant {other:?} (available: \
                 fashion_linear, fashion_mlp, cifar_linear, cifar_mlp)"
            )))
        }
    };
    Ok(v)
}

/// Parameter tensor list of a variant, in layout order.
fn param_tensors(v: &NativeVariant) -> Vec<TensorSpec> {
    let d = v.dims();
    match v.arch {
        Arch::Linear => vec![
            TensorSpec { name: "w".into(), shape: vec![d.input, d.classes] },
            TensorSpec { name: "b".into(), shape: vec![d.classes] },
        ],
        Arch::Mlp { hidden } => vec![
            TensorSpec { name: "w1".into(), shape: vec![d.input, hidden] },
            TensorSpec { name: "b1".into(), shape: vec![hidden] },
            TensorSpec { name: "w2".into(), shape: vec![hidden, d.classes] },
            TensorSpec { name: "b2".into(), shape: vec![d.classes] },
        ],
    }
}

/// Build the flat state layout (params ++ optimizer state) for
/// (variant, optimizer), reusing the manifest-side [`StateLayout`] so
/// blob I/O, aggregation and wire accounting need no native-specific
/// code.
fn layout_for(v: &NativeVariant, opt: &str) -> Result<Arc<StateLayout>> {
    let params = param_tensors(v);
    let opt_tensors: Vec<TensorSpec> = match opt {
        "sgd" => Vec::new(),
        "momentum" => params
            .iter()
            .map(|t| TensorSpec { name: format!("v_{}", t.name), shape: t.shape.clone() })
            .collect(),
        other => {
            return Err(Error::Config(format!(
                "native engine supports optimizer sgd|momentum, got {other:?} \
                 (adam is an XLA-engine artifact)"
            )))
        }
    };
    let (h, w, c) = v.image;
    let spec = VariantSpec {
        name: v.name.to_string(),
        arch: match v.arch {
            Arch::Linear => "linear".into(),
            Arch::Mlp { .. } => "mlp".into(),
        },
        image: (h, w, c),
        classes: v.classes,
        train_batch: 0,
        eval_batch: 0,
        k_values: Vec::new(),
        optimizers: vec!["sgd".into(), "momentum".into()],
        params,
        bn_state: Vec::new(),
        opt_state: BTreeMap::from([(opt.to_string(), opt_tensors)]),
        init_blob: BTreeMap::new(),
        eval_exe: String::new(),
        local_update: BTreeMap::new(),
    };
    StateLayout::new(&spec, opt)
}

/// The native engine.  Stateless — every handle it hands out is a pure
/// function, so one instance serves any number of concurrent runners.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn validate(&self, cfg: &ExperimentConfig) -> Result<()> {
        let v = variant(&cfg.model)?;
        if v.image != cfg.dataset.image() {
            return Err(Error::Config(format!(
                "model {} expects {:?} images but dataset {} yields {:?}",
                cfg.model,
                v.image,
                cfg.dataset.name(),
                cfg.dataset.image()
            )));
        }
        if v.classes != cfg.dataset.classes() {
            return Err(Error::Config(format!(
                "model {} has {} classes but dataset {} has {}",
                cfg.model,
                v.classes,
                cfg.dataset.name(),
                cfg.dataset.classes()
            )));
        }
        // Surfaces the unsupported-optimizer error at construction.
        layout_for(&v, &cfg.optimizer)?;
        Ok(())
    }

    fn init_state(&self, variant_name: &str, opt: &str) -> Result<ModelState> {
        let v = variant(variant_name)?;
        let layout = layout_for(&v, opt)?;
        let mut state = ModelState::zeros(layout.clone());
        // Xavier-uniform weights, zero biases, zero optimizer state —
        // seeded by the variant name only, so the same model starts from
        // the same weights under every optimizer and config seed (the
        // blob-init behavior of the XLA path).
        let mut seed = INIT_SEED;
        for b in v.name.bytes() {
            seed = seed.wrapping_mul(0x100000001B3).wrapping_add(b as u64);
        }
        let mut rng = Rng::new(seed);
        for (i, t) in layout.tensors[..layout.n_params].iter().enumerate() {
            if t.shape.len() != 2 {
                continue; // biases stay zero
            }
            let (fan_in, fan_out) = (t.shape[0], t.shape[1]);
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt();
            let off = layout.offsets[i];
            for e in 0..t.nelems() {
                state.data[off + e] = rng.range(-limit, limit) as f32;
            }
        }
        Ok(state)
    }

    fn local_update(
        &self,
        variant_name: &str,
        opt: &str,
        k: usize,
        b: usize,
    ) -> Result<Box<dyn LocalUpdateHandle>> {
        let v = variant(variant_name)?;
        let layout = layout_for(&v, opt)?;
        if k == 0 || b == 0 {
            return Err(Error::Config("K and batch size must be positive".into()));
        }
        Ok(Box::new(NativeLocalUpdate {
            layout,
            dims: v.dims(),
            momentum: opt == "momentum",
            k,
            b,
        }))
    }

    fn eval(&self, variant_name: &str, opt: &str) -> Result<Box<dyn EvalHandle>> {
        let v = variant(variant_name)?;
        Ok(Box::new(NativeEval { layout: layout_for(&v, opt)?, dims: v.dims() }))
    }
}

/// K local steps of SGD/momentum for one client.
struct NativeLocalUpdate {
    layout: Arc<StateLayout>,
    dims: Dims,
    momentum: bool,
    k: usize,
    b: usize,
}

impl LocalUpdateHandle for NativeLocalUpdate {
    fn run(&self, state: &ModelState, batch: &Batch, lr: f32) -> Result<(ModelState, f32)> {
        let d = &self.dims;
        if batch.x.len() != self.k * self.b * d.input || batch.y.len() != self.k * self.b {
            return Err(Error::Data(format!(
                "batch shape mismatch: x={} y={} want x={} y={}",
                batch.x.len(),
                batch.y.len(),
                self.k * self.b * d.input,
                self.k * self.b
            )));
        }
        if state.layout.total != self.layout.total {
            return Err(Error::Config(format!(
                "state has {} elements, native layout expects {}",
                state.layout.total, self.layout.total
            )));
        }
        let n_params = d.param_elems();
        let mut new_state = state.clone();
        let mut grads = vec![0f32; n_params];
        let mut loss_sum = 0f32;
        for step in 0..self.k {
            let x = &batch.x[step * self.b * d.input..(step + 1) * self.b * d.input];
            let y = &batch.y[step * self.b..(step + 1) * self.b];
            grads.fill(0.0);
            loss_sum +=
                loss_and_grads(d, &new_state.data[..n_params], x, y, Some(&mut grads));
            // Optimizer update.  Under momentum the velocity occupies the
            // state's optimizer region, element-aligned with the params
            // (same tensor list, same order).
            if self.momentum {
                let (params, velocity) = new_state.data.split_at_mut(n_params);
                for ((p, v), &g) in params.iter_mut().zip(velocity.iter_mut()).zip(&grads)
                {
                    *v = MOMENTUM * *v + g;
                    *p -= lr * *v;
                }
            } else {
                for (p, &g) in new_state.data[..n_params].iter_mut().zip(&grads) {
                    *p -= lr * g;
                }
            }
        }
        Ok((new_state, loss_sum / self.k as f32))
    }
}

/// Whole-dataset evaluation (forward only).
struct NativeEval {
    layout: Arc<StateLayout>,
    dims: Dims,
}

impl EvalHandle for NativeEval {
    fn run_dataset(&self, state: &ModelState, ds: &Dataset) -> Result<(f64, f64)> {
        let d = &self.dims;
        if ds.sample_len() != d.input {
            return Err(Error::Data(format!(
                "dataset samples have {} values, model expects {}",
                ds.sample_len(),
                d.input
            )));
        }
        if state.layout.total != self.layout.total {
            return Err(Error::Config(format!(
                "state has {} elements, native layout expects {}",
                state.layout.total, self.layout.total
            )));
        }
        let params = &state.data[..d.param_elems()];
        let mut hidden = vec![0f32; d.hidden];
        let mut logits = vec![0f32; d.classes];
        let mut probs = vec![0f32; d.classes];
        let n = ds.len();
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for i in 0..n {
            let y = ds.label(i) as usize;
            forward(d, params, ds.pixels(i), &mut hidden, &mut logits);
            loss_sum += softmax_xent(&logits, y, &mut probs) as f64;
            let mut best = 0;
            for c in 1..d.classes {
                if logits[c] > logits[best] {
                    best = c;
                }
            }
            if best == y {
                correct += 1.0;
            }
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}

// ---------------------------------------------------------------- math

/// Forward pass for one sample: fills `hidden` (MLP pre-activations get
/// ReLU'd in place; empty for linear) and `logits`.
fn forward(d: &Dims, params: &[f32], x: &[f32], hidden: &mut [f32], logits: &mut [f32]) {
    if d.hidden == 0 {
        let w = &params[..d.input * d.classes];
        let b = &params[d.input * d.classes..];
        logits.copy_from_slice(b);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &w[i * d.classes..(i + 1) * d.classes];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += xi * wv;
            }
        }
    } else {
        let (w1, rest) = params.split_at(d.input * d.hidden);
        let (b1, rest) = rest.split_at(d.hidden);
        let (w2, b2) = rest.split_at(d.hidden * d.classes);
        hidden.copy_from_slice(b1);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &w1[i * d.hidden..(i + 1) * d.hidden];
            for (h, &wv) in hidden.iter_mut().zip(row) {
                *h += xi * wv;
            }
        }
        for h in hidden.iter_mut() {
            if *h < 0.0 {
                *h = 0.0;
            }
        }
        logits.copy_from_slice(b2);
        for (j, &hj) in hidden.iter().enumerate() {
            if hj == 0.0 {
                continue;
            }
            let row = &w2[j * d.classes..(j + 1) * d.classes];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += hj * wv;
            }
        }
    }
}

/// Numerically-stable softmax cross-entropy for one sample: fills the
/// caller's `probs` scratch (same length as `logits` — it doubles as
/// the dlogits buffer in the backward pass, `p - onehot(y)`) and
/// returns the loss.  Caller-owned scratch keeps the per-sample hot
/// loop allocation-free.
fn softmax_xent(logits: &[f32], y: usize, probs: &mut [f32]) -> f32 {
    let mut m = logits[0];
    for &l in &logits[1..] {
        if l > m {
            m = l;
        }
    }
    let mut z = 0f32;
    for (p, &l) in probs.iter_mut().zip(logits) {
        let e = (l - m).exp();
        *p = e;
        z += e;
    }
    for p in probs.iter_mut() {
        *p /= z;
    }
    m + z.ln() - logits[y]
}

/// Mean loss over the minibatch; when `grads` is given, accumulates
/// `d(mean loss)/d(params)` into it (caller zeroes it).  `params` and
/// `grads` are the flat parameter region (no optimizer state).
fn loss_and_grads(
    d: &Dims,
    params: &[f32],
    x: &[f32],
    y: &[i32],
    mut grads: Option<&mut [f32]>,
) -> f32 {
    let batch = y.len();
    let inv_b = 1.0 / batch as f32;
    // Scratch hoisted out of the per-sample loop — the hot path never
    // allocates.
    let mut hidden = vec![0f32; d.hidden];
    let mut logits = vec![0f32; d.classes];
    let mut dlogits = vec![0f32; d.classes];
    let mut dh = vec![0f32; d.hidden];
    let mut loss_sum = 0f32;
    for s in 0..batch {
        let xs = &x[s * d.input..(s + 1) * d.input];
        let ys = y[s] as usize;
        forward(d, params, xs, &mut hidden, &mut logits);
        loss_sum += softmax_xent(&logits, ys, &mut dlogits);
        let Some(g) = grads.as_deref_mut() else { continue };
        dlogits[ys] -= 1.0;
        for dl in dlogits.iter_mut() {
            *dl *= inv_b;
        }
        if d.hidden == 0 {
            let (gw, gb) = g.split_at_mut(d.input * d.classes);
            for (i, &xi) in xs.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row = &mut gw[i * d.classes..(i + 1) * d.classes];
                for (gv, &dl) in row.iter_mut().zip(&dlogits) {
                    *gv += xi * dl;
                }
            }
            for (gv, &dl) in gb.iter_mut().zip(&dlogits) {
                *gv += dl;
            }
        } else {
            let (gw1, rest) = g.split_at_mut(d.input * d.hidden);
            let (gb1, rest) = rest.split_at_mut(d.hidden);
            let (gw2, gb2) = rest.split_at_mut(d.hidden * d.classes);
            let w2_off = d.input * d.hidden + d.hidden;
            let w2 = &params[w2_off..w2_off + d.hidden * d.classes];
            // dh = W2 · dlogits, masked by ReLU (hidden holds post-ReLU
            // activations; zero means the unit was clamped — its
            // pre-activation gradient is the subgradient 0).  dh is
            // reused across samples, so every entry is written each
            // pass, never left stale.
            for (j, &hj) in hidden.iter().enumerate() {
                let row = &w2[j * d.classes..(j + 1) * d.classes];
                let grow = &mut gw2[j * d.classes..(j + 1) * d.classes];
                let mut acc = 0f32;
                for ((gv, &wv), &dl) in grow.iter_mut().zip(row).zip(&dlogits) {
                    acc += wv * dl;
                    *gv += hj * dl;
                }
                dh[j] = if hj > 0.0 { acc } else { 0.0 };
            }
            for (gv, &dl) in gb2.iter_mut().zip(&dlogits) {
                *gv += dl;
            }
            for (i, &xi) in xs.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row = &mut gw1[i * d.hidden..(i + 1) * d.hidden];
                for (gv, &dhj) in row.iter_mut().zip(&dh) {
                    *gv += xi * dhj;
                }
            }
            for (gv, &dhj) in gb1.iter_mut().zip(&dh) {
                *gv += dhj;
            }
        }
    }
    loss_sum * inv_b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, ExperimentConfig};

    fn tiny_dims(hidden: usize) -> Dims {
        Dims { input: 4, hidden, classes: 3 }
    }

    fn seeded_params(d: &Dims, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d.param_elems()).map(|_| rng.range(-0.5, 0.5) as f32).collect()
    }

    fn tiny_batch(d: &Dims, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x = (0..b * d.input).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let y = (0..b).map(|_| rng.below(d.classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn gradients_match_finite_differences() {
        for hidden in [0usize, 5] {
            let d = tiny_dims(hidden);
            let params = seeded_params(&d, 1);
            let (x, y) = tiny_batch(&d, 3, 2);
            let mut grads = vec![0f32; d.param_elems()];
            loss_and_grads(&d, &params, &x, &y, Some(&mut grads));
            let eps = 2e-3f32;
            for i in 0..d.param_elems() {
                let mut plus = params.clone();
                plus[i] += eps;
                let mut minus = params.clone();
                minus[i] -= eps;
                let lp = loss_and_grads(&d, &plus, &x, &y, None);
                let lm = loss_and_grads(&d, &minus, &x, &y, None);
                let numeric = (lp - lm) / (2.0 * eps);
                assert!(
                    (numeric - grads[i]).abs() <= 1e-2 + 0.05 * grads[i].abs(),
                    "hidden={hidden} param {i}: numeric {numeric} vs analytic {}",
                    grads[i]
                );
            }
        }
    }

    #[test]
    fn repeated_steps_on_one_batch_strictly_decrease_loss() {
        for hidden in [0usize, 8] {
            let d = tiny_dims(hidden);
            let mut params = seeded_params(&d, 3);
            let (x, y) = tiny_batch(&d, 4, 4);
            let mut grads = vec![0f32; d.param_elems()];
            let mut last = f32::INFINITY;
            for _ in 0..10 {
                grads.fill(0.0);
                let loss = loss_and_grads(&d, &params, &x, &y, Some(&mut grads));
                assert!(loss < last, "hidden={hidden}: {loss} !< {last}");
                last = loss;
                for (p, g) in params.iter_mut().zip(&grads) {
                    *p -= 0.1 * g;
                }
            }
        }
    }

    #[test]
    fn init_state_is_deterministic_and_shaped() {
        let b = NativeBackend::new();
        let a = b.init_state("fashion_mlp", "momentum").unwrap();
        let c = b.init_state("fashion_mlp", "momentum").unwrap();
        assert_eq!(a.data, c.data);
        let d = Dims { input: 28 * 28, hidden: MLP_HIDDEN, classes: 10 };
        assert_eq!(a.layout.param_elems(), d.param_elems());
        // momentum doubles the state (velocity mirrors the params)
        assert_eq!(a.layout.total, 2 * d.param_elems());
        // sgd carries no optimizer state, same param init
        let s = b.init_state("fashion_mlp", "sgd").unwrap();
        assert_eq!(s.layout.total, d.param_elems());
        assert_eq!(&a.data[..d.param_elems()], &s.data[..]);
        // velocity starts at zero
        assert!(a.data[d.param_elems()..].iter().all(|&v| v == 0.0));
        // weights are initialized, biases zero
        assert!(a.data[..d.param_elems()].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn momentum_first_step_matches_sgd_then_diverges() {
        let b = NativeBackend::new();
        let sgd = b.local_update("fashion_linear", "sgd", 1, 2).unwrap();
        let mom = b.local_update("fashion_linear", "momentum", 1, 2).unwrap();
        let s_sgd = b.init_state("fashion_linear", "sgd").unwrap();
        let s_mom = b.init_state("fashion_linear", "momentum").unwrap();
        let d = Dims { input: 28 * 28, hidden: 0, classes: 10 };
        let (x, y) = tiny_batch(&d, 2, 9);
        let batch = Batch { x, y };
        let (a1, _) = sgd.run(&s_sgd, &batch, 0.1).unwrap();
        let (b1, _) = mom.run(&s_mom, &batch, 0.1).unwrap();
        let n = d.param_elems();
        assert_eq!(&a1.data[..n], &b1.data[..n], "first step: v = g");
        let (a2, _) = sgd.run(&a1, &batch, 0.1).unwrap();
        let (b2, _) = mom.run(&b1, &batch, 0.1).unwrap();
        assert_ne!(&a2.data[..n], &b2.data[..n], "second step: momentum kicks in");
    }

    #[test]
    fn local_update_validates_batch_shape() {
        let b = NativeBackend::new();
        let lu = b.local_update("fashion_linear", "sgd", 2, 4).unwrap();
        let s = b.init_state("fashion_linear", "sgd").unwrap();
        let bad = Batch { x: vec![0.0; 10], y: vec![0; 8] };
        assert!(lu.run(&s, &bad, 0.1).is_err());
    }

    #[test]
    fn unknown_variant_and_optimizer_are_typed_errors() {
        let b = NativeBackend::new();
        assert!(b.init_state("fashion_cnn_slim_fast", "sgd").is_err());
        assert!(b.init_state("fashion_mlp", "adam").is_err());
        let mut cfg = ExperimentConfig {
            model: "fashion_mlp".into(),
            optimizer: "momentum".into(),
            ..ExperimentConfig::default()
        };
        assert!(b.validate(&cfg).is_ok());
        cfg.optimizer = "adam".into();
        assert!(b.validate(&cfg).is_err());
        cfg.optimizer = "sgd".into();
        cfg.dataset = DatasetKind::SynthCifar; // model stays fashion_mlp
        assert!(b.validate(&cfg).is_err());
    }

    #[test]
    fn eval_counts_argmax_matches() {
        let b = NativeBackend::new();
        let ev = b.eval("fashion_linear", "sgd").unwrap();
        let s = b.init_state("fashion_linear", "sgd").unwrap();
        let mut ds = Dataset::new(28, 28, 1, 10);
        let px = vec![0.5f32; 28 * 28];
        for cls in 0..10u32 {
            ds.push(&px, cls);
        }
        let (loss, acc) = ev.run_dataset(&s, &ds).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
    }
}
