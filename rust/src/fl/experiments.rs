//! Paper-experiment suites: Table I, Fig 3(a/b), Fig 4 — shared by the
//! CLI subcommands and the bench targets so both regenerate identical
//! numbers.
//!
//! CPU-scale note (DESIGN.md §3): rounds/sample counts default far below
//! the paper's GPU budget; pass larger values to approach it.  All
//! *relative* orderings the paper reports are regenerated as-is.
//!
//! Independent cells (Table-I dataset×distribution×algorithm runs, Fig-3
//! sweep points, Fig-4 topologies) fan out across a [`WorkerPool`] when
//! [`SuiteOptions::workers`] > 1, sharing one
//! [`TrainBackend`] (under the XLA engine that shares one
//! compiled-executable cache; `SuiteOptions::engine = native` runs the
//! same suites artifact-free).  Cell results are collected in cell
//! order, so suite output is identical at any worker count.  The core
//! budget splits between the cell pool and the per-cell round loops via
//! [`split_budget`] ([`SuiteOptions::cell_workers`] threads inside each
//! cell, pool width shrunk to fit) — both layers reduce in fixed order,
//! so any split reproduces the same bits.
//!
//! Cells drive the stepwise session API directly — `step()` until done,
//! then `report()` — rather than the `run()` convenience loop, so suite
//! cells and any future per-round suite instrumentation share one code
//! path with external drivers.

use std::sync::Arc;

use crate::config::{
    Algorithm, DatasetKind, Distribution, EngineKind, ExperimentConfig, TopologyKind,
};
use crate::data::partition::build_federation;
use crate::fl::comm::{record_round, CommOptions};
use crate::fl::runner::{RunReport, Runner};
use crate::fl::strategy::Strategy;
use crate::netsim::NetSim;
use crate::runtime::backend::TrainBackend;
use crate::runtime::pool::WorkerPool;
use crate::topology::accounting::CommAccountant;
use crate::topology::builder::{build, TopologyParams};
use crate::topology::route::RouteTable;
use crate::util::error::Result;
use crate::util::table::{Align, Table};

/// Drive one experiment cell through the stepwise session API.  Shared
/// by the suites here and by [`crate::fl::campaign`], which fans its
/// grid over the same pool pattern.
pub fn run_cell(
    backend: &Arc<dyn TrainBackend>,
    cfg: ExperimentConfig,
) -> Result<RunReport> {
    let mut r = Runner::with_backend(backend.clone(), cfg)?;
    while !r.is_done() {
        r.step()?;
    }
    Ok(r.report())
}

/// [`run_cell`] with per-cell tracing: when `trace_dir` is non-empty the
/// cell's config is pointed at `<trace_dir>/<cell-name>.trace.jsonl` at
/// `trace_level` (each cell gets its own file, so concurrently-running
/// cells never interleave streams).  An empty `trace_dir` is exactly
/// `run_cell`.
pub fn run_cell_traced(
    backend: &Arc<dyn TrainBackend>,
    mut cfg: ExperimentConfig,
    trace_dir: &str,
    trace_level: &str,
) -> Result<RunReport> {
    if !trace_dir.is_empty() {
        std::fs::create_dir_all(trace_dir)?;
        cfg.trace = format!(
            "{}/{}.trace.jsonl",
            trace_dir.trim_end_matches('/'),
            cfg.name
        );
        // An unset level (e.g. a default-constructed options struct)
        // means the standard default verbosity.
        cfg.trace_level =
            if trace_level.is_empty() { "full".into() } else { trace_level.to_string() };
    }
    run_cell(backend, cfg)
}

/// Split a core budget between the cell pool and the per-cell round
/// pools: `(pool_workers, cell_workers)` with
/// `pool_workers * cell_workers <= budget` always.  `budget = 0` means
/// one per available core (the [`WorkerPool`] convention); `cell_workers
/// = 0` is normalized to 1 (sequential rounds inside each cell, the
/// historical suite behavior).  The per-cell width is clamped to the
/// budget first, then the pool takes whatever multiple still fits.
pub fn split_budget(budget: usize, cell_workers: usize) -> (usize, usize) {
    let total = if budget == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        budget
    };
    let per_cell = cell_workers.max(1).min(total);
    (total / per_cell, per_cell)
}

/// Scale knobs for the training suites.
#[derive(Debug, Clone)]
pub struct SuiteOptions {
    pub rounds: usize,
    pub samples_per_client: usize,
    pub test_samples: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub lr: f64,
    /// Core budget for the whole suite (0 = one per core, 1 = sequential).
    /// Split between the cell pool and per-cell round pools by
    /// [`split_budget`] with [`SuiteOptions::cell_workers`].
    pub workers: usize,
    /// Worker threads inside each cell's round loop (client fan-out).
    /// 0/1 = sequential cells, the historical default; the cell pool
    /// shrinks so `pool * cell_workers` never exceeds `workers`.
    pub cell_workers: usize,
    /// Which engine the cells train on; must match the backend handed to
    /// the suite functions (native cells support sgd|momentum|adam — pick
    /// an `optimizer`/`lr` pair suited to the trainer, e.g. `momentum` at
    /// lr ~0.01 or `adam` at the default 1e-3).
    pub engine: EngineKind,
    /// Optimizer override (None keeps the preset default, adam).
    pub optimizer: Option<String>,
    /// Batch size override (None keeps the preset default, 64).
    pub batch_size: Option<usize>,
    /// Per-cell trace output directory ("" = tracing off): each cell
    /// writes `<trace_dir>/<cell-name>.trace.jsonl`.
    pub trace_dir: String,
    /// Verbosity for cell traces (round | phase | full).
    pub trace_level: String,
}

impl Default for SuiteOptions {
    fn default() -> Self {
        SuiteOptions {
            rounds: 60,
            samples_per_client: 120,
            test_samples: 500,
            eval_every: 10,
            seed: 0,
            lr: 1e-3,
            workers: 1,
            cell_workers: 1,
            engine: EngineKind::Xla,
            optimizer: None,
            batch_size: None,
            trace_dir: String::new(),
            trace_level: "full".into(),
        }
    }
}

impl SuiteOptions {
    /// The resolved `(pool_workers, per_cell_workers)` split of this
    /// suite's core budget (see [`split_budget`]).
    pub fn budget(&self) -> (usize, usize) {
        split_budget(self.workers, self.cell_workers)
    }
}

fn model_for(ds: DatasetKind) -> &'static str {
    match ds {
        DatasetKind::SynthFashion => "fashion_mlp",
        DatasetKind::SynthCifar => "cifar_mlp",
    }
}

fn base_config(
    ds: DatasetKind,
    dist: Distribution,
    alg: Algorithm,
    o: &SuiteOptions,
) -> ExperimentConfig {
    let d = ExperimentConfig::default();
    ExperimentConfig {
        name: format!("{}_{}_{}", ds.name(), dist.name(), alg.name()),
        algorithm: alg,
        dataset: ds,
        distribution: dist,
        model: model_for(ds).into(),
        rounds: o.rounds,
        samples_per_client: o.samples_per_client,
        test_samples: o.test_samples,
        eval_every: o.eval_every,
        seed: o.seed,
        lr: o.lr,
        workers: o.budget().1,
        engine: o.engine,
        optimizer: o.optimizer.clone().unwrap_or_else(|| d.optimizer.clone()),
        batch_size: o.batch_size.unwrap_or(d.batch_size),
        ..d
    }
}

/// One Table-I cell result.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: DatasetKind,
    pub distribution: Distribution,
    pub algorithm: Algorithm,
    pub accuracy: f64,
    pub byte_hops: u64,
    pub report: RunReport,
}

/// Table I: accuracy of FedAvg / EdgeFLowRand / EdgeFLowSeq across
/// dataset x distribution cells (paper §IV.B).
pub fn table1(
    backend: &Arc<dyn TrainBackend>,
    o: &SuiteOptions,
    fast: bool,
) -> Result<(Table, Vec<Cell>)> {
    let cells: Vec<(DatasetKind, Distribution)> = if fast {
        vec![
            (DatasetKind::SynthFashion, Distribution::Iid),
            (DatasetKind::SynthFashion, Distribution::NiidA),
        ]
    } else {
        vec![
            (DatasetKind::SynthFashion, Distribution::Iid),
            (DatasetKind::SynthFashion, Distribution::NiidA),
            (DatasetKind::SynthCifar, Distribution::Iid),
            (DatasetKind::SynthCifar, Distribution::NiidA),
            (DatasetKind::SynthCifar, Distribution::NiidB),
        ]
    };
    let algs = [Algorithm::FedAvg, Algorithm::EdgeFlowRand, Algorithm::EdgeFlowSeq];
    let specs: Vec<(DatasetKind, Distribution, Algorithm)> = cells
        .iter()
        .flat_map(|(ds, dist)| algs.iter().map(|&alg| (*ds, dist.clone(), alg)))
        .collect();
    let pool = WorkerPool::new(o.budget().0);
    let reports = pool.try_run(specs.len(), |i, _w| {
        let (ds, dist, alg) = &specs[i];
        let cfg = base_config(*ds, dist.clone(), *alg, o);
        log::info!("table1 cell: {}", cfg.name);
        run_cell_traced(backend, cfg, &o.trace_dir, &o.trace_level)
    })?;
    let results: Vec<Cell> = specs
        .into_iter()
        .zip(reports)
        .map(|((dataset, distribution, algorithm), report)| Cell {
            dataset,
            distribution,
            algorithm,
            accuracy: report.final_accuracy,
            byte_hops: report.total_byte_hops,
            report,
        })
        .collect();
    // Render in the paper's layout: methods x (dataset, distribution).
    let mut header = vec!["Method".to_string()];
    for (ds, dist) in &cells {
        let d = match ds {
            DatasetKind::SynthFashion => "Fashion",
            DatasetKind::SynthCifar => "CIFAR",
        };
        header.push(format!("{d}/{}", dist.name()));
    }
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(&hdr_refs)
        .title("Table I — accuracy (%) [synthetic stand-in datasets]")
        .align(0, Align::Left);
    for alg in algs {
        let mut row = vec![alg.name().to_string()];
        for (ds, dist) in &cells {
            let cell = results
                .iter()
                .find(|c| {
                    c.algorithm == alg && c.dataset == *ds && c.distribution == *dist
                })
                // lint:allow(unwrap-in-library): results is built by
                // the same (alg, cell) cartesian loop a few lines up,
                // so every lookup key exists.
                .unwrap();
            row.push(format!("{:.2}", cell.accuracy * 100.0));
        }
        table.row(&row);
    }
    Ok((table, results))
}

/// Fig 3(a): EdgeFLowSeq under NIID B with varying cluster size N_m.
pub fn fig3a(
    backend: &Arc<dyn TrainBackend>,
    o: &SuiteOptions,
    cluster_sizes: &[usize],
) -> Result<Vec<(usize, RunReport)>> {
    for &n_m in cluster_sizes {
        assert!(100 % n_m == 0, "N_m must divide 100");
    }
    let pool = WorkerPool::new(o.budget().0);
    let reports = pool.try_run(cluster_sizes.len(), |i, _w| {
        let n_m = cluster_sizes[i];
        let mut cfg = base_config(
            DatasetKind::SynthCifar,
            Distribution::NiidB,
            Algorithm::EdgeFlowSeq,
            o,
        );
        cfg.clusters = 100 / n_m;
        cfg.name = format!("fig3a_nm{n_m}");
        log::info!("fig3a: N_m = {n_m}");
        run_cell_traced(backend, cfg, &o.trace_dir, &o.trace_level)
    })?;
    Ok(cluster_sizes.iter().copied().zip(reports).collect())
}

/// Fig 3(b): EdgeFLowSeq under NIID B with varying local epochs K.
pub fn fig3b(
    backend: &Arc<dyn TrainBackend>,
    o: &SuiteOptions,
    ks: &[usize],
) -> Result<Vec<(usize, RunReport)>> {
    let pool = WorkerPool::new(o.budget().0);
    let reports = pool.try_run(ks.len(), |i, _w| {
        let k = ks[i];
        let mut cfg = base_config(
            DatasetKind::SynthCifar,
            Distribution::NiidB,
            Algorithm::EdgeFlowSeq,
            o,
        );
        cfg.local_steps = k;
        cfg.name = format!("fig3b_k{k}");
        log::info!("fig3b: K = {k}");
        run_cell_traced(backend, cfg, &o.trace_dir, &o.trace_level)
    })?;
    Ok(ks.iter().copied().zip(reports).collect())
}

/// One Fig-4 bar: per-round communication load of an algorithm on a
/// topology (byte-hops averaged over `rounds`), plus DES latency.
#[derive(Debug, Clone)]
pub struct CommResult {
    pub topology: TopologyKind,
    pub algorithm: Algorithm,
    pub byte_hops_per_round: f64,
    /// EdgeFLow / FedAvg load ratio (the paper's compression ratio).
    pub vs_fedavg: f64,
    /// Mean simulated delivery latency of one round's transfers (s).
    pub round_latency_s: f64,
    /// Clients doing local work per round (HierFL trains all N clients
    /// per round while FedAvg/EdgeFLow train N_m — normalize with this
    /// for a per-participant comparison).
    pub participants_per_round: f64,
}

impl CommResult {
    /// Byte-hops per participating client per round.
    pub fn byte_hops_per_participant(&self) -> f64 {
        self.byte_hops_per_round / self.participants_per_round.max(1.0)
    }
}

/// Fig 4: communication load across the four network structures.
/// Pure coordination — no training, no engine.  The four topology cells
/// are independent and fan out across `workers` threads (results are
/// assembled in `TopologyKind::ALL` order either way).
pub fn fig4(
    param_count: usize,
    clusters: usize,
    clients_per_cluster: usize,
    rounds: usize,
    algorithms: &[Algorithm],
    seed: u64,
    workers: usize,
) -> Result<(Table, Vec<CommResult>)> {
    let model_bytes = (param_count * 4) as u64;
    let clients = clusters * clients_per_cluster;
    // A dummy federation provides cluster membership for planning (tiny
    // per-client sample counts keep it cheap; the data is never touched).
    let fed = build_federation(
        DatasetKind::SynthFashion,
        &Distribution::Iid,
        clients,
        clusters,
        10,
        10,
        seed,
    )?;

    let pool = WorkerPool::new(workers);
    let per_topo = pool.try_run(TopologyKind::ALL.len(), |ti, _w| {
        let kind = TopologyKind::ALL[ti];
        let topo = build(&TopologyParams::new(kind, clusters, clients_per_cluster))?;
        // Hop-count routes drive the accounting (the paper's metric is
        // hop-weighted); the DES rides bandwidth-aware transfer-time
        // routes sized to the model, like the runner — the two disagree
        // e.g. on the BS-ring shortcuts of the breadth structures.
        let routes = RouteTable::hops(&topo);
        let sim_routes = RouteTable::transfer_time(&topo, model_bytes);
        let mut per_alg: Vec<(Algorithm, f64, f64, f64)> = Vec::new();
        for &alg in algorithms {
            let cfg = ExperimentConfig {
                algorithm: alg,
                clients,
                clusters,
                samples_per_client: 64,
                seed,
                ..ExperimentConfig::default()
            };
            let mut strat = Strategy::for_config(&cfg, &fed, &topo, model_bytes);
            let mut acc = CommAccountant::new();
            let mut sim = NetSim::new(&topo);
            let mut t_submit = 0.0f64;
            let mut participants = 0usize;
            let mut outcomes = Vec::new();
            for t in 0..rounds {
                let plan = strat.plan_round(t, &fed, Some(&sim));
                participants += plan.participants().len();
                // Rounds are submitted 1 sim-second apart (or back-to-back
                // when a round overruns its slot — the clock is monotone)
                // and drained per round, so latency-aware probes measure
                // the network at the actual decision point rather than
                // racing round-0 traffic at time zero.
                let at = t_submit.max(sim.now_s());
                record_round(
                    &plan,
                    &topo,
                    &routes,
                    &mut acc,
                    model_bytes,
                    t,
                    CommOptions::default(),
                    Some((&mut sim, &sim_routes, at)),
                )?;
                outcomes.extend(sim.run());
                t_submit += 1.0;
            }
            let mean_lat = if outcomes.is_empty() {
                0.0
            } else {
                outcomes.iter().map(|o| o.latency_s()).sum::<f64>()
                    / outcomes.len() as f64
            };
            per_alg.push((
                alg,
                acc.byte_hops() as f64 / rounds as f64,
                mean_lat,
                participants as f64 / rounds as f64,
            ));
        }
        let fedavg_load = per_alg
            .iter()
            .find(|(a, ..)| *a == Algorithm::FedAvg)
            .map(|&(_, l, _, _)| l)
            .unwrap_or(f64::NAN);
        Ok(per_alg
            .into_iter()
            .map(|(alg, load, lat, parts)| CommResult {
                topology: kind,
                algorithm: alg,
                byte_hops_per_round: load,
                vs_fedavg: load / fedavg_load,
                round_latency_s: lat,
                participants_per_round: parts,
            })
            .collect::<Vec<CommResult>>())
    })?;
    let results: Vec<CommResult> = per_topo.into_iter().flatten().collect();

    let mut header = vec!["Topology".to_string()];
    for &alg in algorithms {
        header.push(alg.name().to_string());
        header.push(format!("{}/fedavg", alg.name()));
    }
    let refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(&refs)
        .title("Fig 4 — per-round communication load (byte-hops) and compression ratio")
        .align(0, Align::Left);
    for kind in TopologyKind::ALL {
        let mut row = vec![kind.name().to_string()];
        for &alg in algorithms {
            let r = results
                .iter()
                .find(|r| r.topology == kind && r.algorithm == alg)
                // lint:allow(unwrap-in-library): results is built by
                // the same (kind, alg) cartesian loop above, so every
                // lookup key exists.
                .unwrap();
            row.push(format!("{:.2e}", r.byte_hops_per_round));
            row.push(format!("{:.3}", r.vs_fedavg));
        }
        table.row(&row);
    }
    Ok((table, results))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_budget_never_exceeds_the_core_budget() {
        for budget in 1..=16usize {
            for cell in 0..=20usize {
                let (pool, per_cell) = split_budget(budget, cell);
                assert!(pool >= 1, "budget={budget} cell={cell}");
                assert!(per_cell >= 1, "budget={budget} cell={cell}");
                assert!(
                    pool * per_cell <= budget,
                    "budget={budget} cell={cell} -> pool={pool} per_cell={per_cell}"
                );
            }
        }
        // 0 = all cores resolves to a positive split too.
        let (pool, per_cell) = split_budget(0, 2);
        assert!(pool >= 1 && per_cell >= 1);
        // The historical default (cell_workers unset) keeps the whole
        // budget on the cell pool with sequential cells.
        assert_eq!(split_budget(8, 0), (8, 1));
        assert_eq!(split_budget(8, 1), (8, 1));
        // Splits divide the budget; an oversized per-cell ask is clamped.
        assert_eq!(split_budget(8, 2), (4, 2));
        assert_eq!(split_budget(4, 3), (1, 3));
        assert_eq!(split_budget(1, 4), (1, 1));
    }

    #[test]
    fn suite_options_budget_reaches_cell_configs() {
        let o = SuiteOptions { workers: 4, cell_workers: 2, ..SuiteOptions::default() };
        assert_eq!(o.budget(), (2, 2));
        let cfg = base_config(
            DatasetKind::SynthFashion,
            Distribution::Iid,
            Algorithm::EdgeFlowSeq,
            &o,
        );
        assert_eq!(cfg.workers, 2, "per-cell round loops get the split's width");
    }

    #[test]
    fn fig4_edgeflow_beats_fedavg_on_deep_topologies() {
        let algs = [Algorithm::FedAvg, Algorithm::HierFl, Algorithm::EdgeFlowSeq];
        let (_, results) = fig4(100_000, 10, 10, 40, &algs, 0, 1).unwrap();
        for kind in TopologyKind::ALL {
            let ratio = results
                .iter()
                .find(|r| r.topology == kind && r.algorithm == Algorithm::EdgeFlowSeq)
                .unwrap()
                .vs_fedavg;
            assert!(
                ratio < 1.0,
                "{kind:?}: EdgeFLow ratio {ratio} should be < 1"
            );
        }
        // Deeper structures give bigger savings: depth_linear's ratio is
        // the smallest of the four (the paper's depth-oriented claim).
        let ratio_of = |k: TopologyKind| {
            results
                .iter()
                .find(|r| r.topology == k && r.algorithm == Algorithm::EdgeFlowSeq)
                .unwrap()
                .vs_fedavg
        };
        assert!(ratio_of(TopologyKind::DepthLinear) < ratio_of(TopologyKind::Simple));
        assert!(ratio_of(TopologyKind::Hybrid) < ratio_of(TopologyKind::Simple));
    }

    #[test]
    fn fig4_savings_in_paper_band_for_deep_structures() {
        // §V claims 50-80% reduction; verify the deep/hybrid structures
        // land at >= 50% savings (ratio <= 0.5).
        let algs = [Algorithm::FedAvg, Algorithm::EdgeFlowSeq];
        let (_, results) = fig4(100_000, 10, 10, 40, &algs, 0, 1).unwrap();
        for kind in [TopologyKind::DepthLinear, TopologyKind::Hybrid, TopologyKind::BreadthParallel] {
            let r = results
                .iter()
                .find(|r| r.topology == kind && r.algorithm == Algorithm::EdgeFlowSeq)
                .unwrap();
            assert!(
                r.vs_fedavg <= 0.5,
                "{kind:?}: ratio {} above the paper's band",
                r.vs_fedavg
            );
        }
    }

    #[test]
    fn hierfl_wins_per_participant_on_deep_topologies() {
        // HierFL trains everyone each round, so raw load exceeds FedAvg;
        // per participating client it must be cheaper wherever BS->cloud
        // is more than one hop (edge aggregation amortizes the backbone).
        let algs = [Algorithm::FedAvg, Algorithm::HierFl];
        let (_, results) = fig4(100_000, 10, 10, 20, &algs, 0, 1).unwrap();
        for kind in [TopologyKind::DepthLinear, TopologyKind::BreadthParallel, TopologyKind::Hybrid] {
            let get = |alg| {
                results
                    .iter()
                    .find(|r| r.topology == kind && r.algorithm == alg)
                    .unwrap()
                    .byte_hops_per_participant()
            };
            assert!(
                get(Algorithm::HierFl) < get(Algorithm::FedAvg),
                "{kind:?}"
            );
        }
    }

    #[test]
    fn fig4_supports_latency_aware_schedule() {
        let algs = [Algorithm::FedAvg, Algorithm::EdgeFlowLatency];
        let (_, results) = fig4(50_000, 4, 4, 8, &algs, 0, 1).unwrap();
        for kind in TopologyKind::ALL {
            let r = results
                .iter()
                .find(|r| {
                    r.topology == kind
                        && r.algorithm == Algorithm::EdgeFlowLatency
                })
                .unwrap();
            assert!(r.byte_hops_per_round > 0.0);
            assert!(
                r.vs_fedavg < 1.0,
                "{kind:?}: latency-aware ratio {} should be < 1",
                r.vs_fedavg
            );
        }
    }

    #[test]
    fn fig4_latencies_positive() {
        let algs = [Algorithm::FedAvg, Algorithm::EdgeFlowSeq];
        let (_, results) = fig4(50_000, 4, 4, 10, &algs, 1, 1).unwrap();
        assert!(results.iter().all(|r| r.round_latency_s > 0.0));
    }
}
