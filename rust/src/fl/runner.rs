//! The experiment driver: wires data, topology, runtime and strategy into
//! the round loop of Algorithm 1.
//!
//! Local updates within a round fan out across a [`WorkerPool`]: each
//! worker owns one `LocalUpdateExe` handle and pulls `(group, client)`
//! jobs off a shared cursor.  Results are collected **in plan order** and
//! reduced with the fixed-order tree in [`crate::fl::aggregate`], so a
//! run's reports are bit-identical at any `workers` setting — the knob
//! changes wall-clock time, never numbers.

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::loader::ClientLoader;
use crate::data::partition::{build_federation, Federation};
use crate::fl::aggregate::par_reduce_states_weighted;
use crate::fl::comm::{record_round, CommOptions};
use crate::fl::strategy::Strategy;
use crate::metrics::{ExperimentMetrics, RoundRecord};
use crate::netsim::NetSim;
use crate::runtime::executor::{Engine, EvalExe, LocalUpdateExe};
use crate::runtime::params::ModelState;
use crate::runtime::pool::WorkerPool;
use crate::topology::accounting::CommAccountant;
use crate::topology::builder::{build, TopologyParams};
use crate::topology::graph::Topology;
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};
use crate::util::timer::Timer;

/// Result summary of one experiment run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub name: String,
    pub algorithm: &'static str,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    pub total_byte_hops: u64,
    pub rounds: usize,
    pub metrics: ExperimentMetrics,
    /// Wall-clock seconds by phase (train/aggregate/eval/comm).
    pub phase_seconds: Vec<(String, f64)>,
}

/// The experiment runner.
pub struct Runner {
    pub cfg: ExperimentConfig,
    engine: Arc<Engine>,
    pub fed: Federation,
    pub topo: Topology,
    strategy: Strategy,
    loader: ClientLoader,
    state: ModelState,
    /// One local-update executable per pool worker (all share the
    /// engine's compiled-executable cache); index 0 is the sequential
    /// path.
    lus: Vec<LocalUpdateExe>,
    ev: EvalExe,
    pool: WorkerPool,
    pub accountant: CommAccountant,
    /// Failure-injection stream (client dropout).
    dropout_rng: crate::rng::Rng,
    /// Persistent network DES: link state and the simulated clock carry
    /// across rounds, so `clock_s` accumulates into a simulated
    /// wall-clock.  Rounds are synchronous barriers (each drains before
    /// the next trains), so links are idle again at every round boundary
    /// — contention lives *within* a round; the carried state is the
    /// clock.  `NetSim::reset` restores round-zero semantics.
    net: NetSim,
}

impl Runner {
    /// Build a runner with a fresh PJRT engine.
    pub fn new(cfg: ExperimentConfig, artifacts_dir: &str) -> Result<Runner> {
        let engine = Arc::new(Engine::load(artifacts_dir)?);
        Runner::with_engine(engine, cfg)
    }

    /// Build a runner sharing an existing engine (compiled executables are
    /// cached per (variant, optimizer, K) across runs).
    pub fn with_engine(engine: Arc<Engine>, cfg: ExperimentConfig) -> Result<Runner> {
        let cfg = cfg.validate()?;
        let variant = engine.manifest.variant(&cfg.model)?;
        // Cross-validate config against the artifact contract.
        if variant.train_batch != cfg.batch_size {
            return Err(Error::Config(format!(
                "batch_size {} != artifact train batch {} for {}",
                cfg.batch_size, variant.train_batch, cfg.model
            )));
        }
        if !variant.k_values.contains(&cfg.local_steps) {
            return Err(Error::Config(format!(
                "K={} has no artifact for {} (available: {:?}) — extend \
                 BUILD_MATRIX in python/compile/aot.py",
                cfg.local_steps, cfg.model, variant.k_values
            )));
        }
        let (h, w, c) = variant.image;
        if (h, w, c) != cfg.dataset.image() {
            return Err(Error::Config(format!(
                "model {} expects {:?} images but dataset {} yields {:?}",
                cfg.model,
                variant.image,
                cfg.dataset.name(),
                cfg.dataset.image()
            )));
        }
        let fed = build_federation(
            cfg.dataset,
            &cfg.distribution,
            cfg.clients,
            cfg.clusters,
            cfg.samples_per_client,
            cfg.test_samples,
            cfg.seed,
        )?;
        let topo = build(&TopologyParams::new(
            cfg.topology,
            cfg.clusters,
            cfg.cluster_size(),
        ))?;
        let state = engine.init_state(&cfg.model, &cfg.optimizer)?;
        let strategy = Strategy::for_config(&cfg, &fed, &topo, state.param_bytes());
        let loader = ClientLoader::new(cfg.seed ^ LOADER_SEED_MIX, cfg.batch_size);
        let net = NetSim::new(&topo);
        let pool = WorkerPool::new(cfg.workers);
        let lus = (0..pool.workers())
            .map(|_| engine.local_update(&cfg.model, &cfg.optimizer, cfg.local_steps))
            .collect::<Result<Vec<_>>>()?;
        let ev = engine.eval(&cfg.model, &cfg.optimizer)?;
        let dropout_rng = crate::rng::Rng::new(cfg.seed ^ 0xD509_0A7);
        Ok(Runner {
            cfg,
            engine,
            fed,
            topo,
            strategy,
            loader,
            state,
            lus,
            ev,
            pool,
            accountant: CommAccountant::new(),
            dropout_rng,
            net,
        })
    }

    /// Current simulated network clock (cumulative across rounds).
    pub fn net_clock_s(&self) -> f64 {
        self.net.now_s()
    }

    /// Current global model state.
    pub fn state(&self) -> &ModelState {
        &self.state
    }

    /// The shared engine.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Evaluate the current global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let (loss, acc) = self.ev.run_dataset(&self.state, &self.fed.test)?;
        Ok((loss, acc))
    }

    /// Eq. 3 aggregation weight of one client: its actual train-set size
    /// `|D_n|` (clamped to 1 so a degenerate empty client cannot zero a
    /// whole round's weights).
    pub fn client_weight(&self, id: usize) -> f64 {
        self.fed.clients[id].samples.len().max(1) as f64
    }

    /// Run one client's local update against the current global state —
    /// exactly what a pool worker runs for this `(client, round)` job.
    /// Public for diagnostics and for tests that verify aggregation
    /// semantics against manually-composed expectations.
    pub fn local_update_for(&self, id: usize, round: usize) -> Result<(ModelState, f32)> {
        let batch = self.loader.local_batches(
            &self.fed.train,
            &self.fed.clients[id],
            round,
            self.cfg.local_steps,
        );
        self.lus[0].run(&self.state, &batch, self.cfg.lr as f32)
    }

    /// Run the full experiment.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut metrics = ExperimentMetrics::default();
        let mut timer = Timer::new();
        // Byte-hop accounting stays on hop-shortest routes (the paper's
        // load metric); the DES rides the latency-weighted routes its
        // contract documents — on diamond topologies the two disagree.
        let routes = RouteTable::hops(&self.topo);
        let sim_routes = RouteTable::latency(&self.topo);
        let model_bytes = self.state.param_bytes();
        let rounds = self.cfg.rounds;
        let deadline = self.cfg.deadline_s;

        for t in 0..rounds {
            timer.lap("idle");
            let mut plan = self.strategy.plan_round(t, &self.fed, Some(&self.net));

            // --- failure injection ---------------------------------------
            if self.cfg.dropout > 0.0 {
                let p = self.cfg.dropout;
                for (_m, members) in &mut plan.groups {
                    members.retain(|_| !self.dropout_rng.chance(p));
                }
                plan.groups.retain(|(_, v)| !v.is_empty());
                if plan.groups.is_empty() {
                    // Every selected client dropped: the round is lost; the
                    // model (and any scheduled migration) carries over, and
                    // nothing touches the network, so the persistent sim
                    // clock stays put.
                    log::debug!("round {t}: all participants dropped");
                    metrics.push(lost_round_record(
                        t,
                        plan.cluster,
                        0,
                        0.0,
                        self.net.now_s(),
                        Vec::new(),
                    ));
                    continue;
                }
            }

            // --- communication accounting + network simulation -----------
            // Simulated *before* the numeric work: delivery times decide
            // which uploads make the round's deadline, and stragglers must
            // be excluded from the Eq. 3 reduction below.  (The DES is
            // independent of the trained values, so the reordering cannot
            // change any report.)
            let round_start = self.net.now_s();
            let comm = record_round(
                &plan,
                &self.topo,
                &routes,
                &mut self.accountant,
                model_bytes,
                t,
                CommOptions::default(),
                Some((&mut self.net, &sim_routes, round_start)),
            )?;
            let byte_hops = comm.byte_hops;
            let outcomes = self.net.run();
            // The round's simulated network time is the makespan of its
            // transfers on the carried-forward network state.
            let net_s = outcomes
                .iter()
                .map(|o| o.delivered_s)
                .fold(round_start, f64::max)
                - round_start;
            let mut stragglers: Vec<usize> = Vec::new();
            if deadline > 0.0 {
                for &(client, sim_id) in &comm.uploads {
                    let late = outcomes
                        .iter()
                        .find(|o| o.id == sim_id)
                        .is_some_and(|o| o.delivered_s - round_start > deadline);
                    if late {
                        stragglers.push(client);
                    }
                }
                stragglers.sort_unstable();
                if !stragglers.is_empty() {
                    log::debug!(
                        "round {t}: {} stragglers past deadline_s={deadline}",
                        stragglers.len()
                    );
                    for (_m, members) in &mut plan.groups {
                        members.retain(|id| !stragglers.contains(id));
                    }
                    plan.groups.retain(|(_, v)| !v.is_empty());
                }
            }
            timer.lap("comm");

            if plan.groups.is_empty() {
                // Every surviving client straggled: the traffic was spent,
                // but nothing aggregates; the model carries over.
                metrics.push(lost_round_record(
                    t,
                    plan.cluster,
                    byte_hops,
                    net_s,
                    self.net.now_s(),
                    stragglers,
                ));
                continue;
            }

            // --- local updates (fanned out across the pool) --------------
            // Groups run one after another; members *within* a group fan
            // out across the pool and come back in member order, so the
            // loss vector and the reduction below see an identical
            // operand sequence at any worker count.  Per-group fan-out
            // also bounds peak memory at one group's states (HierFL's
            // full-participation rounds would otherwise hold every
            // client's state at once), and each group's partial is
            // reduced — by sample count, paper Eq. 3 — before the next
            // group trains.
            let mut losses = Vec::new();
            let mut group_states: Vec<(f64, ModelState)> =
                Vec::with_capacity(plan.groups.len());
            for (_m, members) in &plan.groups {
                let results: Vec<Result<(ModelState, f32)>> = {
                    let state = &self.state;
                    let loader = &self.loader;
                    let fed = &self.fed;
                    let lus = &self.lus;
                    let k = self.cfg.local_steps;
                    let lr = self.cfg.lr as f32;
                    self.pool.run(members.len(), move |i, w| {
                        let id = members[i];
                        let batch =
                            loader.local_batches(&fed.train, &fed.clients[id], t, k);
                        lus[w].run(state, &batch, lr)
                    })
                };
                let mut weighted = Vec::with_capacity(members.len());
                for (&id, r) in members.iter().zip(results) {
                    let (s, loss) = r?;
                    if !loss.is_finite() {
                        return Err(Error::Data(format!(
                            "non-finite loss at round {t} client {id} — \
                             lower the learning rate"
                        )));
                    }
                    losses.push(loss as f64);
                    weighted.push((self.client_weight(id), s));
                }
                group_states.push(par_reduce_states_weighted(weighted, &self.pool)?);
            }
            let train_s = timer.lap("train").as_secs_f64();

            // --- aggregation (Eq. 3) -------------------------------------
            // Each group partial carries its summed sample count, so the
            // cloud (or a multi-group edge plan) also aggregates per
            // Eq. 3 — not by contributing-group count, and never by
            // dropping surplus groups.  An empty plan is a typed error.
            if group_states.is_empty() {
                return Err(Error::Data(format!(
                    "round {t}: aggregation plan has no surviving groups"
                )));
            }
            let (_total_w, merged) =
                par_reduce_states_weighted(group_states, &self.pool)?;
            self.state = merged;
            let aggregate_s = timer.lap("aggregate").as_secs_f64();

            // --- evaluation -----------------------------------------------
            let eval_now = t + 1 == rounds
                || (self.cfg.eval_every > 0 && (t + 1) % self.cfg.eval_every == 0);
            let (test_loss, test_acc) = if eval_now {
                let (l, a) = self.evaluate()?;
                (l, a)
            } else {
                (f64::NAN, f64::NAN)
            };
            let _ = timer.lap("eval");

            let train_loss =
                losses.iter().sum::<f64>() / losses.len().max(1) as f64;
            if eval_now {
                log::info!(
                    "[{}] round {t:>4} cluster {:>3} loss {train_loss:.4} \
                     acc {:.4} ({} byte-hops)",
                    self.strategy.name(),
                    plan_cluster_label(plan.cluster),
                    test_acc,
                    byte_hops
                );
            }
            metrics.push(RoundRecord {
                round: t,
                cluster: plan.cluster,
                train_loss,
                test_accuracy: test_acc,
                test_loss,
                comm_byte_hops: byte_hops,
                train_s,
                aggregate_s,
                net_s,
                clock_s: self.net.now_s(),
                stragglers,
            });
        }

        let final_loss = metrics
            .rounds
            .last()
            .map(|r| r.train_loss)
            .unwrap_or(f64::NAN);
        Ok(RunReport {
            name: self.cfg.name.clone(),
            algorithm: self.strategy.name(),
            final_accuracy: metrics.final_accuracy(),
            best_accuracy: metrics.best_accuracy(),
            final_loss,
            total_byte_hops: metrics.total_byte_hops(),
            rounds,
            metrics,
            phase_seconds: timer.laps(),
        })
    }
}

fn plan_cluster_label(m: usize) -> String {
    if m == usize::MAX {
        "-".to_string()
    } else {
        m.to_string()
    }
}

/// Carry-over record for a round that trained nothing (all participants
/// dropped, or every survivor straggled past the deadline): NaN losses,
/// whatever traffic/clock the round did spend, and the model unchanged.
fn lost_round_record(
    round: usize,
    cluster: usize,
    comm_byte_hops: u64,
    net_s: f64,
    clock_s: f64,
    stragglers: Vec<usize>,
) -> RoundRecord {
    RoundRecord {
        round,
        cluster,
        train_loss: f64::NAN,
        test_accuracy: f64::NAN,
        test_loss: f64::NAN,
        comm_byte_hops,
        train_s: 0.0,
        aggregate_s: 0.0,
        net_s,
        clock_s,
        stragglers,
    }
}

/// Seed-mixing constant separating the loader's stream from the
/// partitioner's and the strategies'.
const LOADER_SEED_MIX: u64 = 0x10AD_E2B6;
