//! The experiment driver: wires data, topology, runtime and strategy into
//! the round loop of Algorithm 1 — exposed as a **stepwise round
//! session**.
//!
//! [`Runner::step`] executes exactly one round (plan → communicate →
//! train → aggregate → migrate) and returns a typed
//! [`RoundOutcome`]; [`Runner::run`] is nothing but a thin loop over
//! `step()` that any caller can reimplement.  Around the session:
//!
//! * [`RoundObserver`]s hook the phases of each round and can request
//!   early stop / deadline changes through [`RoundControl`] (see
//!   [`crate::fl::session`]); progress logging is the built-in
//!   [`crate::fl::session::ProgressObserver`].
//! * [`Runner::checkpoint`] / [`Runner::restore`] serialize the whole
//!   session — model state, the persistent [`NetSim`] clock, every RNG
//!   stream, the scheduler cursor, accumulated metrics and pending
//!   deferred updates — such that a run checkpointed at round T and
//!   resumed is **bit-identical** to the uninterrupted run (wall-clock
//!   phase timings excepted, by nature).
//! * `straggler_policy = defer` re-includes stragglers: a late update is
//!   held in the session's [`crate::fl::session::DeferredPool`] and
//!   folded, with its Eq. 3 sample weight, into the next round's
//!   reduction instead of being discarded.
//!
//! The engine behind the round loop is pluggable
//! ([`crate::runtime::backend::TrainBackend`], selected by
//! `cfg.engine: xla|native`): the runner only ever talks to
//! `LocalUpdateHandle`/`EvalHandle` objects, so the XLA artifact path
//! and the pure-Rust native trainer drive identical sessions.
//!
//! Local updates within a round fan out across a [`WorkerPool`]: each
//! worker owns one local-update handle and pulls `(group, client)`
//! jobs off a shared cursor.  Results are collected **in plan order** and
//! reduced with the fixed-order tree in [`crate::fl::aggregate`], so a
//! run's reports are bit-identical at any `workers` setting — the knob
//! changes wall-clock time, never numbers.

use std::sync::Arc;

use crate::config::{ExperimentConfig, StragglerPolicy};
use crate::data::loader::ClientLoader;
use crate::data::partition::{build_federation, Federation};
use crate::fl::aggregate::par_reduce_states_weighted;
use crate::fl::comm::{record_round, CommOptions};
use crate::fl::session::{
    DeferredPool, DeferredUpdate, LostCause, ProgressObserver, RoundControl,
    RoundObserver, RoundOutcome,
};
use crate::fl::strategy::{AggregationSite, Strategy};
use crate::metrics::{ExperimentMetrics, RoundRecord};
use crate::netsim::{NetSim, NetSimState};
use crate::obs::{MetricsRegistry, PhaseTimer, TraceLevel, Tracer, WallMark};
use crate::rng::{Rng, RngState};
use crate::runtime::backend::{
    backend_for, EvalHandle, LocalUpdateHandle, TrainBackend,
};
use crate::runtime::params::ModelState;
use crate::runtime::pool::WorkerPool;
use crate::topology::accounting::CommAccountant;
use crate::topology::builder::{build, TopologyParams};
use crate::topology::graph::Topology;
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::{bytes_from_hex, bytes_to_hex, f64_from_hex, f64_to_hex};

/// Result summary of one experiment run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub name: String,
    pub algorithm: &'static str,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    /// Last *finite* per-round training loss (a final round lost to
    /// dropout/stragglers must not turn this NaN).
    pub final_loss: f64,
    pub total_byte_hops: u64,
    /// Rounds actually executed (== configured rounds unless an observer
    /// stopped the session early).
    pub rounds: usize,
    pub metrics: ExperimentMetrics,
    /// Wall-clock seconds by phase (train/aggregate/eval/comm) — this
    /// process's work only; timings do not survive checkpoint/resume.
    pub phase_seconds: Vec<(String, f64)>,
}

/// The experiment runner: a stepwise round session over Algorithm 1.
pub struct Runner {
    pub cfg: ExperimentConfig,
    backend: Arc<dyn TrainBackend>,
    pub fed: Federation,
    pub topo: Topology,
    strategy: Strategy,
    loader: ClientLoader,
    state: ModelState,
    /// One local-update handle per pool worker (the XLA engine shares
    /// its compiled-executable cache behind them); index 0 is the
    /// sequential path.
    lus: Vec<Box<dyn LocalUpdateHandle>>,
    ev: Box<dyn EvalHandle>,
    pool: WorkerPool,
    pub accountant: CommAccountant,
    /// Failure-injection stream (client dropout).
    dropout_rng: Rng,
    /// Persistent network DES: link state and the simulated clock carry
    /// across rounds, so `clock_s` accumulates into a simulated
    /// wall-clock.  Rounds are synchronous barriers (each drains before
    /// the next trains), so links are idle again at every round boundary
    /// — contention lives *within* a round; the carried state is the
    /// clock.  `NetSim::reset` restores round-zero semantics.
    net: NetSim,
    // ------------------------------------------------- session state
    /// Next round to execute (== rounds executed so far, counting any
    /// restored history).
    cursor: usize,
    /// Set by an observer's stop request; `is_done()` honors it.
    stopped: bool,
    /// Active round deadline in simulated seconds (0 = off).  Starts at
    /// `cfg.deadline_s`; observers may adjust it per round.
    deadline_s: f64,
    /// Per-round records accumulated across `step()` calls (and restored
    /// by `restore()`).
    metrics: ExperimentMetrics,
    /// Phase laps, folded into the trace: one measurement feeds both
    /// `phase_seconds` and the emitted phase spans.
    timer: PhaseTimer,
    /// Structured trace destination (`cfg.trace`; no-op when empty).
    tracer: Tracer,
    /// Deterministic logical counters/histograms — worker-count- and
    /// wall-clock-free by construction, so registry snapshots are
    /// bit-identical across `--workers` settings.
    reg: MetricsRegistry,
    /// Straggler re-inclusion pool (`straggler_policy = defer`).
    deferred: DeferredPool,
    observers: Vec<Box<dyn RoundObserver>>,
}

impl Runner {
    /// Build a runner with a fresh engine of the kind the config selects
    /// (`engine: xla|native`); `artifacts_dir` is only read by the XLA
    /// path.
    pub fn new(cfg: ExperimentConfig, artifacts_dir: &str) -> Result<Runner> {
        // with_backend validates; `cfg.engine` needs no validation to
        // pick the backend.
        let backend = backend_for(&cfg, artifacts_dir)?;
        Runner::with_backend(backend, cfg)
    }

    /// Build a runner sharing an existing backend (the XLA engine caches
    /// compiled executables per (variant, optimizer, K) across runs; the
    /// native engine is stateless).
    pub fn with_backend(
        backend: Arc<dyn TrainBackend>,
        cfg: ExperimentConfig,
    ) -> Result<Runner> {
        let cfg = cfg.validate()?;
        // Cross-validate config against the engine's model contract (the
        // XLA path checks the artifact manifest; native its variant
        // table).
        backend.validate(&cfg)?;
        let fed = build_federation(
            cfg.dataset,
            &cfg.distribution,
            cfg.clients,
            cfg.clusters,
            cfg.samples_per_client,
            cfg.test_samples,
            cfg.seed,
        )?;
        let topo = build(&TopologyParams::new(
            cfg.topology,
            cfg.clusters,
            cfg.cluster_size(),
        ))?;
        let state = backend.init_state(&cfg.model, &cfg.optimizer)?;
        // The latency-aware schedule's probes ride the same codec wire
        // bytes the round accounting charges.  What moves on the wire is
        // the *full* state (`layout.total`): optimizer state — momentum
        // velocity, Adam moments — and BN statistics deliberately
        // migrate/aggregate with the params, so they are paid for too
        // (under plain SGD the two counts coincide).
        let wire_bytes = cfg.codec.wire_bytes(state.layout.total);
        let strategy = Strategy::for_config(&cfg, &fed, &topo, wire_bytes);
        let loader = ClientLoader::new(cfg.seed ^ LOADER_SEED_MIX, cfg.batch_size);
        let net = NetSim::new(&topo);
        let pool = WorkerPool::new(cfg.workers);
        let lus = (0..pool.workers())
            .map(|_| {
                backend.local_update(
                    &cfg.model,
                    &cfg.optimizer,
                    cfg.local_steps,
                    cfg.batch_size,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let ev = backend.eval(&cfg.model, &cfg.optimizer)?;
        let dropout_rng = Rng::new(cfg.seed ^ 0xD509_0A7);
        let observers: Vec<Box<dyn RoundObserver>> =
            vec![Box::new(ProgressObserver::new(strategy.name()))];
        let deadline_s = cfg.deadline_s;
        let tracer = Tracer::from_config(&cfg.trace, &cfg.trace_level, &cfg.name)?;
        let timer = PhaseTimer::new(tracer.clone());
        Ok(Runner {
            cfg,
            backend,
            fed,
            topo,
            strategy,
            loader,
            state,
            lus,
            ev,
            pool,
            accountant: CommAccountant::new(),
            dropout_rng,
            net,
            cursor: 0,
            stopped: false,
            deadline_s,
            metrics: ExperimentMetrics::default(),
            timer,
            tracer,
            reg: MetricsRegistry::default(),
            deferred: DeferredPool::default(),
            observers,
        })
    }

    /// Build a runner sharing an existing backend.  Alias of
    /// [`Runner::with_backend`], kept under the XLA-era name — an
    /// `Arc<Engine>` coerces to `Arc<dyn TrainBackend>` at the call
    /// site, so existing callers read unchanged.
    pub fn with_engine(
        engine: Arc<dyn TrainBackend>,
        cfg: ExperimentConfig,
    ) -> Result<Runner> {
        Runner::with_backend(engine, cfg)
    }

    /// Current simulated network clock (cumulative across rounds).
    pub fn net_clock_s(&self) -> f64 {
        self.net.now_s()
    }

    /// Current global model state.
    pub fn state(&self) -> &ModelState {
        &self.state
    }

    /// The shared backend.
    pub fn backend(&self) -> &Arc<dyn TrainBackend> {
        &self.backend
    }

    /// Metrics accumulated so far (every executed round's record).
    pub fn metrics(&self) -> &ExperimentMetrics {
        &self.metrics
    }

    /// The session's tracer (disabled unless `cfg.trace` names a path).
    /// Observers and drivers clone it to emit into the same stream.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Next round index (== rounds executed so far).
    pub fn round(&self) -> usize {
        self.cursor
    }

    /// True once every configured round ran or an observer stopped the
    /// session.
    pub fn is_done(&self) -> bool {
        self.stopped || self.cursor >= self.cfg.rounds
    }

    /// Clients with a pending deferred late update (straggler
    /// re-inclusion), ascending.
    pub fn pending_deferrals(&self) -> Vec<usize> {
        self.deferred.clients()
    }

    /// Attach an observer; hooks fire in attachment order, after the
    /// built-in progress logger.
    pub fn add_observer(&mut self, observer: Box<dyn RoundObserver>) {
        self.observers.push(observer);
    }

    /// Evaluate the current global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let (loss, acc) = self.ev.run_dataset(&self.state, &self.fed.test)?;
        Ok((loss, acc))
    }

    /// Eq. 3 aggregation weight of one client: its actual train-set size
    /// `|D_n|` (clamped to 1 so a degenerate empty client cannot zero a
    /// whole round's weights).
    pub fn client_weight(&self, id: usize) -> f64 {
        self.fed.clients[id].samples.len().max(1) as f64
    }

    /// Run one client's local update against the current global state —
    /// exactly what a pool worker runs for this `(client, round)` job.
    /// Public for diagnostics and for tests that verify aggregation
    /// semantics against manually-composed expectations.
    pub fn local_update_for(&self, id: usize, round: usize) -> Result<(ModelState, f32)> {
        let batch = self.loader.local_batches(
            &self.fed.train,
            &self.fed.clients[id],
            round,
            self.cfg.local_steps,
        );
        self.lus[0].run(&self.state, &batch, self.cfg.lr as f32)
    }

    /// Execute exactly one round — the session's unit of progress — and
    /// return its typed outcome.  Errors once the session [`is
    /// done`](Runner::is_done).
    pub fn step(&mut self) -> Result<RoundOutcome> {
        if self.is_done() {
            return Err(Error::Config(format!(
                "round session is complete after {} rounds — step() has \
                 nothing left to execute",
                self.cursor
            )));
        }
        let t = self.cursor;
        self.timer.set_round(t);
        let round_mark = self.tracer.mark_if(TraceLevel::Round);
        self.timer.lap("idle");
        // Every model transfer this round — migrations, uploads,
        // downlinks, deferred folds — is charged the codec's wire size
        // of the **full state** (`layout.total`, params *and* the
        // optimizer/BN regions that migrate with them: momentum velocity
        // and Adam moments ride in the state by design, so they cost
        // wire too), and the DES sizes its transfers the same way, so
        // compressed runs report compressed byte-hops and transfer
        // times.  The payload itself stays lossless: the codec shrinks
        // the accounting, never the numbers.
        let model_bytes =
            self.cfg.codec.wire_bytes(self.state.layout.total);

        let mut plan = self.strategy.plan_round(t, &self.fed, Some(&self.net));
        self.notify(|o, ctl| o.on_plan(t, &plan, ctl));

        // --- failure injection ---------------------------------------
        if self.cfg.dropout > 0.0 {
            let p = self.cfg.dropout;
            for (_m, members) in &mut plan.groups {
                members.retain(|_| !self.dropout_rng.chance(p));
            }
            plan.groups.retain(|(_, v)| !v.is_empty());
            if plan.groups.is_empty() {
                // Every selected client dropped: the round is lost; the
                // model (and any scheduled migration) carries over, and
                // nothing touches the network, so the persistent sim
                // clock stays put.
                log::debug!("round {t}: all participants dropped");
                let record = lost_round_record(
                    t,
                    plan.cluster,
                    0,
                    0.0,
                    self.net.now_s(),
                    Vec::new(),
                );
                return self.finish(
                    round_mark,
                    RoundOutcome::Lost { record, cause: LostCause::AllDropped },
                );
            }
        }

        // --- communication accounting + network simulation -----------
        // Simulated *before* the numeric work: delivery times decide
        // which uploads make the round's deadline, and stragglers must
        // be excluded from the Eq. 3 reduction below.  (The DES is
        // independent of the trained values, so the reordering cannot
        // change any report.)
        // Byte-hop accounting stays on hop-shortest routes (the paper's
        // load metric); the DES rides bandwidth-aware transfer-time
        // routes sized to the migrating model, so bulk transfers stop
        // preferring thin low-latency links.  (Both tables borrow the
        // topology and are rebuilt where needed — construction is O(1),
        // and holding them across the observer hooks would pin `self`.)
        let routes = RouteTable::hops(&self.topo);
        let sim_routes = RouteTable::transfer_time(&self.topo, model_bytes);
        let round_start = self.net.now_s();
        let comm = record_round(
            &plan,
            &self.topo,
            &routes,
            &mut self.accountant,
            model_bytes,
            t,
            CommOptions::default(),
            Some((&mut self.net, &sim_routes, round_start)),
        )?;
        let mut byte_hops = comm.byte_hops;
        let outcomes = self.net.run_traced(&self.tracer);
        // The round's simulated network time is the makespan of its
        // transfers on the carried-forward network state.
        let net_s = outcomes
            .iter()
            .map(|o| o.delivered_s)
            .fold(round_start, f64::max)
            - round_start;
        let deadline = self.deadline_s;
        let mut stragglers: Vec<usize> = Vec::new();
        let mut late_ids: Vec<usize> = Vec::new();
        if deadline > 0.0 {
            for &(client, sim_id) in &comm.uploads {
                let late = outcomes
                    .iter()
                    .find(|o| o.id == sim_id)
                    .is_some_and(|o| o.delivered_s - round_start > deadline);
                if late {
                    stragglers.push(client);
                    late_ids.push(sim_id);
                }
            }
            stragglers.sort_unstable();
            late_ids.sort_unstable();
            if !stragglers.is_empty() {
                log::debug!(
                    "round {t}: {} stragglers past deadline_s={deadline}",
                    stragglers.len()
                );
            }
        }
        // Per-transfer spans: one `net` span per DES delivery, on a
        // per-route lane, sim window submit -> deliver, kind joined from
        // the round's submission log and the straggler verdict attached.
        // The DES order is worker-count-independent, so so is this
        // event sequence.
        if self.tracer.enabled(TraceLevel::Full) {
            let mut kind_of = std::collections::BTreeMap::new();
            for &(id, kind) in &comm.submitted {
                kind_of.insert(id, kind);
            }
            for o in &outcomes {
                let kind = kind_of.get(&o.id).copied().unwrap_or("transfer");
                let mut attrs = vec![
                    ("transfer", o.id.into()),
                    ("round", t.into()),
                    ("bytes", o.bytes.into()),
                    ("hops", o.hops.into()),
                    ("queue_wait_s", o.queue_wait_s.into()),
                ];
                if late_ids.binary_search(&o.id).is_ok() {
                    attrs.push(("straggler", true.into()));
                }
                self.tracer.span_at(
                    TraceLevel::Full,
                    "net",
                    kind,
                    &format!("route:{}->{}", o.src.0, o.dst.0),
                    self.tracer.rel_now_ns(),
                    0,
                    Some((o.submitted_s, o.latency_s())),
                    attrs,
                );
            }
        }
        self.reg.inc("transfers_total", outcomes.len() as u64);
        self.reg.inc(
            "transfer_bytes_total",
            outcomes.iter().map(|o| o.bytes).sum::<u64>(),
        );
        for o in &outcomes {
            self.reg.observe(
                "transfer_latency_s",
                &TRANSFER_LATENCY_BOUNDS,
                o.latency_s(),
            );
        }
        self.reg.inc("stragglers_total", stragglers.len() as u64);
        self.notify(|o, ctl| o.on_comm(t, &comm, net_s, &stragglers, ctl));
        self.timer.lap("comm");

        // Under the drop policy a straggler neither trains nor
        // aggregates; under defer it still trains below — its update is
        // held for the next round — but is excluded from this round's
        // partials either way.  The straggler list is sorted, so
        // membership checks are binary searches, not linear scans.
        let defer = self.cfg.straggler_policy == StragglerPolicy::Defer;
        if !stragglers.is_empty() && !defer {
            for (_m, members) in &mut plan.groups {
                members.retain(|id| stragglers.binary_search(id).is_err());
            }
            plan.groups.retain(|(_, v)| !v.is_empty());
        }

        // Earlier rounds' deferred updates fold into *this* round's
        // reduction (empty unless straggler_policy = defer); this
        // round's new deferrals are taken after the drain, so an update
        // can never fold into the round that produced it.  An
        // all-dropped round returned above *without* draining — a round
        // that never touches the network cannot transport the held
        // states, so they stay pending for the next round that
        // communicates.
        let folded = self.deferred.drain_sorted();

        // --- local updates (fanned out across the pool) --------------
        // Groups run one after another; members *within* a group fan
        // out across the pool and come back in member order, so the
        // loss vector and the reduction below see an identical
        // operand sequence at any worker count.  Per-group fan-out
        // also bounds peak memory at one group's states (HierFL's
        // full-participation rounds would otherwise hold every
        // client's state at once), and each group's partial is
        // reduced — by sample count, paper Eq. 3 — before the next
        // group trains.
        let mut loss_terms: Vec<(f64, f64)> = Vec::new(); // (Eq. 3 weight, loss)
        let mut group_states: Vec<(f64, ModelState)> =
            Vec::with_capacity(plan.groups.len());
        // Per-client spans are *measured* inside the pool closures (mark
        // pairs only — no emission off the main thread) and emitted
        // below in plan order, so the logical event stream is identical
        // at any worker count; only the worker-lane labels and wall
        // offsets vary.
        let trace_clients = self.tracer.enabled(TraceLevel::Full);
        for (_m, members) in &plan.groups {
            self.reg.inc("local_updates_total", members.len() as u64);
            let results: Vec<(u64, u64, usize, Result<(ModelState, f32)>)> = {
                let state = &self.state;
                let loader = &self.loader;
                let fed = &self.fed;
                let lus = &self.lus;
                let k = self.cfg.local_steps;
                let lr = self.cfg.lr as f32;
                let tracer = &self.tracer;
                self.pool.run_spanned(
                    tracer,
                    "local_update",
                    members.len(),
                    move |i, w| {
                        let id = members[i];
                        let start =
                            if trace_clients { tracer.rel_now_ns() } else { 0 };
                        let batch = loader
                            .local_batches(&fed.train, &fed.clients[id], t, k);
                        let r = lus[w].run(state, &batch, lr);
                        let dur = if trace_clients {
                            tracer.rel_now_ns().saturating_sub(start)
                        } else {
                            0
                        };
                        (start, dur, w, r)
                    },
                )
            };
            let mut weighted = Vec::with_capacity(members.len());
            for (&id, (start_ns, dur_ns, w, r)) in members.iter().zip(results) {
                if trace_clients {
                    self.tracer.span_at(
                        TraceLevel::Full,
                        "client",
                        "local_update",
                        &format!("worker{w}"),
                        start_ns,
                        dur_ns,
                        None,
                        vec![("round", t.into()), ("client", id.into())],
                    );
                }
                let (s, loss) = r?;
                if !loss.is_finite() {
                    return Err(Error::Data(format!(
                        "non-finite loss at round {t} client {id} — \
                         lower the learning rate"
                    )));
                }
                let weight = self.client_weight(id);
                if stragglers.binary_search(&id).is_ok() {
                    // Straggler re-inclusion: hold the late update for
                    // the next round (a client straggling again before
                    // the pool drains replaces its older entry — never
                    // two updates from one client in one reduction).
                    self.deferred.defer(DeferredUpdate {
                        client: id,
                        round: t,
                        weight,
                        loss: loss as f64,
                        state: s,
                    });
                } else {
                    loss_terms.push((weight, loss as f64));
                    weighted.push((weight, s));
                }
            }
            if !weighted.is_empty() {
                group_states.push(par_reduce_states_weighted(weighted, &self.pool)?);
            }
        }
        let train_s = self.timer.lap("train").as_secs_f64();

        // --- aggregation (Eq. 3) -------------------------------------
        // Each group partial carries its summed sample count, so the
        // cloud (or a multi-group edge plan) also aggregates per
        // Eq. 3 — not by contributing-group count, and never by
        // dropping surplus groups.  Folded deferred updates join the
        // reduction after the partials, in client-id order, each with
        // its own Eq. 3 weight.
        let mut operands = group_states;
        let mut deferred_ids = Vec::with_capacity(folded.len());
        // Clients contributing a fresh on-time update this round (their
        // Eq. 3 entries are already inside the group partials).  A
        // pending stale update from such a client is *superseded* and
        // must not fold next to the fresh one — a reduction carries at
        // most one update per client, and the freshest wins.  (Rotating
        // schedules like EdgeFLow never hit this; FedAvg resampling and
        // HierFL full participation do.)
        let mut on_time: Vec<usize> = if folded.is_empty() {
            Vec::new()
        } else {
            plan.groups
                .iter()
                .flat_map(|(_, ms)| ms.iter().copied())
                .filter(|id| stragglers.binary_search(id).is_err())
                .collect()
        };
        on_time.sort_unstable();
        // A folded update was delivered (late) to its *own* cluster's BS
        // back when it straggled; reaching this round's aggregation site
        // is one more model-sized transfer, charged to this round's
        // byte-hops under the "deferred" label (the paper's load metric
        // must not get straggler re-inclusion for free).  Its timing
        // piggybacks on the round barrier — the held state travels
        // alongside the migration, so no extra DES transfer is
        // simulated.
        // (Folded non-empty implies the defer policy, which never empties
        // plan.groups — so groups[0] is safe in the SeqFL arm.)
        let site_node = if folded.is_empty() {
            None
        } else {
            Some(match plan.aggregation {
                AggregationSite::Cloud => self.topo.cloud()?,
                AggregationSite::EdgeBs(m) => self.topo.edge_bs(m)?,
                AggregationSite::None => self.topo.edge_bs(plan.groups[0].0)?,
            })
        };
        for d in folded {
            if on_time.binary_search(&d.client).is_ok() {
                log::debug!(
                    "round {t}: client {}'s deferred round-{} update is \
                     superseded by its on-time update and dropped",
                    d.client,
                    d.round
                );
                continue;
            }
            // lint:allow(unwrap-in-library): site_node is None only
            // when `folded` is empty, and this loop iterates `folded`.
            let site = site_node.expect("folded non-empty implies a site");
            let from = self.topo.edge_bs(self.fed.clients[d.client].cluster)?;
            if from != site {
                let fold_routes = RouteTable::hops(&self.topo);
                let hops = self.accountant.record(
                    &self.topo,
                    &fold_routes,
                    from,
                    site,
                    model_bytes,
                    "deferred",
                    t,
                )?;
                byte_hops += model_bytes * hops as u64;
            }
            deferred_ids.push(d.client);
            loss_terms.push((d.weight, d.loss));
            operands.push((d.weight, d.state));
        }
        if operands.is_empty() {
            // Every survivor straggled and nothing was pending: the
            // traffic was spent, but nothing aggregates; the model
            // carries over.
            let record = lost_round_record(
                t,
                plan.cluster,
                byte_hops,
                net_s,
                self.net.now_s(),
                stragglers,
            );
            return self.finish(
                round_mark,
                RoundOutcome::Lost { record, cause: LostCause::AllStraggled },
            );
        }
        let (_total_w, merged) = par_reduce_states_weighted(operands, &self.pool)?;
        let aggregate_s = self.timer.lap("aggregate").as_secs_f64();
        self.notify(|o, ctl| o.on_aggregate(t, &merged, ctl));
        self.state = merged;

        // --- evaluation ----------------------------------------------
        let eval_now = t + 1 == self.cfg.rounds
            || (self.cfg.eval_every > 0 && (t + 1) % self.cfg.eval_every == 0);
        let (test_loss, test_acc) = if eval_now {
            self.reg.inc("evals_total", 1);
            self.evaluate()?
        } else {
            (f64::NAN, f64::NAN)
        };
        let _ = self.timer.lap("eval");

        // Per-round training loss weighted by the same Eq. 3 sample
        // counts the aggregation uses — a uniform mean would misreport
        // unbalanced federations.  Folded deferred updates contribute
        // here too: the reported loss covers exactly this round's
        // reduction operands.
        let weight_sum: f64 = loss_terms.iter().map(|(w, _)| w).sum();
        let train_loss =
            loss_terms.iter().map(|(w, l)| w * l).sum::<f64>() / weight_sum;
        let record = RoundRecord {
            round: t,
            cluster: plan.cluster,
            train_loss,
            test_accuracy: test_acc,
            test_loss,
            comm_byte_hops: byte_hops,
            train_s,
            aggregate_s,
            net_s,
            clock_s: self.net.now_s(),
            stragglers,
            deferred: deferred_ids,
        };
        self.finish(
            round_mark,
            RoundOutcome::Completed { record, migration: plan.migration },
        )
    }

    /// Record the round, advance the cursor, emit the round span, fire
    /// `on_round_end`.
    fn finish(
        &mut self,
        round_mark: Option<WallMark>,
        outcome: RoundOutcome,
    ) -> Result<RoundOutcome> {
        {
            let record = outcome.record();
            self.reg.inc("rounds_total", 1);
            if matches!(outcome, RoundOutcome::Lost { .. }) {
                self.reg.inc("rounds_lost_total", 1);
            }
            // The round's sim window: `clock_s` is the DES clock at the
            // round's end and `net_s` its makespan, so the window starts
            // at their difference.
            let mut attrs = vec![
                ("round", record.round.into()),
                ("byte_hops", record.comm_byte_hops.into()),
                ("stragglers", record.stragglers.len().into()),
            ];
            if record.cluster != usize::MAX {
                attrs.push(("cluster", record.cluster.into()));
            }
            self.tracer.span(
                TraceLevel::Round,
                "round",
                "round",
                "main",
                round_mark,
                Some((record.clock_s - record.net_s, record.net_s)),
                attrs,
            );
            self.metrics.push(record.clone());
        }
        self.cursor += 1;
        let t = outcome.round();
        self.notify(|o, ctl| o.on_round_end(t, &outcome, ctl));
        Ok(outcome)
    }

    /// Fire `f` over every observer (detached so hooks can receive
    /// borrowed round data) and honor any control requests afterwards.
    fn notify(&mut self, mut f: impl FnMut(&mut dyn RoundObserver, &mut RoundControl)) {
        if self.observers.is_empty() {
            return;
        }
        let mut obs = std::mem::take(&mut self.observers);
        let mut ctl = RoundControl::default();
        for o in obs.iter_mut() {
            f(o.as_mut(), &mut ctl);
        }
        self.observers = obs;
        self.apply_control(ctl);
    }

    /// Honor an observer's control requests.
    fn apply_control(&mut self, ctl: RoundControl) {
        if ctl.stop_requested() {
            self.stopped = true;
        }
        if let Some(d) = ctl.deadline_override() {
            if d.is_finite() && d >= 0.0 {
                self.deadline_s = d;
            } else {
                log::warn!("ignoring invalid deadline override {d}");
            }
        }
    }

    /// Result summary of the rounds executed so far.  Callable at any
    /// round boundary; after a restore it covers the whole run (records
    /// travel in the checkpoint), while `phase_seconds` covers only this
    /// process's work.
    pub fn report(&self) -> RunReport {
        let report = RunReport {
            name: self.cfg.name.clone(),
            algorithm: self.strategy.name(),
            final_accuracy: self.metrics.final_accuracy(),
            best_accuracy: self.metrics.best_accuracy(),
            final_loss: self.metrics.final_train_loss(),
            total_byte_hops: self.metrics.total_byte_hops(),
            rounds: self.metrics.rounds.len(),
            metrics: self.metrics.clone(),
            phase_seconds: self.timer.laps(),
        };
        // Snapshot the deterministic registry into the trace, with the
        // summary gauges stamped on a copy so repeated `report()` calls
        // never mutate session state.
        if self.tracer.enabled(TraceLevel::Round) {
            let mut reg = self.reg.clone();
            reg.set_gauge("final_accuracy", report.final_accuracy);
            reg.set_gauge("best_accuracy", report.best_accuracy);
            reg.set_gauge("sim_clock_s", self.net.now_s());
            self.tracer.metrics(&reg);
            self.tracer.flush();
        }
        report
    }

    /// Run the session to completion: a thin loop over [`Runner::step`].
    /// Callers that need checkpoints, pacing, or custom stop conditions
    /// drive `step()` themselves.
    pub fn run(&mut self) -> Result<RunReport> {
        while !self.is_done() {
            self.step()?;
        }
        Ok(self.report())
    }

    // ------------------------------------------------ checkpoint/resume

    /// Snapshot the session at a round boundary.  Captures the config,
    /// round cursor, model state, the persistent DES's carried clock and
    /// link state, the dropout RNG stream, the strategy's scheduler
    /// cursor, every accumulated round record, and pending deferred
    /// updates — everything `restore` needs to continue bit-identically.
    /// (The loader's minibatch stream is a pure function of
    /// `(seed, client, round)` and needs no state.)
    pub fn checkpoint(&self) -> Result<RunnerCheckpoint> {
        self.tracer.instant(
            TraceLevel::Round,
            "ckpt",
            "checkpoint",
            "main",
            Some(self.net.now_s()),
            vec![("round", self.cursor.into())],
        );
        Ok(RunnerCheckpoint {
            cfg: self.cfg.clone(),
            cursor: self.cursor,
            stopped: self.stopped,
            deadline_s: self.deadline_s,
            state_blob: self.state.to_blob(),
            net: self.net.state()?,
            dropout_rng: self.dropout_rng.state(),
            strategy: self.strategy.checkpoint(),
            records: self.metrics.rounds.clone(),
            deferred: self
                .deferred
                .entries()
                .iter()
                .map(|d| DeferredBlob {
                    client: d.client,
                    round: d.round,
                    weight: d.weight,
                    loss: d.loss,
                    blob: d.state.to_blob(),
                })
                .collect(),
        })
    }

    /// Restore a [`RunnerCheckpoint`] onto a runner built from the
    /// *same* config.  A run checkpointed at round T and restored
    /// produces a `RunReport` bit-identical to the uninterrupted run's
    /// (wall-clock phase timings excepted).  The communication
    /// accountant restarts empty — per-round byte-hops are deltas and
    /// the totals live in the restored records.
    pub fn restore(&mut self, ck: &RunnerCheckpoint) -> Result<()> {
        // Tracing is observability, not session state: a run may resume
        // with tracing toggled or redirected, so the config comparison
        // blanks the trace fields on both sides.
        let sans_trace = |c: &ExperimentConfig| {
            let mut c = c.clone();
            c.trace = String::new();
            c.trace_level = "full".into();
            c.to_json().dump()
        };
        if sans_trace(&ck.cfg) != sans_trace(&self.cfg) {
            return Err(Error::Config(
                "checkpoint was taken under a different config — build the \
                 runner from the checkpoint's cfg (Runner::resume)"
                    .into(),
            ));
        }
        let layout = self.state.layout.clone();
        self.state = ModelState::from_blob(layout.clone(), &ck.state_blob)?;
        self.net.restore(&ck.net)?;
        self.dropout_rng = Rng::from_state(&ck.dropout_rng);
        self.strategy.restore(&ck.strategy)?;
        self.metrics = ExperimentMetrics { rounds: ck.records.clone() };
        self.accountant = CommAccountant::new();
        self.deferred = DeferredPool::default();
        for d in &ck.deferred {
            self.deferred.defer(DeferredUpdate {
                client: d.client,
                round: d.round,
                weight: d.weight,
                loss: d.loss,
                state: ModelState::from_blob(layout.clone(), &d.blob)?,
            });
        }
        self.cursor = ck.cursor;
        self.stopped = ck.stopped;
        self.deadline_s = ck.deadline_s;
        self.timer = PhaseTimer::new(self.tracer.clone());
        self.reg = MetricsRegistry::default();
        self.tracer.instant(
            TraceLevel::Round,
            "ckpt",
            "restore",
            "main",
            Some(self.net.now_s()),
            vec![("round", self.cursor.into())],
        );
        Ok(())
    }

    /// Build a runner from a checkpoint's embedded config and restore
    /// the session — the one-call resume path behind `--resume`.  The
    /// backend must match the checkpoint's `cfg.engine` (use
    /// [`crate::runtime::backend::backend_for`] on the embedded config).
    pub fn resume(backend: Arc<dyn TrainBackend>, ck: &RunnerCheckpoint) -> Result<Runner> {
        let mut r = Runner::with_backend(backend, ck.cfg.clone())?;
        r.restore(ck)?;
        Ok(r)
    }
}

/// A pending straggler update in wire form (model state as the
/// little-endian `*_init.bin` blob format).
#[derive(Debug, Clone)]
pub struct DeferredBlob {
    pub client: usize,
    pub round: usize,
    pub weight: f64,
    pub loss: f64,
    pub blob: Vec<u8>,
}

/// Serializable session snapshot (see [`Runner::checkpoint`]).  Floats
/// travel as bit patterns, blobs as hex — the resume-is-bit-identical
/// contract leaves no room for decimal round-trips.
#[derive(Debug, Clone)]
pub struct RunnerCheckpoint {
    pub cfg: ExperimentConfig,
    pub cursor: usize,
    pub stopped: bool,
    /// Active (possibly observer-adjusted) round deadline.
    pub deadline_s: f64,
    /// Model state in the little-endian blob format.
    pub state_blob: Vec<u8>,
    pub net: NetSimState,
    pub dropout_rng: RngState,
    /// Strategy cursor/stream state ([`Strategy::checkpoint`]).
    pub strategy: Json,
    pub records: Vec<RoundRecord>,
    /// Pending straggler re-inclusion updates.
    pub deferred: Vec<DeferredBlob>,
}

impl RunnerCheckpoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", 1usize.into()),
            ("cfg", self.cfg.to_json()),
            ("cursor", self.cursor.into()),
            ("stopped", self.stopped.into()),
            ("deadline_s", f64_to_hex(self.deadline_s).into()),
            ("state_hex", bytes_to_hex(&self.state_blob).into()),
            (
                "net",
                Json::obj(vec![
                    (
                        "link_free_s",
                        Json::arr(
                            self.net
                                .link_free_s
                                .iter()
                                .map(|&v| Json::from(f64_to_hex(v))),
                        ),
                    ),
                    (
                        "link_busy_s",
                        Json::arr(
                            self.net
                                .link_busy_s
                                .iter()
                                .map(|&v| Json::from(f64_to_hex(v))),
                        ),
                    ),
                    ("clock_s", f64_to_hex(self.net.clock_s).into()),
                    ("seq", self.net.seq.into()),
                    ("id_base", self.net.id_base.into()),
                ]),
            ),
            ("dropout_rng", self.dropout_rng.to_json()),
            ("strategy", self.strategy.clone()),
            (
                "records",
                Json::arr(self.records.iter().map(|r| r.to_ckpt_json())),
            ),
            (
                "deferred",
                Json::arr(self.deferred.iter().map(|d| {
                    Json::obj(vec![
                        ("client", d.client.into()),
                        ("round", d.round.into()),
                        ("weight", f64_to_hex(d.weight).into()),
                        ("loss", f64_to_hex(d.loss).into()),
                        ("state_hex", bytes_to_hex(&d.blob).into()),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunnerCheckpoint> {
        let version = j.usize_field("version")?;
        if version != 1 {
            return Err(Error::Config(format!(
                "unsupported checkpoint version {version}"
            )));
        }
        let netj = j.req("net")?;
        let hex_vec = |field: &str| -> Result<Vec<f64>> {
            netj.req(field)?
                .as_arr()
                .ok_or_else(|| Error::Json(format!("field {field:?} must be an array")))?
                .iter()
                .map(|x| {
                    f64_from_hex(x.as_str().ok_or_else(|| {
                        Error::Json(format!("field {field:?} holds a non-hex entry"))
                    })?)
                })
                .collect()
        };
        let records = j
            .req("records")?
            .as_arr()
            .ok_or_else(|| Error::Json("records must be an array".into()))?
            .iter()
            .map(RoundRecord::from_ckpt_json)
            .collect::<Result<Vec<_>>>()?;
        let deferred = j
            .req("deferred")?
            .as_arr()
            .ok_or_else(|| Error::Json("deferred must be an array".into()))?
            .iter()
            .map(|d| {
                Ok(DeferredBlob {
                    client: d.usize_field("client")?,
                    round: d.usize_field("round")?,
                    weight: f64_from_hex(d.str_field("weight")?)?,
                    loss: f64_from_hex(d.str_field("loss")?)?,
                    blob: bytes_from_hex(d.str_field("state_hex")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunnerCheckpoint {
            cfg: ExperimentConfig::from_json(j.req("cfg")?)?,
            cursor: j.usize_field("cursor")?,
            stopped: j
                .req("stopped")?
                .as_bool()
                .ok_or_else(|| Error::Json("stopped must be a bool".into()))?,
            deadline_s: f64_from_hex(j.str_field("deadline_s")?)?,
            state_blob: bytes_from_hex(j.str_field("state_hex")?)?,
            net: NetSimState {
                link_free_s: hex_vec("link_free_s")?,
                link_busy_s: hex_vec("link_busy_s")?,
                clock_s: f64_from_hex(netj.str_field("clock_s")?)?,
                seq: netj.usize_field("seq")?,
                id_base: netj.usize_field("id_base")?,
            },
            dropout_rng: RngState::from_json(j.req("dropout_rng")?)?,
            strategy: j.req("strategy")?.clone(),
            records,
            deferred,
        })
    }

    /// Write the checkpoint as pretty JSON — atomically (temp file +
    /// rename), so an interrupt mid-write can never destroy the
    /// previous good checkpoint; surviving exactly such interrupts is
    /// what checkpointing is for.
    pub fn save(&self, path: &str) -> Result<()> {
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, self.to_json().pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a checkpoint written by [`RunnerCheckpoint::save`].
    pub fn load(path: &str) -> Result<RunnerCheckpoint> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

/// Carry-over record for a round that trained nothing (all participants
/// dropped, or every survivor straggled past the deadline with nothing
/// pending): NaN losses, whatever traffic/clock the round did spend, and
/// the model unchanged.
fn lost_round_record(
    round: usize,
    cluster: usize,
    comm_byte_hops: u64,
    net_s: f64,
    clock_s: f64,
    stragglers: Vec<usize>,
) -> RoundRecord {
    RoundRecord {
        round,
        cluster,
        train_loss: f64::NAN,
        test_accuracy: f64::NAN,
        test_loss: f64::NAN,
        comm_byte_hops,
        train_s: 0.0,
        aggregate_s: 0.0,
        net_s,
        clock_s,
        stragglers,
        deferred: Vec::new(),
    }
}

// ------------------------------------------- checkpoint operability
//
// Long runs rotate checkpoints instead of overwriting one file:
// `--checkpoint-keep N` writes round-stamped siblings of the base path
// and prunes the oldest, and `--resume-latest <dir>` picks up wherever
// the newest one left off — no path bookkeeping across restarts.

/// Suffix every checkpoint file carries.
const CKPT_SUFFIX: &str = ".ckpt.json";

/// Round-stamped sibling of a base checkpoint path:
/// `runs/foo.ckpt.json` at round 12 -> `runs/foo.r000012.ckpt.json`.
/// A base without the canonical suffix still *gains* it (`run` ->
/// `run.r000012.ckpt.json`), so rotated files are always discoverable
/// by [`find_latest_checkpoint`] and prunable by [`prune_checkpoints`].
/// Zero-padding keeps lexicographic and numeric order identical.
pub fn round_stamped_path(base: &str, round: usize) -> String {
    let stem = base.strip_suffix(CKPT_SUFFIX).unwrap_or(base);
    format!("{stem}.r{round:06}{CKPT_SUFFIX}")
}

/// The round stamp of a checkpoint file name (`foo.r000012.ckpt.json`
/// -> 12), or `None` for unstamped files.
fn round_stamp(name: &str) -> Option<usize> {
    let stem = name.strip_suffix(CKPT_SUFFIX)?;
    let (_, tail) = stem.rsplit_once(".r")?;
    if tail.is_empty() || !tail.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    tail.parse().ok()
}

/// Newest `*.ckpt.json` in a directory — newest by **parsed round
/// stamp**, the deterministic key the rotation itself writes: stamped
/// files rank above unstamped, higher rounds above lower.  Filesystem
/// mtime is only the tie-break between equal stamps (distinct run
/// families sharing a directory), then name — two checkpoints written
/// within one mtime granule used to race on which resumed.  Errors
/// when the directory holds no checkpoint at all.
pub fn find_latest_checkpoint(dir: &str) -> Result<String> {
    let mut candidates = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.ends_with(CKPT_SUFFIX) || !entry.file_type()?.is_file() {
            continue;
        }
        // Primary key: the round stamp parsed from the file name.
        let stamp = match round_stamp(&name) {
            Some(r) => 1 + r as u64,
            None => 0,
        };
        // lint:allow(wall-clock-in-sim): the filesystem clock only
        // breaks ties between *equal* round stamps; resume order is
        // decided by the deterministic stamp above.
        let mtime = entry.metadata()?.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        let path = entry.path().to_string_lossy().into_owned();
        candidates.push((stamp, mtime, name, path));
    }
    candidates.into_iter().max().map(|(_, _, _, path)| path).ok_or_else(|| {
        Error::Config(format!("no *{CKPT_SUFFIX} checkpoint found in {dir:?}"))
    })
}

/// Prune round-stamped siblings of `base`, keeping the `keep` newest
/// (by round).  Returns the deleted paths.  The unstamped base file and
/// unrelated checkpoints are never touched; `keep == 0` is a no-op
/// (pruning disabled), matching the CLI default.
pub fn prune_checkpoints(base: &str, keep: usize) -> Result<Vec<String>> {
    if keep == 0 {
        return Ok(Vec::new());
    }
    let path = std::path::Path::new(base);
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let stem = match path.file_name().map(|n| n.to_string_lossy().into_owned()) {
        // The same suffix-optional stem rule as `round_stamped_path`,
        // so every base this module stamps, it can also prune.
        Some(n) => n.strip_suffix(CKPT_SUFFIX).unwrap_or(&n).to_string(),
        None => return Ok(Vec::new()),
    };
    let mut stamped: Vec<(usize, std::path::PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(&dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let Some(rest) = name.strip_prefix(&stem) else { continue };
        let Some(round) = round_stamp(&name) else { continue };
        // The stamp must be exactly `.r<digits>` between stem and
        // suffix, not a longer sibling name that happens to share the
        // prefix.
        if rest != format!(".r{round:06}{CKPT_SUFFIX}") {
            continue;
        }
        stamped.push((round, entry.path()));
    }
    stamped.sort_by(|a, b| b.0.cmp(&a.0)); // newest first
    let mut removed = Vec::new();
    for (_, p) in stamped.into_iter().skip(keep) {
        std::fs::remove_file(&p)?;
        removed.push(p.to_string_lossy().into_owned());
    }
    Ok(removed)
}

/// Seed-mixing constant separating the loader's stream from the
/// partitioner's and the strategies'.
const LOADER_SEED_MIX: u64 = 0x10AD_E2B6;

/// Fixed bucket bounds (simulated seconds) for the per-transfer latency
/// histogram — fixed so registry snapshots merge and compare
/// bit-identically across runs and worker counts.
const TRANSFER_LATENCY_BOUNDS: [f64; 6] = [0.001, 0.01, 0.1, 1.0, 10.0, 100.0];

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("edgeflow_ckpt_ops_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn round_stamping_and_parsing() {
        assert_eq!(
            round_stamped_path("runs/foo.ckpt.json", 12),
            "runs/foo.r000012.ckpt.json"
        );
        // A suffix-less base still produces discoverable/prunable files.
        assert_eq!(round_stamped_path("bare", 3), "bare.r000003.ckpt.json");
        assert_eq!(round_stamp("foo.r000012.ckpt.json"), Some(12));
        assert_eq!(round_stamp("foo.ckpt.json"), None);
        assert_eq!(round_stamp("foo.rabc.ckpt.json"), None);
        assert_eq!(round_stamp("foo.r12.csv"), None);
    }

    #[test]
    fn latest_prefers_round_stamp_over_mtime() {
        // Regression: resume order must be decided by the parsed round
        // stamp, not by filesystem mtime — two checkpoints written
        // within one mtime granule used to race on which resumed.  The
        // highest stamp wins even when lower-stamped files are written
        // measurably *later*.
        let d = tmpdir("latest");
        std::fs::write(d.join("old.r000100.ckpt.json"), "{}").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        for r in [2usize, 7, 10] {
            std::fs::write(d.join(format!("run.r{r:06}.ckpt.json")), "{}").unwrap();
        }
        std::fs::write(d.join("notes.txt"), "x").unwrap();
        let latest = find_latest_checkpoint(d.to_str().unwrap()).unwrap();
        assert!(latest.ends_with("old.r000100.ckpt.json"), "{latest}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn equal_stamps_tie_break_by_mtime_then_name() {
        // mtime still matters, but only *between* equal round stamps
        // (distinct run families sharing a directory) — and a stamped
        // file beats a fresher unstamped one.
        let d = tmpdir("tiebreak");
        std::fs::write(d.join("a.r000005.ckpt.json"), "{}").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        std::fs::write(d.join("b.r000005.ckpt.json"), "{}").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        std::fs::write(d.join("z.ckpt.json"), "{}").unwrap();
        let latest = find_latest_checkpoint(d.to_str().unwrap()).unwrap();
        assert!(latest.ends_with("b.r000005.ckpt.json"), "{latest}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn latest_falls_back_to_unstamped_and_errors_when_empty() {
        let d = tmpdir("fallback");
        assert!(find_latest_checkpoint(d.to_str().unwrap()).is_err());
        std::fs::write(d.join("a.ckpt.json"), "{}").unwrap();
        std::fs::write(d.join("b.ckpt.json"), "{}").unwrap();
        let latest = find_latest_checkpoint(d.to_str().unwrap()).unwrap();
        assert!(latest.ends_with(".ckpt.json"), "{latest}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn prune_keeps_newest_rounds_only() {
        let d = tmpdir("prune");
        let base = d.join("run.ckpt.json");
        let base_s = base.to_str().unwrap().to_string();
        for r in 1..=5usize {
            std::fs::write(d.join(format!("run.r{r:06}.ckpt.json")), "{}").unwrap();
        }
        // Unstamped base and an unrelated stamped family are untouched.
        std::fs::write(&base, "{}").unwrap();
        std::fs::write(d.join("other.r000001.ckpt.json"), "{}").unwrap();
        let removed = prune_checkpoints(&base_s, 2).unwrap();
        assert_eq!(removed.len(), 3, "{removed:?}");
        let mut left: Vec<String> = std::fs::read_dir(&d)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        left.sort();
        assert_eq!(
            left,
            vec![
                "other.r000001.ckpt.json",
                "run.ckpt.json",
                "run.r000004.ckpt.json",
                "run.r000005.ckpt.json",
            ]
        );
        // keep = 0 disables pruning entirely
        assert!(prune_checkpoints(&base_s, 0).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn stamp_and_prune_work_for_suffixless_base() {
        // A `--checkpoint run` base gains the canonical suffix on every
        // stamped file, so rotation and --resume-latest still see them.
        let d = tmpdir("suffixless");
        let base = d.join("run");
        let base_s = base.to_str().unwrap().to_string();
        for r in 1..=3usize {
            std::fs::write(round_stamped_path(&base_s, r), "{}").unwrap();
        }
        let removed = prune_checkpoints(&base_s, 1).unwrap();
        assert_eq!(removed.len(), 2, "{removed:?}");
        let latest = find_latest_checkpoint(d.to_str().unwrap()).unwrap();
        assert!(latest.ends_with("run.r000003.ckpt.json"), "{latest}");
        let _ = std::fs::remove_dir_all(&d);
    }
}
