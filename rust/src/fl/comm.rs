//! Per-round communication patterns over a topology (paper Fig 4).
//!
//! Each algorithm induces a fixed transfer pattern per round; recording it
//! with [`CommAccountant`] yields the paper's "parameters uploaded per
//! round" metric (byte-hops) and, through [`crate::netsim`], transfer
//! latencies.  The paper counts *uploads* (model-parameter traffic toward
//! the aggregation point plus EdgeFLow's migration); downloads can be
//! included with [`CommOptions::count_downloads`] for the extended study.

use crate::fl::strategy::{AggregationSite, RoundPlan};
use crate::netsim::NetSim;
use crate::topology::accounting::CommAccountant;
use crate::topology::graph::Topology;
use crate::topology::route::RouteTable;
use crate::util::error::Result;

/// What to count.
#[derive(Debug, Clone, Copy)]
pub struct CommOptions {
    /// Also count model broadcast/download traffic (paper counts uploads).
    pub count_downloads: bool,
}

impl Default for CommOptions {
    fn default() -> Self {
        CommOptions { count_downloads: false }
    }
}

/// What one round's transfers amounted to.
#[derive(Debug, Clone, Default)]
pub struct RoundComm {
    /// Byte-hops added to the accountant by this round.
    pub byte_hops: u64,
    /// `(client id, DES transfer id)` for every client *upload* submitted
    /// to the sim — the runner matches these against delivery times to
    /// find deadline stragglers.  Empty when no sim was supplied.
    pub uploads: Vec<(usize, usize)>,
    /// `(DES transfer id, kind)` for *every* sim submission this round —
    /// uploads, downloads and migrations — in submission order; the
    /// runner joins these against [`crate::netsim::TransferOutcome`]s to
    /// emit per-transfer trace spans.  Empty when no sim was supplied.
    pub submitted: Vec<(usize, &'static str)>,
}

/// Record one round's transfers into `acc` (routed on `routes` — the
/// paper's hop-count load metric); optionally simulate their timing in a
/// DES.  `sim` carries its own route table (submitted at `at_s`): the
/// simulator rides time-weighted routes — latency, or bandwidth-aware
/// transfer time when the model size is known — which on diamond
/// topologies disagree with the hop-shortest accounting routes.
#[allow(clippy::too_many_arguments)]
pub fn record_round(
    plan: &RoundPlan,
    topo: &Topology,
    routes: &RouteTable,
    acc: &mut CommAccountant,
    model_bytes: u64,
    round: usize,
    opts: CommOptions,
    mut sim: Option<(&mut NetSim, &RouteTable, f64)>,
) -> Result<RoundComm> {
    let before = acc.byte_hops();
    let mut uploads: Vec<(usize, usize)> = Vec::new();
    let mut submitted: Vec<(usize, &'static str)> = Vec::new();
    let mut send = |acc: &mut CommAccountant,
                    uploads: &mut Vec<(usize, usize)>,
                    submitted: &mut Vec<(usize, &'static str)>,
                    src,
                    dst,
                    label: &'static str,
                    client: Option<usize>|
     -> Result<()> {
        acc.record(topo, routes, src, dst, model_bytes, label, round)?;
        if let Some((sim, sim_routes, at_s)) = sim.as_mut() {
            let id = sim.submit(sim_routes, src, dst, model_bytes, *at_s)?;
            submitted.push((id, label));
            if let Some(c) = client {
                uploads.push((c, id));
            }
        }
        Ok(())
    };

    match plan.aggregation {
        AggregationSite::Cloud => {
            let cloud = topo.cloud()?;
            if plan.groups.len() == 1 && plan.groups[0].0 == usize::MAX {
                // FedAvg: every sampled client uploads device -> cloud
                // (via its base station), and downloads the fresh model.
                for &id in &plan.groups[0].1 {
                    let c = topo.client(id)?;
                    if opts.count_downloads {
                        send(acc, &mut uploads, &mut submitted, cloud, c, "download", None)?;
                    }
                    send(acc, &mut uploads, &mut submitted, c, cloud, "upload", Some(id))?;
                }
            } else {
                // Hierarchical FL: clients upload to their edge BS; each BS
                // uploads one cluster model to the cloud.
                for (m, members) in &plan.groups {
                    let bs = topo.edge_bs(*m)?;
                    for &id in members {
                        let c = topo.client(id)?;
                        if opts.count_downloads {
                            send(acc, &mut uploads, &mut submitted, bs, c, "download", None)?;
                        }
                        send(acc, &mut uploads, &mut submitted, c, bs, "upload", Some(id))?;
                    }
                    if opts.count_downloads {
                        send(acc, &mut uploads, &mut submitted, cloud, bs, "download", None)?;
                    }
                    send(acc, &mut uploads, &mut submitted, bs, cloud, "upload", None)?;
                }
            }
        }
        AggregationSite::EdgeBs(site) => {
            // EdgeFLow: every group's clients exchange with *their own*
            // BS — multi-group edge plans aggregate all groups, so all of
            // them are charged (not just the first) — non-site groups then
            // ship their partial to the aggregation site (mirroring
            // HierFL's BS -> cloud leg), and the model migrates BS ->
            // next BS.
            let site_bs = topo.edge_bs(site)?;
            for (m, members) in &plan.groups {
                let bs = topo.edge_bs(*m)?;
                for &id in members {
                    let c = topo.client(id)?;
                    if opts.count_downloads {
                        send(acc, &mut uploads, &mut submitted, bs, c, "download", None)?;
                    }
                    send(acc, &mut uploads, &mut submitted, c, bs, "upload", Some(id))?;
                }
                if bs != site_bs {
                    send(acc, &mut uploads, &mut submitted, bs, site_bs, "upload", None)?;
                }
            }
            if let Some((from, to)) = plan.migration {
                if from != to {
                    let a = topo.edge_bs(from)?;
                    let b = topo.edge_bs(to)?;
                    send(acc, &mut uploads, &mut submitted, a, b, "migration", None)?;
                }
            }
        }
        AggregationSite::None => {
            // Sequential FL: the model hops from the previous trainer to
            // this one (client -> client).  Approximated as one model
            // transfer per round between the involved clients' BSs plus
            // the radio hops.
            let id = plan.groups[0].1[0];
            let c = topo.client(id)?;
            let bs = topo.edge_bs(plan.groups[0].0)?;
            if opts.count_downloads {
                send(acc, &mut uploads, &mut submitted, bs, c, "download", None)?;
            }
            send(acc, &mut uploads, &mut submitted, c, bs, "upload", Some(id))?;
            if let Some((from, to)) = plan.migration {
                if from != to {
                    let a = topo.edge_bs(from)?;
                    let b = topo.edge_bs(to)?;
                    send(acc, &mut uploads, &mut submitted, a, b, "migration", None)?;
                }
            }
        }
    }
    Ok(RoundComm { byte_hops: acc.byte_hops() - before, uploads, submitted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::fl::strategy::RoundPlan;
    use crate::topology::builder::{build, TopologyParams};

    fn topo(kind: TopologyKind) -> Topology {
        build(&TopologyParams::new(kind, 4, 2)).unwrap()
    }

    fn fedavg_plan() -> RoundPlan {
        RoundPlan {
            groups: vec![(usize::MAX, vec![0, 3, 5])],
            cluster: usize::MAX,
            aggregation: AggregationSite::Cloud,
            migration: None,
        }
    }

    fn edgeflow_plan(m: usize, migr: Option<(usize, usize)>) -> RoundPlan {
        let members = vec![m * 2, m * 2 + 1];
        RoundPlan {
            groups: vec![(m, members)],
            cluster: m,
            aggregation: AggregationSite::EdgeBs(m),
            migration: migr,
        }
    }

    #[test]
    fn fedavg_upload_costs_hops_to_cloud() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let r = record_round(
            &fedavg_plan(),
            &t,
            &rt,
            &mut acc,
            100,
            0,
            CommOptions::default(),
            None,
        )
        .unwrap();
        // each client: 2 hops (radio + backbone) x 100 bytes x 3 clients
        assert_eq!(r.byte_hops, 600);
        assert!(r.uploads.is_empty(), "no sim, no upload ids");
    }

    #[test]
    fn edgeflow_upload_is_one_radio_hop() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let r = record_round(
            &edgeflow_plan(1, None),
            &t,
            &rt,
            &mut acc,
            100,
            0,
            CommOptions::default(),
            None,
        )
        .unwrap();
        assert_eq!(r.byte_hops, 200); // 2 clients x 1 hop
    }

    #[test]
    fn edgeflow_migration_adds_bs_route() {
        let t = topo(TopologyKind::DepthLinear);
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        record_round(
            &edgeflow_plan(1, Some((0, 1))),
            &t,
            &rt,
            &mut acc,
            100,
            0,
            CommOptions::default(),
            None,
        )
        .unwrap();
        assert_eq!(acc.byte_hops_for("migration"), 100); // adjacent BS
        assert_eq!(acc.byte_hops_for("upload"), 200);
    }

    #[test]
    fn downloads_double_fedavg_traffic() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let mut up = CommAccountant::new();
        let mut both = CommAccountant::new();
        record_round(&fedavg_plan(), &t, &rt, &mut up, 10, 0, CommOptions::default(), None)
            .unwrap();
        record_round(
            &fedavg_plan(),
            &t,
            &rt,
            &mut both,
            10,
            0,
            CommOptions { count_downloads: true },
            None,
        )
        .unwrap();
        assert_eq!(both.byte_hops(), 2 * up.byte_hops());
    }

    #[test]
    fn hierfl_counts_cluster_and_cloud_uploads() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let plan = RoundPlan {
            groups: (0..4).map(|m| (m, vec![m * 2, m * 2 + 1])).collect(),
            cluster: usize::MAX,
            aggregation: AggregationSite::Cloud,
            migration: None,
        };
        let mut acc = CommAccountant::new();
        let r = record_round(&plan, &t, &rt, &mut acc, 10, 0, CommOptions::default(), None)
            .unwrap();
        // 8 clients x 1 radio hop x 10 + 4 BS x 1 backbone hop x 10
        assert_eq!(r.byte_hops, 120);
    }

    #[test]
    fn edge_multi_group_plans_charge_every_group() {
        // PR 1 made multi-group edge plans aggregate *all* groups; the
        // EdgeBs arm used to charge only groups[0], silently undercounting.
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let plan = RoundPlan {
            groups: vec![(0, vec![0, 1]), (2, vec![4, 5])],
            cluster: 0,
            aggregation: AggregationSite::EdgeBs(0),
            migration: None,
        };
        let mut acc = CommAccountant::new();
        let r = record_round(&plan, &t, &rt, &mut acc, 100, 0, CommOptions::default(), None)
            .unwrap();
        // 4 clients x 1 radio hop x 100 bytes (group 1 no longer dropped)
        // + the non-site group's partial riding BS2 -> BS0 (2 backbone
        // hops via the cloud on the `simple` structure) x 100 bytes.
        assert_eq!(r.byte_hops, 600);
        assert_eq!(acc.transfer_count(), 5);
        // clients upload to *their own* BS, the partial to the site BS
        let bs0 = t.edge_bs(0).unwrap();
        let bs2 = t.edge_bs(2).unwrap();
        let trs = acc.transfers();
        assert!(trs[2].dst == bs2 && trs[3].dst == bs2);
        assert_eq!(trs[4].src, bs2);
        assert_eq!(trs[4].dst, bs0);
    }

    #[test]
    fn netsim_integration_produces_latencies() {
        let t = topo(TopologyKind::Hybrid);
        let rt = RouteTable::latency(&t);
        let mut acc = CommAccountant::new();
        let mut sim = NetSim::new(&t);
        let r = record_round(
            &edgeflow_plan(2, Some((1, 2))),
            &t,
            &rt,
            &mut acc,
            1_000_000,
            0,
            CommOptions::default(),
            Some((&mut sim, &rt, 0.0)),
        )
        .unwrap();
        let out = sim.run();
        assert_eq!(out.len(), 3); // 2 uploads + 1 migration
        assert!(out.iter().all(|o| o.latency_s() > 0.0));
        // every sim submission is labeled for the trace join
        assert_eq!(r.submitted.len(), 3);
        assert_eq!(r.submitted.iter().filter(|(_, k)| *k == "upload").count(), 2);
        assert_eq!(r.submitted.iter().filter(|(_, k)| *k == "migration").count(), 1);
        // upload ids map clients onto their DES transfers
        assert_eq!(r.uploads.len(), 2);
        for &(client, sim_id) in &r.uploads {
            let o = out.iter().find(|o| o.id == sim_id).unwrap();
            assert_eq!(o.src, t.client(client).unwrap());
        }
    }

    #[test]
    fn sim_transfers_ride_the_sim_route_table() {
        // BreadthParallel BS ring: the hop-shortest BS0 -> BS5 route rides
        // the backbone (4 hops, 20 ms), the latency route rides the ring
        // (5 hops, 5 ms).  Accounting must stay on the hop routes while
        // the DES rides the latency routes it documents.
        let t = build(&TopologyParams::new(TopologyKind::BreadthParallel, 10, 1))
            .unwrap();
        let hops_rt = RouteTable::hops(&t);
        let lat_rt = RouteTable::latency(&t);
        let a = t.edge_bs(0).unwrap();
        let b = t.edge_bs(5).unwrap();
        assert!(
            hops_rt.path(a, b).unwrap().len() < lat_rt.path(a, b).unwrap().len(),
            "route tables must disagree on this topology"
        );
        let plan = RoundPlan {
            groups: vec![(5, vec![5])],
            cluster: 5,
            aggregation: AggregationSite::EdgeBs(5),
            migration: Some((0, 5)),
        };
        let mut acc = CommAccountant::new();
        let mut sim = NetSim::new(&t);
        record_round(
            &plan,
            &t,
            &hops_rt,
            &mut acc,
            1_000,
            0,
            CommOptions::default(),
            Some((&mut sim, &lat_rt, 0.0)),
        )
        .unwrap();
        let migr = acc
            .transfers()
            .iter()
            .find(|tr| tr.label == "migration")
            .unwrap();
        assert_eq!(migr.hops, 4, "accounting stays hop-shortest");
        let out = sim.run();
        let sim_migr = out.iter().find(|o| o.hops > 1).unwrap();
        assert_eq!(sim_migr.hops, 5, "the DES rides the latency route");
    }
}
