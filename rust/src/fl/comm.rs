//! Per-round communication patterns over a topology (paper Fig 4).
//!
//! Each algorithm induces a fixed transfer pattern per round; recording it
//! with [`CommAccountant`] yields the paper's "parameters uploaded per
//! round" metric (byte-hops) and, through [`crate::netsim`], transfer
//! latencies.  The paper counts *uploads* (model-parameter traffic toward
//! the aggregation point plus EdgeFLow's migration); downloads can be
//! included with [`CommOptions::count_downloads`] for the extended study.

use crate::fl::strategy::{AggregationSite, RoundPlan};
use crate::netsim::NetSim;
use crate::topology::accounting::CommAccountant;
use crate::topology::graph::Topology;
use crate::topology::route::RouteTable;
use crate::util::error::Result;

/// What to count.
#[derive(Debug, Clone, Copy)]
pub struct CommOptions {
    /// Also count model broadcast/download traffic (paper counts uploads).
    pub count_downloads: bool,
}

impl Default for CommOptions {
    fn default() -> Self {
        CommOptions { count_downloads: false }
    }
}

/// Record one round's transfers into `acc`; optionally simulate their
/// timing in `sim` (submitted at `at_s`).  Returns the byte-hops added.
#[allow(clippy::too_many_arguments)]
pub fn record_round(
    plan: &RoundPlan,
    topo: &Topology,
    routes: &RouteTable,
    acc: &mut CommAccountant,
    model_bytes: u64,
    round: usize,
    opts: CommOptions,
    mut sim: Option<(&mut NetSim, f64)>,
) -> Result<u64> {
    let before = acc.byte_hops();
    let mut send = |acc: &mut CommAccountant,
                    src,
                    dst,
                    label: &'static str|
     -> Result<()> {
        acc.record(topo, routes, src, dst, model_bytes, label, round)?;
        if let Some((sim, at_s)) = sim.as_mut() {
            sim.submit(routes, src, dst, model_bytes, *at_s)?;
        }
        Ok(())
    };

    match plan.aggregation {
        AggregationSite::Cloud => {
            let cloud = topo.cloud()?;
            if plan.groups.len() == 1 && plan.groups[0].0 == usize::MAX {
                // FedAvg: every sampled client uploads device -> cloud
                // (via its base station), and downloads the fresh model.
                for &id in &plan.groups[0].1 {
                    let c = topo.client(id)?;
                    if opts.count_downloads {
                        send(acc, cloud, c, "download")?;
                    }
                    send(acc, c, cloud, "upload")?;
                }
            } else {
                // Hierarchical FL: clients upload to their edge BS; each BS
                // uploads one cluster model to the cloud.
                for (m, members) in &plan.groups {
                    let bs = topo.edge_bs(*m)?;
                    for &id in members {
                        let c = topo.client(id)?;
                        if opts.count_downloads {
                            send(acc, bs, c, "download")?;
                        }
                        send(acc, c, bs, "upload")?;
                    }
                    if opts.count_downloads {
                        send(acc, cloud, bs, "download")?;
                    }
                    send(acc, bs, cloud, "upload")?;
                }
            }
        }
        AggregationSite::EdgeBs(m) => {
            // EdgeFLow: active cluster's clients exchange with their BS,
            // then the model migrates BS -> next BS.
            let bs = topo.edge_bs(m)?;
            for &id in &plan.groups[0].1 {
                let c = topo.client(id)?;
                if opts.count_downloads {
                    send(acc, bs, c, "download")?;
                }
                send(acc, c, bs, "upload")?;
            }
            if let Some((from, to)) = plan.migration {
                if from != to {
                    let a = topo.edge_bs(from)?;
                    let b = topo.edge_bs(to)?;
                    send(acc, a, b, "migration")?;
                }
            }
        }
        AggregationSite::None => {
            // Sequential FL: the model hops from the previous trainer to
            // this one (client -> client).  Approximated as one model
            // transfer per round between the involved clients' BSs plus
            // the radio hops.
            let id = plan.groups[0].1[0];
            let c = topo.client(id)?;
            let bs = topo.edge_bs(plan.groups[0].0)?;
            if opts.count_downloads {
                send(acc, bs, c, "download")?;
            }
            send(acc, c, bs, "upload")?;
            if let Some((from, to)) = plan.migration {
                if from != to {
                    let a = topo.edge_bs(from)?;
                    let b = topo.edge_bs(to)?;
                    send(acc, a, b, "migration")?;
                }
            }
        }
    }
    Ok(acc.byte_hops() - before)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::fl::strategy::RoundPlan;
    use crate::topology::builder::{build, TopologyParams};

    fn topo(kind: TopologyKind) -> Topology {
        build(&TopologyParams::new(kind, 4, 2)).unwrap()
    }

    fn fedavg_plan() -> RoundPlan {
        RoundPlan {
            groups: vec![(usize::MAX, vec![0, 3, 5])],
            cluster: usize::MAX,
            aggregation: AggregationSite::Cloud,
            migration: None,
        }
    }

    fn edgeflow_plan(m: usize, migr: Option<(usize, usize)>) -> RoundPlan {
        let members = vec![m * 2, m * 2 + 1];
        RoundPlan {
            groups: vec![(m, members)],
            cluster: m,
            aggregation: AggregationSite::EdgeBs(m),
            migration: migr,
        }
    }

    #[test]
    fn fedavg_upload_costs_hops_to_cloud() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let bh = record_round(
            &fedavg_plan(),
            &t,
            &rt,
            &mut acc,
            100,
            0,
            CommOptions::default(),
            None,
        )
        .unwrap();
        // each client: 2 hops (radio + backbone) x 100 bytes x 3 clients
        assert_eq!(bh, 600);
    }

    #[test]
    fn edgeflow_upload_is_one_radio_hop() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let bh = record_round(
            &edgeflow_plan(1, None),
            &t,
            &rt,
            &mut acc,
            100,
            0,
            CommOptions::default(),
            None,
        )
        .unwrap();
        assert_eq!(bh, 200); // 2 clients x 1 hop
    }

    #[test]
    fn edgeflow_migration_adds_bs_route() {
        let t = topo(TopologyKind::DepthLinear);
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        record_round(
            &edgeflow_plan(1, Some((0, 1))),
            &t,
            &rt,
            &mut acc,
            100,
            0,
            CommOptions::default(),
            None,
        )
        .unwrap();
        assert_eq!(acc.byte_hops_for("migration"), 100); // adjacent BS
        assert_eq!(acc.byte_hops_for("upload"), 200);
    }

    #[test]
    fn downloads_double_fedavg_traffic() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let mut up = CommAccountant::new();
        let mut both = CommAccountant::new();
        record_round(&fedavg_plan(), &t, &rt, &mut up, 10, 0, CommOptions::default(), None)
            .unwrap();
        record_round(
            &fedavg_plan(),
            &t,
            &rt,
            &mut both,
            10,
            0,
            CommOptions { count_downloads: true },
            None,
        )
        .unwrap();
        assert_eq!(both.byte_hops(), 2 * up.byte_hops());
    }

    #[test]
    fn hierfl_counts_cluster_and_cloud_uploads() {
        let t = topo(TopologyKind::Simple);
        let rt = RouteTable::hops(&t);
        let plan = RoundPlan {
            groups: (0..4).map(|m| (m, vec![m * 2, m * 2 + 1])).collect(),
            cluster: usize::MAX,
            aggregation: AggregationSite::Cloud,
            migration: None,
        };
        let mut acc = CommAccountant::new();
        let bh = record_round(&plan, &t, &rt, &mut acc, 10, 0, CommOptions::default(), None)
            .unwrap();
        // 8 clients x 1 radio hop x 10 + 4 BS x 1 backbone hop x 10
        assert_eq!(bh, 120);
    }

    #[test]
    fn netsim_integration_produces_latencies() {
        let t = topo(TopologyKind::Hybrid);
        let rt = RouteTable::latency(&t);
        let mut acc = CommAccountant::new();
        let mut sim = NetSim::new(&t);
        record_round(
            &edgeflow_plan(2, Some((1, 2))),
            &t,
            &rt,
            &mut acc,
            1_000_000,
            0,
            CommOptions::default(),
            Some((&mut sim, 0.0)),
        )
        .unwrap();
        let out = sim.run();
        assert_eq!(out.len(), 3); // 2 uploads + 1 migration
        assert!(out.iter().all(|o| o.latency_s() > 0.0));
    }
}
