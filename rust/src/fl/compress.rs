//! Model-transfer compression for migration and uploads.
//!
//! The paper's related-work positions EdgeFLow against transmission-volume
//! reduction (pruning [5], quantization [7]); these compose with topology
//! savings, so the coordinator ships both as migration codecs:
//!
//! * [`Codec::QuantizeInt8`] — per-tensor-chunk affine int8 quantization
//!   (4x smaller, bounded error).
//! * [`Codec::TopK`] — magnitude top-k *delta* sparsification: transmit the
//!   largest-|value| fraction of the change against a reference the
//!   receiver already has (index + value pairs).  Wire size is capped at
//!   the dense encoding: once `kept >= n/2` the 8-byte pairs would cost
//!   more than shipping all `n` values raw, so the sender falls back to a
//!   lossless dense transfer.
//! * [`Codec::None`] — the baseline.
//!
//! `roundtrip` returns both the reconstructed payload and the wire size so
//! the comm accountant can charge compressed bytes; the ablation bench in
//! `bench_fig4`'s CSV (and `edgeflow comm-sim`) multiplies the savings.

use crate::util::error::{Error, Result};

/// Chunk length for int8 quantization scales (per-chunk affine params keep
/// outliers from destroying resolution across a whole tensor).
const Q_CHUNK: usize = 1024;

/// A migration codec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Codec {
    /// Raw f32 transfer.
    None,
    /// Per-chunk affine int8.
    QuantizeInt8,
    /// Keep the top `keep_fraction` of |delta| entries (0 < f <= 1).
    TopK { keep_fraction: f64 },
}

impl Codec {
    /// Parse a CLI codec spec: `none`, `int8`, or `top<percent>` (e.g.
    /// `top10` keeps the top 10% of deltas).
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "none" => Ok(Codec::None),
            "int8" => Ok(Codec::QuantizeInt8),
            other => {
                if let Some(pct) = other.strip_prefix("top") {
                    let p: f64 = pct
                        .parse()
                        .map_err(|_| Error::Config(format!("bad codec {other:?}")))?;
                    if !(0.0 < p && p <= 100.0) {
                        return Err(Error::Config(format!(
                            "top-k percent {p} outside (0, 100]"
                        )));
                    }
                    Ok(Codec::TopK { keep_fraction: p / 100.0 })
                } else {
                    Err(Error::Config(format!("unknown codec {other:?}")))
                }
            }
        }
    }

    /// Canonical spec string accepted by [`Codec::parse`] (`top10`, not
    /// `top10%`).  The decimal percent form is exact for fractions with
    /// short decimal expansions; for the rest (e.g. 1/3) the config
    /// layer carries the exact bits alongside (`codec_keep_hex`), so
    /// checkpoint resume never sees a 1-ulp drift.
    pub fn name(&self) -> String {
        match self {
            Codec::None => "none".into(),
            Codec::QuantizeInt8 => "int8".into(),
            Codec::TopK { keep_fraction } => format!("top{}", keep_fraction * 100.0),
        }
    }

    /// Wire bytes for a payload of `n` f32 values under this codec.
    pub fn wire_bytes(&self, n: usize) -> u64 {
        match self {
            Codec::None => (n * 4) as u64,
            // int8 payload + one (scale, zero) f32 pair per chunk
            Codec::QuantizeInt8 => (n + n.div_ceil(Q_CHUNK) * 8) as u64,
            // (u32 index + f32 value) per kept entry — capped at the
            // dense 4n encoding: above 50% keep the index+value pairs
            // would cost *more* wire than shipping every value raw, so
            // the sender falls back to dense (and `roundtrip` mirrors
            // the fallback by reconstructing losslessly there).
            Codec::TopK { keep_fraction } => {
                let kept = ((n as f64) * keep_fraction).ceil() as u64;
                (kept * 8).min((n * 4) as u64)
            }
        }
    }

    /// Encode+decode `values` against `reference` (same layout the
    /// receiver holds; only used by TopK).  Returns the values as the
    /// receiver reconstructs them and the wire size in bytes.
    pub fn roundtrip(&self, values: &[f32], reference: Option<&[f32]>) -> Result<(Vec<f32>, u64)> {
        match self {
            Codec::None => Ok((values.to_vec(), self.wire_bytes(values.len()))),
            Codec::QuantizeInt8 => Ok((quantize_int8_roundtrip(values), self.wire_bytes(values.len()))),
            Codec::TopK { keep_fraction } => {
                if !(0.0 < *keep_fraction && *keep_fraction <= 1.0) {
                    return Err(Error::Config(format!(
                        "top-k keep fraction {keep_fraction} outside (0, 1]"
                    )));
                }
                let reference = reference.ok_or_else(|| {
                    Error::Config("TopK codec needs the receiver's reference state".into())
                })?;
                if reference.len() != values.len() {
                    return Err(Error::Config("TopK reference length mismatch".into()));
                }
                Ok((
                    topk_roundtrip(values, reference, *keep_fraction),
                    self.wire_bytes(values.len()),
                ))
            }
        }
    }

    /// Compression ratio vs raw f32 (lower is smaller).
    pub fn ratio(&self, n: usize) -> f64 {
        self.wire_bytes(n) as f64 / (n as f64 * 4.0)
    }
}

fn quantize_int8_roundtrip(values: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(values.len());
    for chunk in values.chunks(Q_CHUNK) {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in chunk {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() || hi <= lo {
            // constant (or empty) chunk: transmit the midpoint exactly
            out.extend(chunk.iter().copied());
            continue;
        }
        let scale = (hi - lo) / 255.0;
        for &v in chunk {
            let q = ((v - lo) / scale).round().clamp(0.0, 255.0);
            out.push(lo + q * scale);
        }
    }
    out
}

fn topk_roundtrip(values: &[f32], reference: &[f32], keep: f64) -> Vec<f32> {
    let n = values.len();
    let kept = ((n as f64) * keep).ceil() as usize;
    // Dense fallback, mirroring the `wire_bytes` cap: once the sparse
    // index+value pairs cost at least the dense 4n encoding (kept >=
    // n/2), the sender ships every value raw — lossless, at the dense
    // wire size the accountant charges.
    if kept * 8 >= n * 4 {
        return values.to_vec();
    }
    // Select the top-|delta| indices (nth-element style via sorting a key
    // vector; n is ~1e5-1e6, this is off the round hot path).  total_cmp
    // keeps the comparator a total order even under NaN deltas: NaN
    // |delta| ranks above every finite magnitude (|x| clears the sign
    // bit), so a poisoned coordinate is always *kept* — transmitted
    // as-is — instead of the old `Equal` fallback letting the sort
    // implementation decide which coordinates survive.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_unstable_by(|&a, &b| {
        let da = (values[a] - reference[a]).abs();
        let db = (values[b] - reference[b]).abs();
        db.total_cmp(&da).then(a.cmp(&b))
    });
    let mut out = reference.to_vec();
    for &i in &idx[..kept] {
        out[i] = values[i];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn none_is_identity() {
        let v = randvec(100, 1);
        let (out, bytes) = Codec::None.roundtrip(&v, None).unwrap();
        assert_eq!(out, v);
        assert_eq!(bytes, 400);
    }

    #[test]
    fn int8_error_bounded_by_half_step() {
        let v = randvec(5000, 2);
        let (out, bytes) = Codec::QuantizeInt8.roundtrip(&v, None).unwrap();
        assert!(bytes < 400 * 5000 / 100); // ~4x smaller than 20000
        let (lo, hi) = v.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &x| {
            (l.min(x), h.max(x))
        });
        let step = (hi - lo) / 255.0;
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() <= step, "{a} vs {b}");
        }
    }

    #[test]
    fn int8_constant_chunk_exact() {
        let v = vec![0.5f32; 2000];
        let (out, _) = Codec::QuantizeInt8.roundtrip(&v, None).unwrap();
        assert_eq!(out, v);
    }

    #[test]
    fn int8_ratio_about_quarter() {
        let r = Codec::QuantizeInt8.ratio(1_000_000);
        assert!(r > 0.25 && r < 0.26, "{r}");
    }

    #[test]
    fn topk_keeps_largest_deltas() {
        let reference = vec![0f32; 10];
        let mut v = reference.clone();
        v[3] = 5.0;
        v[7] = -9.0;
        v[1] = 0.01;
        let (out, bytes) =
            Codec::TopK { keep_fraction: 0.2 }.roundtrip(&v, Some(&reference)).unwrap();
        assert_eq!(out[7], -9.0);
        assert_eq!(out[3], 5.0);
        assert_eq!(out[1], 0.0); // dropped small delta
        assert_eq!(bytes, 16); // 2 kept x 8 bytes
    }

    #[test]
    fn topk_full_fraction_is_identity() {
        let reference = randvec(50, 3);
        let v = randvec(50, 4);
        let (out, _) =
            Codec::TopK { keep_fraction: 1.0 }.roundtrip(&v, Some(&reference)).unwrap();
        assert_eq!(out, v);
    }

    #[test]
    fn topk_requires_reference() {
        assert!(Codec::TopK { keep_fraction: 0.5 }.roundtrip(&[1.0], None).is_err());
        assert!(Codec::TopK { keep_fraction: 0.0 }
            .roundtrip(&[1.0], Some(&[0.0]))
            .is_err());
    }

    #[test]
    fn topk_reduces_l2_error_monotonically_in_k() {
        let reference = randvec(1000, 5);
        let v = randvec(1000, 6);
        let err = |keep: f64| -> f64 {
            let (out, _) = Codec::TopK { keep_fraction: keep }
                .roundtrip(&v, Some(&reference))
                .unwrap();
            v.iter().zip(&out).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        // Fractions below the dense-fallback threshold stay sparse and
        // lossy; at >= 50% keep the fallback makes the error exactly 0.
        assert!(err(0.3) < err(0.1));
        assert!(err(0.45) < err(0.3));
        assert_eq!(err(0.5), 0.0, "dense fallback is lossless");
    }

    #[test]
    fn topk_above_half_keep_never_exceeds_dense_wire() {
        // Regression: `top60`..`top100` used to charge kept * 8 bytes
        // with no cap, i.e. *more* wire than a raw dense transfer.
        let n = 1000;
        let dense = Codec::None.wire_bytes(n);
        for pct in [51.0, 60.0, 75.0, 100.0] {
            let codec = Codec::TopK { keep_fraction: pct / 100.0 };
            assert_eq!(codec.wire_bytes(n), dense, "top{pct}");
            assert!(codec.ratio(n) <= 1.0, "top{pct}");
        }
        assert!(
            Codec::TopK { keep_fraction: 1.0 }.wire_bytes(n)
                <= Codec::None.wire_bytes(n)
        );
        // Below the threshold the sparse encoding still pays off, and the
        // boundary (kept == n/2, 8 bytes/entry == dense) sits exactly at
        // the dense size.
        assert_eq!(Codec::TopK { keep_fraction: 0.4 }.wire_bytes(n), 3200);
        assert_eq!(Codec::TopK { keep_fraction: 0.5 }.wire_bytes(n), dense);
        // The payload mirrors the accounting: at dense wire size the
        // reconstruction is lossless.
        let reference = randvec(64, 7);
        let v = randvec(64, 8);
        let (out, bytes) = Codec::TopK { keep_fraction: 0.6 }
            .roundtrip(&v, Some(&reference))
            .unwrap();
        assert_eq!(out, v, "dense fallback ships the exact values");
        assert_eq!(bytes, Codec::None.wire_bytes(64));
    }

    #[test]
    fn topk_with_nan_delta_is_deterministic_and_keeps_nan() {
        // Regression for the NaN-unsound comparator: the old
        // `partial_cmp(..).unwrap_or(Equal)` fallback made every
        // NaN-vs-x comparison "equal", handing the sort implementation
        // the choice of which coordinates survive.  With total_cmp a
        // NaN |delta| ranks above every finite magnitude, so the
        // poisoned coordinate is deterministically part of the kept
        // set and the other kept indices are stable across runs.
        let n = 100;
        let reference = vec![0f32; n];
        let mut v: Vec<f32> = (0..n).map(|i| i as f32 * 1e-3).collect();
        let large = [3usize, 11, 19, 42, 55, 60, 71, 83, 96];
        for &j in &large {
            v[j] = 5.0 + j as f32;
        }
        v[37] = f32::NAN;

        let codec = Codec::TopK { keep_fraction: 0.1 }; // kept = 10 < n/2
        let kept_set = |out: &[f32]| -> Vec<usize> {
            (0..n)
                .filter(|&i| out[i].is_nan() || out[i].to_bits() != reference[i].to_bits())
                .collect()
        };

        let (out1, _) = codec.roundtrip(&v, Some(&reference)).unwrap();
        let (out2, _) = codec.roundtrip(&v, Some(&reference)).unwrap();

        let mut expected: Vec<usize> = large.to_vec();
        expected.push(37);
        expected.sort_unstable();
        assert_eq!(kept_set(&out1), expected, "NaN + 9 largest deltas kept");
        assert_eq!(kept_set(&out1), kept_set(&out2), "same index set across runs");
        assert!(out1[37].is_nan(), "poisoned coordinate transmitted as-is");
        for (a, b) in out1.iter().zip(&out2) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-identical reconstruction");
        }
    }

    #[test]
    fn names_roundtrip_through_parse() {
        for codec in [
            Codec::None,
            Codec::QuantizeInt8,
            Codec::TopK { keep_fraction: 0.1 },
            Codec::TopK { keep_fraction: 0.125 },
        ] {
            assert_eq!(Codec::parse(&codec.name()).unwrap(), codec);
        }
    }

    #[test]
    fn wire_bytes_sane() {
        assert_eq!(Codec::None.wire_bytes(10), 40);
        assert_eq!(Codec::TopK { keep_fraction: 0.1 }.wire_bytes(100), 80);
        // int8: 100 bytes payload + 1 chunk x 8 bytes params
        assert_eq!(Codec::QuantizeInt8.wire_bytes(100), 108);
    }
}
