//! Theorem 1 (Eq. 8): the convergence bound, term by term.
//!
//! Used by the `sweep --theory` CLI and the Fig 3 bench to juxtapose the
//! measured curves with the bound's predictions: larger `N_m` shrinks the
//! variance term (Fig 3a), while `K` appears in both the numerator of the
//! drift term and the denominator of the init term, making the bound
//! non-monotonic in `K` (Fig 3b).

/// Problem constants for the bound (Assumptions 1–3).
#[derive(Debug, Clone)]
pub struct TheoryParams {
    /// L-smoothness constant.
    pub l: f64,
    /// Gradient second-moment bound G².
    pub g2: f64,
    /// Stochastic-gradient variance bound σ².
    pub sigma2: f64,
    /// F(θ⁰) − F*.
    pub init_gap: f64,
    /// Learning rate η.
    pub eta: f64,
    /// Local steps K.
    pub k: usize,
    /// Rounds T.
    pub t: usize,
    /// Cluster heterogeneity bounds λ²_{m(t)} per round (len T, or len 1
    /// to broadcast).
    pub lambda2: Vec<f64>,
    /// Cluster sizes N_{m(t)} per round (len T or 1).
    pub n_m: Vec<usize>,
}

/// The four terms of Eq. 8 and their total.
#[derive(Debug, Clone, PartialEq)]
pub struct BoundTerms {
    /// 4 (F⁰ − F*) / (K η T)
    pub init: f64,
    /// (2/T) Σ λ²_{m(t)}
    pub heterogeneity: f64,
    /// (2/T) Σ L η σ² / N_{m(t)}
    pub variance: f64,
    /// 4 L² K² η² G² / 3
    pub drift: f64,
}

impl BoundTerms {
    pub fn total(&self) -> f64 {
        self.init + self.heterogeneity + self.variance + self.drift
    }
}

fn broadcast<T: Copy>(xs: &[T], t: usize, what: &str) -> Vec<T> {
    match xs.len() {
        1 => vec![xs[0]; t],
        n if n == t => xs.to_vec(),
        // lint:allow(unwrap-in-library): documented contract of the
        // theory evaluator (see `bound`'s doc comment) — malformed
        // per-round vectors are a caller bug, pinned by should_panic
        // tests, not a runtime condition to recover from.
        n => panic!("{what} has {n} entries, want 1 or {t}"),
    }
}

/// Evaluate Eq. 8.  Panics if `eta` violates the step-size condition
/// `L K η < 1` (the theorem's hypothesis).
pub fn bound(p: &TheoryParams) -> BoundTerms {
    assert!(p.t > 0 && p.k > 0);
    assert!(
        p.l * p.k as f64 * p.eta < 1.0,
        "step-size condition LKη < 1 violated (L={} K={} η={})",
        p.l,
        p.k,
        p.eta
    );
    let t = p.t as f64;
    let k = p.k as f64;
    let lambda2 = broadcast(&p.lambda2, p.t, "lambda2");
    let n_m = broadcast(&p.n_m, p.t, "n_m");
    BoundTerms {
        init: 4.0 * p.init_gap / (k * p.eta * t),
        heterogeneity: 2.0 / t * lambda2.iter().sum::<f64>(),
        variance: 2.0 / t
            * n_m
                .iter()
                .map(|&n| p.l * p.eta * p.sigma2 / n as f64)
                .sum::<f64>(),
        drift: 4.0 * p.l * p.l * k * k * p.eta * p.eta * p.g2 / 3.0,
    }
}

/// The largest admissible K for the step-size condition at a given η.
pub fn max_k(l: f64, eta: f64) -> usize {
    ((1.0 / (l * eta)).ceil() as usize).saturating_sub(1).max(1)
}

/// Scan the bound over K (Fig 3b's theoretical companion): returns
/// (K, total bound) pairs for K in `1..=k_max` with the condition held.
pub fn k_scan(base: &TheoryParams, k_max: usize) -> Vec<(usize, f64)> {
    (1..=k_max)
        .filter(|&k| base.l * k as f64 * base.eta < 1.0)
        .map(|k| {
            let p = TheoryParams { k, ..base.clone() };
            (k, bound(&p).total())
        })
        .collect()
}

/// Heterogeneity proxy λ²_m from class histograms: squared L2 distance
/// between the cluster's class distribution and the global one, scaled by
/// G² (a standard surrogate when true gradient diversity is unavailable;
/// see DESIGN.md).
pub fn lambda2_proxy(cluster_hist: &[usize], global_hist: &[usize], g2: f64) -> f64 {
    let cs: f64 = cluster_hist.iter().sum::<usize>() as f64;
    let gs: f64 = global_hist.iter().sum::<usize>() as f64;
    assert!(cs > 0.0 && gs > 0.0);
    let d2: f64 = cluster_hist
        .iter()
        .zip(global_hist)
        .map(|(&c, &g)| {
            let d = c as f64 / cs - g as f64 / gs;
            d * d
        })
        .sum();
    g2 * d2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> TheoryParams {
        TheoryParams {
            l: 1.0,
            g2: 1.0,
            sigma2: 1.0,
            init_gap: 1.0,
            eta: 0.01,
            k: 5,
            t: 100,
            lambda2: vec![0.1],
            n_m: vec![10],
        }
    }

    #[test]
    fn terms_match_formula() {
        let b = bound(&base());
        assert!((b.init - 4.0 / (5.0 * 0.01 * 100.0)).abs() < 1e-12);
        assert!((b.heterogeneity - 0.2).abs() < 1e-12);
        assert!((b.variance - 2.0 * 0.01 / 10.0).abs() < 1e-12);
        assert!((b.drift - 4.0 * 25.0 * 1e-4 / 3.0).abs() < 1e-12);
        assert!(b.total() > 0.0);
    }

    #[test]
    fn larger_clusters_shrink_variance() {
        // Fig 3a's prediction.
        let mut small = base();
        small.n_m = vec![5];
        let mut large = base();
        large.n_m = vec![50];
        assert!(bound(&large).variance < bound(&small).variance);
        assert!(bound(&large).total() < bound(&small).total());
    }

    #[test]
    fn bound_is_nonmonotonic_in_k() {
        // Fig 3b's prediction: some interior K beats both extremes.
        let mut p = base();
        p.eta = 0.02;
        p.g2 = 5.0;
        let scan = k_scan(&p, 40);
        let totals: Vec<f64> = scan.iter().map(|&(_, v)| v).collect();
        let best = totals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert!(best > 0, "best K should not be K=1 here");
        assert!(best < totals.len() - 1, "best K should not be the max");
    }

    #[test]
    fn per_round_vectors_accepted() {
        let mut p = base();
        p.lambda2 = (0..100).map(|i| 0.001 * i as f64).collect();
        p.n_m = vec![10; 100];
        let b = bound(&p);
        assert!(b.heterogeneity > 0.0);
    }

    #[test]
    #[should_panic(expected = "LKη < 1")]
    fn step_condition_enforced() {
        let mut p = base();
        p.eta = 0.5; // LKη = 2.5
        bound(&p);
    }

    #[test]
    fn max_k_respects_condition() {
        let k = max_k(1.0, 0.01);
        assert!(1.0 * k as f64 * 0.01 < 1.0);
        assert!(1.0 * (k + 1) as f64 * 0.01 >= 1.0);
    }

    #[test]
    fn lambda2_proxy_zero_for_identical() {
        let g = vec![10, 10, 10];
        assert_eq!(lambda2_proxy(&g, &g, 4.0), 0.0);
        let skew = vec![30, 0, 0];
        assert!(lambda2_proxy(&skew, &g, 4.0) > 0.0);
    }
}
