//! Model aggregation — the coordinator's hot path (paper Eq. 3).
//!
//! A base station averages `N_m` client states of ~10^5..10^6 f32 each,
//! every round.  The kernels below are written to be memory-bandwidth
//! bound: a single pass over each source, accumulating into the
//! destination, with a fused final scale.  (See EXPERIMENTS.md §Perf for
//! the measured GB/s and the iteration log.)
//!
//! The round loop reduces through [`reduce_states_weighted`] /
//! [`par_reduce_states_weighted`] — a fixed-order pairwise tree whose
//! merge structure depends only on the operand count and order, so a run
//! is bit-identical at any `--workers` setting.  The flat kernels below
//! remain the single-thread bandwidth reference the tree is tested
//! against.

use crate::runtime::params::ModelState;
use crate::runtime::pool::WorkerPool;
use crate::util::error::{Error, Result};
use std::sync::Mutex;

/// Chunk size for cache-blocked accumulation: 8192 f32 = 32 KiB, sized so
/// the destination chunk stays L1-resident while every source streams
/// through it once.  (Unblocked accumulation re-streams `dst` from DRAM
/// once per source — measured 1.9x slower at 10x1M; EXPERIMENTS.md §Perf.)
const AGG_CHUNK: usize = 8192;

/// dst = mean(sources), uniform weights.  All slices must be equal length.
pub fn mean_into(dst: &mut [f32], sources: &[&[f32]]) {
    assert!(!sources.is_empty(), "mean of zero sources");
    let n = dst.len();
    for s in sources {
        assert_eq!(s.len(), n, "source length mismatch");
    }
    let inv = 1.0 / sources.len() as f32;
    let mut off = 0;
    while off < n {
        let end = (off + AGG_CHUNK).min(n);
        let chunk = &mut dst[off..end];
        chunk.copy_from_slice(&sources[0][off..end]);
        for s in &sources[1..] {
            for (d, &v) in chunk.iter_mut().zip(&s[off..end]) {
                *d += v;
            }
        }
        for d in chunk.iter_mut() {
            *d *= inv;
        }
        off = end;
    }
}

/// dst = sum_i w_i * s_i with w normalized to 1.  Weights must be
/// non-negative and not all zero.
pub fn weighted_mean_into(dst: &mut [f32], sources: &[&[f32]], weights: &[f64]) {
    assert_eq!(sources.len(), weights.len());
    assert!(!sources.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "all-zero aggregation weights");
    let n = dst.len();
    for s in sources.iter() {
        assert_eq!(s.len(), n);
    }
    let wf: Vec<f32> = weights.iter().map(|&w| (w / total) as f32).collect();
    let mut off = 0;
    while off < n {
        let end = (off + AGG_CHUNK).min(n);
        let chunk = &mut dst[off..end];
        chunk.fill(0.0);
        for (s, &w) in sources.iter().zip(&wf) {
            for (d, &v) in chunk.iter_mut().zip(&s[off..end]) {
                *d += w * v;
            }
        }
        off = end;
    }
}

/// dst = (w_dst * dst + w_src * src) / (w_dst + w_src) — one pairwise
/// merge step of the reduction tree.  Weight math runs in f64; the blend
/// itself is a single fused pass in f32.
pub fn merge_weighted_into(dst: &mut [f32], w_dst: f64, src: &[f32], w_src: f64) {
    assert_eq!(dst.len(), src.len(), "merge length mismatch");
    let total = w_dst + w_src;
    assert!(total > 0.0, "all-zero aggregation weights");
    let a = (w_dst / total) as f32;
    let b = (w_src / total) as f32;
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = a * *d + b * v;
    }
}

/// Validate a reduction input and drop zero-weight items (their
/// contribution is exactly zero, and removing them keeps every pairwise
/// merge's weight sum positive).  Typed errors — not panics — for empty
/// input, mismatched layouts, and degenerate weights, matching the rest
/// of this module's error discipline.
fn check_reduce_input(items: Vec<(f64, ModelState)>) -> Result<Vec<(f64, ModelState)>> {
    if items.is_empty() {
        return Err(Error::Data("aggregate of zero states".into()));
    }
    let total = items[0].1.layout.total;
    for (w, s) in &items {
        if s.layout.total != total {
            return Err(Error::Data("aggregate over mismatched layouts".into()));
        }
        if !w.is_finite() || *w < 0.0 {
            return Err(Error::Data(format!("bad aggregation weight {w}")));
        }
    }
    let kept: Vec<(f64, ModelState)> =
        items.into_iter().filter(|(w, _)| *w > 0.0).collect();
    if kept.is_empty() {
        return Err(Error::Data("all-zero aggregation weights".into()));
    }
    Ok(kept)
}

/// Weighted average of `(weight, state)` pairs by a **fixed-order pairwise
/// tree**: level by level, adjacent pairs `(2i, 2i+1)` merge (an odd tail
/// carries over), so the merge tree — and therefore every f32 rounding
/// decision — is a function of the item count and order alone.  Returns
/// the merged state together with the summed weight, so partial
/// aggregates compose: group-level results feed straight into the
/// cross-group reduction with their total sample counts as weights
/// (paper Eq. 3 applied twice).
pub fn reduce_states_weighted(items: Vec<(f64, ModelState)>) -> Result<(f64, ModelState)> {
    Ok(reduce_prepared(check_reduce_input(items)?))
}

/// The sequential tree over an already-validated input.
fn reduce_prepared(mut level: Vec<(f64, ModelState)>) -> (f64, ModelState) {
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some((wa, mut a)) = it.next() {
            match it.next() {
                Some((wb, b)) => {
                    merge_weighted_into(&mut a.data, wa, &b.data, wb);
                    next.push((wa + wb, a));
                }
                None => next.push((wa, a)),
            }
        }
        level = next;
    }
    // lint:allow(unwrap-in-library): check_reduce_input rejects empty
    // inputs, and every halving level keeps at least one item.
    level.pop().expect("non-empty reduction")
}

/// [`reduce_states_weighted`] with the merges of each tree level fanned
/// out across `pool`.  The tree structure is identical to the sequential
/// version and each merge touches the same operands in the same order,
/// so the result is **bit-identical at any worker count** — workers only
/// decide *who* executes a merge, never *which* merges happen.
pub fn par_reduce_states_weighted(
    items: Vec<(f64, ModelState)>,
    pool: &WorkerPool,
) -> Result<(f64, ModelState)> {
    let items = check_reduce_input(items)?;
    if pool.workers() <= 1 || items.len() <= 2 {
        return Ok(reduce_prepared(items));
    }
    let mut level = items;
    while level.len() > 1 {
        // Hand each adjacent pair to the pool as an owned job slot; the
        // odd tail (if any) carries to the next level unmerged.
        let mut pairs: Vec<Mutex<Option<((f64, ModelState), (f64, ModelState))>>> =
            Vec::with_capacity(level.len() / 2);
        let mut tail = None;
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => pairs.push(Mutex::new(Some((a, b)))),
                None => tail = Some(a),
            }
        }
        let mut next = pool.run(pairs.len(), |i, _w| {
            // lint:allow(unwrap-in-library): the pool hands each job
            // index to exactly one worker, so slot i is locked and
            // taken exactly once.
            let pair = pairs[i].lock().unwrap().take().expect("pair taken once");
            let ((wa, mut a), (wb, b)) = pair;
            merge_weighted_into(&mut a.data, wa, &b.data, wb);
            (wa + wb, a)
        });
        next.extend(tail);
        level = next;
    }
    // lint:allow(unwrap-in-library): same non-empty invariant as the
    // sequential tree above.
    Ok(level.pop().expect("non-empty reduction"))
}

/// Average full model states (params ++ BN stats ++ optimizer state).
///
/// Averaging the optimizer moments alongside the parameters keeps the
/// migrated Adam state meaningful at the next cluster; this is the
/// EdgeFLow analogue of the server optimizer state in FedAvg systems.
pub fn aggregate_states(states: &[ModelState], weights: Option<&[f64]>) -> Result<ModelState> {
    if states.is_empty() {
        return Err(Error::Data("aggregate of zero states".into()));
    }
    let layout = states[0].layout.clone();
    for s in states {
        if s.layout.total != layout.total {
            return Err(Error::Data("aggregate over mismatched layouts".into()));
        }
    }
    let mut out = ModelState::zeros(layout);
    let srcs: Vec<&[f32]> = states.iter().map(|s| s.data.as_slice()).collect();
    match weights {
        Some(w) => weighted_mean_into(&mut out.data, &srcs, w),
        None => mean_into(&mut out.data, &srcs),
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{TensorSpec, VariantSpec};
    use crate::runtime::params::StateLayout;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn tiny_layout() -> Arc<StateLayout> {
        let v = VariantSpec {
            name: "t".into(),
            arch: "mlp".into(),
            image: (1, 1, 1),
            classes: 2,
            train_batch: 1,
            eval_batch: 1,
            k_values: vec![1],
            optimizers: vec!["sgd".into()],
            params: vec![TensorSpec { name: "w".into(), shape: vec![4] }],
            bn_state: vec![],
            opt_state: BTreeMap::from([("sgd".to_string(), vec![])]),
            init_blob: BTreeMap::new(),
            eval_exe: "e".into(),
            local_update: BTreeMap::new(),
        };
        StateLayout::new(&v, "sgd").unwrap()
    }

    #[test]
    fn mean_matches_manual() {
        let mut dst = vec![0f32; 3];
        mean_into(&mut dst, &[&[1.0, 2.0, 3.0], &[3.0, 4.0, 5.0]]);
        assert_eq!(dst, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn mean_of_identical_is_identity() {
        let src = vec![0.5f32, -1.25, 7.0];
        let mut dst = vec![0f32; 3];
        mean_into(&mut dst, &[&src, &src, &src]);
        assert_eq!(dst, src);
    }

    #[test]
    fn weighted_mean_normalizes() {
        let mut dst = vec![0f32; 2];
        weighted_mean_into(&mut dst, &[&[1.0, 0.0], &[0.0, 1.0]], &[3.0, 1.0]);
        assert!((dst[0] - 0.75).abs() < 1e-6);
        assert!((dst[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn aggregate_states_uniform() {
        let l = tiny_layout();
        let mut a = ModelState::zeros(l.clone());
        let mut b = ModelState::zeros(l);
        a.data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.data.copy_from_slice(&[3.0, 2.0, 1.0, 0.0]);
        let m = aggregate_states(&[a, b], None).unwrap();
        assert_eq!(m.data, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn aggregate_rejects_empty() {
        assert!(aggregate_states(&[], None).is_err());
    }

    #[test]
    fn convexity_envelope() {
        // Result stays within [min, max] of the sources componentwise.
        let l = tiny_layout();
        let mut rng = crate::rng::Rng::new(3);
        let states: Vec<ModelState> = (0..5)
            .map(|_| {
                let mut s = ModelState::zeros(l.clone());
                for v in &mut s.data {
                    *v = rng.f32() * 10.0 - 5.0;
                }
                s
            })
            .collect();
        let w: Vec<f64> = (0..5).map(|_| rng.f64() + 0.01).collect();
        let m = aggregate_states(&states, Some(&w)).unwrap();
        for j in 0..4 {
            let lo = states.iter().map(|s| s.data[j]).fold(f32::INFINITY, f32::min);
            let hi = states.iter().map(|s| s.data[j]).fold(f32::NEG_INFINITY, f32::max);
            assert!(m.data[j] >= lo - 1e-5 && m.data[j] <= hi + 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn zero_weights_panic() {
        let mut dst = vec![0f32; 1];
        weighted_mean_into(&mut dst, &[&[1.0]], &[0.0]);
    }

    fn random_states(n: usize, seed: u64) -> Vec<(f64, ModelState)> {
        let l = tiny_layout();
        let mut rng = crate::rng::Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut s = ModelState::zeros(l.clone());
                for v in &mut s.data {
                    *v = rng.f32() * 4.0 - 2.0;
                }
                (rng.f64() * 100.0 + 1.0, s)
            })
            .collect()
    }

    #[test]
    fn merge_weighted_is_convex_blend() {
        let mut dst = vec![1.0f32, 0.0];
        merge_weighted_into(&mut dst, 3.0, &[0.0, 1.0], 1.0);
        assert!((dst[0] - 0.75).abs() < 1e-6);
        assert!((dst[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn tree_reduce_single_item_is_identity() {
        let items = random_states(1, 11);
        let (w0, expect) = (items[0].0, items[0].1.data.clone());
        let (w, s) = reduce_states_weighted(items).unwrap();
        assert_eq!(w, w0);
        assert_eq!(s.data, expect);
    }

    #[test]
    fn tree_reduce_matches_flat_weighted_mean() {
        for n in [2usize, 3, 5, 8, 13] {
            let items = random_states(n, n as u64);
            let weights: Vec<f64> = items.iter().map(|(w, _)| *w).collect();
            let states: Vec<ModelState> =
                items.iter().map(|(_, s)| s.clone()).collect();
            let flat = aggregate_states(&states, Some(&weights)).unwrap();
            let (w, tree) = reduce_states_weighted(items).unwrap();
            assert!((w - weights.iter().sum::<f64>()).abs() < 1e-9);
            for (a, b) in tree.data.iter().zip(&flat.data) {
                // Different summation orders: equal up to f32 rounding.
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn tree_reduce_honors_unbalanced_weights() {
        // Two clients, one with 3x the data: the aggregate must sit at
        // the 3:1 point, not the midpoint (the Eq. 3 bugfix).
        let l = tiny_layout();
        let mut a = ModelState::zeros(l.clone());
        let mut b = ModelState::zeros(l);
        a.data.copy_from_slice(&[4.0, 4.0, 4.0, 4.0]);
        b.data.copy_from_slice(&[0.0, 0.0, 0.0, 0.0]);
        let (_, weighted) =
            reduce_states_weighted(vec![(300.0, a.clone()), (100.0, b.clone())]).unwrap();
        assert_eq!(weighted.data, vec![3.0, 3.0, 3.0, 3.0]);
        let (_, uniform) = reduce_states_weighted(vec![(1.0, a), (1.0, b)]).unwrap();
        assert_eq!(uniform.data, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn par_reduce_bit_identical_at_any_worker_count() {
        for n in [2usize, 3, 7, 16, 33] {
            let seq =
                reduce_states_weighted(random_states(n, 77 + n as u64)).unwrap();
            for workers in [1usize, 2, 4, 8] {
                let pool = WorkerPool::new(workers);
                let par =
                    par_reduce_states_weighted(random_states(n, 77 + n as u64), &pool)
                        .unwrap();
                assert_eq!(par.0.to_bits(), seq.0.to_bits(), "n={n} w={workers}");
                assert_eq!(par.1.data, seq.1.data, "n={n} w={workers}");
            }
        }
    }

    #[test]
    fn tree_reduce_rejects_empty_and_mismatched() {
        assert!(reduce_states_weighted(vec![]).is_err());
        assert!(par_reduce_states_weighted(vec![], &WorkerPool::new(4)).is_err());
    }

    #[test]
    fn tree_reduce_zero_weights_are_typed_errors_or_dropped() {
        let l = tiny_layout();
        let mut a = ModelState::zeros(l.clone());
        let b = ModelState::zeros(l.clone());
        a.data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        // All-zero weights: a typed error, not a panic.
        assert!(
            reduce_states_weighted(vec![(0.0, a.clone()), (0.0, b.clone())]).is_err()
        );
        // A zero-weight member contributes nothing.
        let (w, m) = reduce_states_weighted(vec![(0.0, b), (2.0, a.clone())]).unwrap();
        assert_eq!(w, 2.0);
        assert_eq!(m.data, a.data);
        // Negative / non-finite weights are rejected.
        let c = ModelState::zeros(l);
        assert!(reduce_states_weighted(vec![(-1.0, c.clone())]).is_err());
        assert!(reduce_states_weighted(vec![(f64::NAN, c)]).is_err());
    }
}
