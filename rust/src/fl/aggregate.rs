//! Model aggregation — the coordinator's hot path (paper Eq. 3).
//!
//! A base station averages `N_m` client states of ~10^5..10^6 f32 each,
//! every round.  The kernels below are written to be memory-bandwidth
//! bound: a single pass over each source, accumulating into the
//! destination, with a fused final scale.  (See EXPERIMENTS.md §Perf for
//! the measured GB/s and the iteration log.)

use crate::runtime::params::ModelState;
use crate::util::error::{Error, Result};

/// Chunk size for cache-blocked accumulation: 8192 f32 = 32 KiB, sized so
/// the destination chunk stays L1-resident while every source streams
/// through it once.  (Unblocked accumulation re-streams `dst` from DRAM
/// once per source — measured 1.9x slower at 10x1M; EXPERIMENTS.md §Perf.)
const AGG_CHUNK: usize = 8192;

/// dst = mean(sources), uniform weights.  All slices must be equal length.
pub fn mean_into(dst: &mut [f32], sources: &[&[f32]]) {
    assert!(!sources.is_empty(), "mean of zero sources");
    let n = dst.len();
    for s in sources {
        assert_eq!(s.len(), n, "source length mismatch");
    }
    let inv = 1.0 / sources.len() as f32;
    let mut off = 0;
    while off < n {
        let end = (off + AGG_CHUNK).min(n);
        let chunk = &mut dst[off..end];
        chunk.copy_from_slice(&sources[0][off..end]);
        for s in &sources[1..] {
            for (d, &v) in chunk.iter_mut().zip(&s[off..end]) {
                *d += v;
            }
        }
        for d in chunk.iter_mut() {
            *d *= inv;
        }
        off = end;
    }
}

/// dst = sum_i w_i * s_i with w normalized to 1.  Weights must be
/// non-negative and not all zero.
pub fn weighted_mean_into(dst: &mut [f32], sources: &[&[f32]], weights: &[f64]) {
    assert_eq!(sources.len(), weights.len());
    assert!(!sources.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "all-zero aggregation weights");
    let n = dst.len();
    for s in sources.iter() {
        assert_eq!(s.len(), n);
    }
    let wf: Vec<f32> = weights.iter().map(|&w| (w / total) as f32).collect();
    let mut off = 0;
    while off < n {
        let end = (off + AGG_CHUNK).min(n);
        let chunk = &mut dst[off..end];
        chunk.fill(0.0);
        for (s, &w) in sources.iter().zip(&wf) {
            for (d, &v) in chunk.iter_mut().zip(&s[off..end]) {
                *d += w * v;
            }
        }
        off = end;
    }
}

/// Average full model states (params ++ BN stats ++ optimizer state).
///
/// Averaging the optimizer moments alongside the parameters keeps the
/// migrated Adam state meaningful at the next cluster; this is the
/// EdgeFLow analogue of the server optimizer state in FedAvg systems.
pub fn aggregate_states(states: &[ModelState], weights: Option<&[f64]>) -> Result<ModelState> {
    if states.is_empty() {
        return Err(Error::Data("aggregate of zero states".into()));
    }
    let layout = states[0].layout.clone();
    for s in states {
        if s.layout.total != layout.total {
            return Err(Error::Data("aggregate over mismatched layouts".into()));
        }
    }
    let mut out = ModelState::zeros(layout);
    let srcs: Vec<&[f32]> = states.iter().map(|s| s.data.as_slice()).collect();
    match weights {
        Some(w) => weighted_mean_into(&mut out.data, &srcs, w),
        None => mean_into(&mut out.data, &srcs),
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{TensorSpec, VariantSpec};
    use crate::runtime::params::StateLayout;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn tiny_layout() -> Arc<StateLayout> {
        let v = VariantSpec {
            name: "t".into(),
            arch: "mlp".into(),
            image: (1, 1, 1),
            classes: 2,
            train_batch: 1,
            eval_batch: 1,
            k_values: vec![1],
            optimizers: vec!["sgd".into()],
            params: vec![TensorSpec { name: "w".into(), shape: vec![4] }],
            bn_state: vec![],
            opt_state: BTreeMap::from([("sgd".to_string(), vec![])]),
            init_blob: BTreeMap::new(),
            eval_exe: "e".into(),
            local_update: BTreeMap::new(),
        };
        StateLayout::new(&v, "sgd").unwrap()
    }

    #[test]
    fn mean_matches_manual() {
        let mut dst = vec![0f32; 3];
        mean_into(&mut dst, &[&[1.0, 2.0, 3.0], &[3.0, 4.0, 5.0]]);
        assert_eq!(dst, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn mean_of_identical_is_identity() {
        let src = vec![0.5f32, -1.25, 7.0];
        let mut dst = vec![0f32; 3];
        mean_into(&mut dst, &[&src, &src, &src]);
        assert_eq!(dst, src);
    }

    #[test]
    fn weighted_mean_normalizes() {
        let mut dst = vec![0f32; 2];
        weighted_mean_into(&mut dst, &[&[1.0, 0.0], &[0.0, 1.0]], &[3.0, 1.0]);
        assert!((dst[0] - 0.75).abs() < 1e-6);
        assert!((dst[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn aggregate_states_uniform() {
        let l = tiny_layout();
        let mut a = ModelState::zeros(l.clone());
        let mut b = ModelState::zeros(l);
        a.data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.data.copy_from_slice(&[3.0, 2.0, 1.0, 0.0]);
        let m = aggregate_states(&[a, b], None).unwrap();
        assert_eq!(m.data, vec![2.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn aggregate_rejects_empty() {
        assert!(aggregate_states(&[], None).is_err());
    }

    #[test]
    fn convexity_envelope() {
        // Result stays within [min, max] of the sources componentwise.
        let l = tiny_layout();
        let mut rng = crate::rng::Rng::new(3);
        let states: Vec<ModelState> = (0..5)
            .map(|_| {
                let mut s = ModelState::zeros(l.clone());
                for v in &mut s.data {
                    *v = rng.f32() * 10.0 - 5.0;
                }
                s
            })
            .collect();
        let w: Vec<f64> = (0..5).map(|_| rng.f64() + 0.01).collect();
        let m = aggregate_states(&states, Some(&w)).unwrap();
        for j in 0..4 {
            let lo = states.iter().map(|s| s.data[j]).fold(f32::INFINITY, f32::min);
            let hi = states.iter().map(|s| s.data[j]).fold(f32::NEG_INFINITY, f32::max);
            assert!(m.data[j] >= lo - 1e-5 && m.data[j] <= hi + 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn zero_weights_panic() {
        let mut dst = vec![0f32; 1];
        weighted_mean_into(&mut dst, &[&[1.0]], &[0.0]);
    }
}
