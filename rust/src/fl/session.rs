//! Stepwise round-session vocabulary.
//!
//! [`crate::fl::runner::Runner::step`] executes exactly one round of
//! Algorithm 1 and returns a typed [`RoundOutcome`]; callers that need
//! more than "run to completion" (schedulers, controllers, checkpointers,
//! experiment drivers) compose with the round loop through this module
//! instead of patching the loop itself:
//!
//! * [`RoundObserver`] — hooks into the phases of a round (`on_plan`,
//!   `on_comm`, `on_aggregate`, `on_round_end`).  Progress logging and
//!   live metrics export ship as built-in observers
//!   ([`ProgressObserver`], [`MetricsCsvObserver`]).
//! * [`RoundControl`] — the observer return channel: request an early
//!   stop or adjust the round deadline (per-cluster adaptive deadlines
//!   are an observer, not runner surgery).
//! * [`DeferredPool`] — session state behind straggler *re-inclusion*
//!   (`straggler_policy = defer`): a late update is held here with its
//!   Eq. 3 sample weight and folded into the next reduction instead of
//!   being discarded.

use crate::fl::comm::RoundComm;
use crate::fl::strategy::RoundPlan;
use crate::metrics::{ExperimentMetrics, RoundRecord};
use crate::obs::{TraceLevel, Tracer};
use crate::runtime::params::ModelState;
use crate::util::csv::CsvWriter;
use std::collections::BTreeMap;

/// Why a round trained nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LostCause {
    /// Failure injection removed every selected client before upload; no
    /// traffic moved, the sim clock did not advance.  Pending deferred
    /// updates stay held: a round that never touches the network cannot
    /// transport them, so they fold into the next communicating round.
    AllDropped,
    /// Every surviving upload missed the deadline (and, under `defer`,
    /// no earlier-round update was pending): traffic was spent but
    /// nothing aggregated.
    AllStraggled,
}

/// Typed result of executing exactly one round.
#[derive(Debug, Clone)]
pub enum RoundOutcome {
    /// The round aggregated: the global model moved.
    Completed {
        record: RoundRecord,
        /// BS -> BS model migration this round rode in on
        /// (EdgeFLow/SeqFL), as `(from_cluster, to_cluster)`.
        migration: Option<(usize, usize)>,
    },
    /// The round trained nothing; the model (and any scheduled
    /// migration) carries over.
    Lost { record: RoundRecord, cause: LostCause },
}

impl RoundOutcome {
    /// The round's metrics record, whichever way it went.
    pub fn record(&self) -> &RoundRecord {
        match self {
            RoundOutcome::Completed { record, .. } => record,
            RoundOutcome::Lost { record, .. } => record,
        }
    }

    /// Round index.
    pub fn round(&self) -> usize {
        self.record().round
    }

    pub fn is_lost(&self) -> bool {
        matches!(self, RoundOutcome::Lost { .. })
    }
}

/// Observer return channel: every hook receives one of these and may
/// request session-level adjustments; the runner applies them after the
/// hook returns.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundControl {
    stop: bool,
    deadline_s: Option<f64>,
}

impl RoundControl {
    /// Stop the session after the current round completes:
    /// `Runner::is_done()` turns true and `run()`'s loop exits cleanly.
    pub fn request_stop(&mut self) {
        self.stop = true;
    }

    pub fn stop_requested(&self) -> bool {
        self.stop
    }

    /// Override the round deadline from here on (`0` disables).  Set
    /// during `on_plan` it applies to the round being planned — the hook
    /// for per-cluster adaptive deadlines.
    pub fn set_deadline_s(&mut self, deadline_s: f64) {
        self.deadline_s = Some(deadline_s);
    }

    pub fn deadline_override(&self) -> Option<f64> {
        self.deadline_s
    }
}

/// Hooks into the phases of one round.  All hooks default to no-ops;
/// implement the ones you need.  Within a round the runner fires, in
/// order: `on_plan` (after the strategy planned, before failure
/// injection), `on_comm` (after the DES delivered the round's transfers
/// and stragglers are known; skipped when the round was lost to
/// dropout), `on_aggregate` (after the global model moved; skipped for
/// lost rounds), `on_round_end` (always, with the typed outcome).
pub trait RoundObserver {
    fn on_plan(&mut self, _t: usize, _plan: &RoundPlan, _ctl: &mut RoundControl) {}

    fn on_comm(
        &mut self,
        _t: usize,
        _comm: &RoundComm,
        _net_s: f64,
        _stragglers: &[usize],
        _ctl: &mut RoundControl,
    ) {
    }

    fn on_aggregate(
        &mut self,
        _t: usize,
        _state: &ModelState,
        _ctl: &mut RoundControl,
    ) {
    }

    fn on_round_end(
        &mut self,
        _t: usize,
        _outcome: &RoundOutcome,
        _ctl: &mut RoundControl,
    ) {
    }
}

/// Built-in observer: the round loop's progress logging, re-expressed as
/// an observer (one `info` line per evaluated round).
#[derive(Debug)]
pub struct ProgressObserver {
    /// Algorithm label for the log line (`Strategy::name()`).
    algorithm: &'static str,
}

impl ProgressObserver {
    pub fn new(algorithm: &'static str) -> ProgressObserver {
        ProgressObserver { algorithm }
    }
}

impl RoundObserver for ProgressObserver {
    fn on_round_end(
        &mut self,
        t: usize,
        outcome: &RoundOutcome,
        _ctl: &mut RoundControl,
    ) {
        let r = outcome.record();
        if !r.test_accuracy.is_nan() {
            let cluster = if r.cluster == usize::MAX {
                "-".to_string()
            } else {
                r.cluster.to_string()
            };
            log::info!(
                "[{}] round {t:>4} cluster {:>3} loss {:.4} acc {:.4} \
                 ({} byte-hops)",
                self.algorithm,
                cluster,
                r.train_loss,
                r.test_accuracy,
                r.comm_byte_hops
            );
        }
    }
}

/// Built-in observer: live per-round metrics export.  The steady state
/// **appends** one row per round — O(1) I/O instead of rewriting the
/// whole accumulated document (O(R²) over a long run) — so the curves
/// are inspectable (and survive a crash) without waiting for the final
/// report.  Rows ride [`crate::metrics::RoundRecord::csv_fields`], the
/// same serialization the batch export uses, so the live file is
/// byte-identical to [`crate::metrics::ExperimentMetrics::to_csv`] over
/// the same records.  Every record is also retained in memory: if an
/// append fails (transient I/O error, file deleted out from under the
/// run), the next export rewrites the full document and the file heals
/// — no round's row is ever silently lost.
#[derive(Debug)]
pub struct MetricsCsvObserver {
    path: String,
    /// Every record seen so far — the source of truth a failed append
    /// is healed from.
    metrics: ExperimentMetrics,
    /// Rows known to be in the file (behind the header); lagging
    /// `metrics.rounds.len()` means the next export rewrites in full.
    flushed: usize,
}

impl MetricsCsvObserver {
    pub fn new(path: &str) -> MetricsCsvObserver {
        MetricsCsvObserver {
            path: path.to_string(),
            metrics: ExperimentMetrics::default(),
            flushed: 0,
        }
    }

    fn export(&mut self, record: &RoundRecord) -> std::io::Result<()> {
        use std::io::Write;
        self.metrics.push(record.clone());
        if self.flushed > 0 && self.flushed + 1 == self.metrics.rounds.len() {
            // Steady state: the file holds every earlier row — append
            // this one.  Deliberately no `create(true)`: a vanished
            // file fails the open and lands in the rewrite arm below,
            // which restores the header and all rows.
            let row = CsvWriter::encode_row(&record.csv_fields());
            let appended = std::fs::OpenOptions::new()
                .append(true)
                .open(&self.path)
                .and_then(|mut f| f.write_all(&row));
            if appended.is_ok() {
                self.flushed += 1;
                return Ok(());
            }
        }
        // First row, or recovery from a failed/missed append: write the
        // whole accumulated document.
        std::fs::write(&self.path, self.metrics.to_csv().as_bytes())?;
        self.flushed = self.metrics.rounds.len();
        Ok(())
    }
}

impl RoundObserver for MetricsCsvObserver {
    fn on_round_end(
        &mut self,
        _t: usize,
        outcome: &RoundOutcome,
        _ctl: &mut RoundControl,
    ) {
        if let Err(e) = self.export(outcome.record()) {
            log::warn!("metrics export to {} failed: {e}", self.path);
        }
    }
}

/// Built-in observer: **adaptive round deadlines** (the ROADMAP's
/// "per-cluster adaptive deadlines need no runner surgery" policy).
///
/// Tracks an EWMA of the per-round simulated network makespan (the
/// `net_s` each `on_comm` reports — upload deliveries plus the
/// migration leg) and, once `warmup` rounds have been observed, sets
/// the next round's deadline to `slack × EWMA` via
/// [`RoundControl::set_deadline_s`].  A slack comfortably above 1
/// tolerates normal jitter and only cuts genuine outliers; a slack
/// below 1 deliberately starves slow uploads (useful in tests).
/// Lost rounds report no makespan and leave the estimate untouched.
///
/// Observer state is process-local by design — it re-warms after a
/// checkpoint resume rather than riding in the checkpoint.
///
/// [`per_cluster`](AdaptiveDeadlineObserver::per_cluster) upgrades the
/// single global estimate to one EWMA per *planned* cluster: clusters
/// whose base stations sit behind different backhauls settle on
/// different makespans, and a shared estimate either starves the slow
/// cluster or over-waits the fast one.  A cluster falls back to the
/// global EWMA until its own estimate has `warmup` samples.
#[derive(Debug)]
pub struct AdaptiveDeadlineObserver {
    /// EWMA weight of the newest sample (0 < alpha <= 1).
    alpha: f64,
    /// Deadline = slack × EWMA.
    slack: f64,
    /// Rounds to observe before the first deadline applies.
    warmup: usize,
    ewma: Option<f64>,
    seen: usize,
    /// Per-planned-cluster `(ewma, samples)`; `None` = single global
    /// estimate.  BTreeMap: iteration order never feeds back into
    /// results, but this module stays ordered-containers-only anyway.
    clusters: Option<BTreeMap<usize, (f64, usize)>>,
    /// Cluster the in-flight round planned — attributes the makespan
    /// `on_comm` reports to the right per-cluster estimate.
    pending: Option<usize>,
    /// Control-decision tracing (`deadline.set` instants); off by
    /// default.
    tracer: Tracer,
}

impl AdaptiveDeadlineObserver {
    /// Default policy: EWMA alpha 0.3, 3 warmup rounds.
    pub fn new(slack: f64) -> AdaptiveDeadlineObserver {
        AdaptiveDeadlineObserver::with_params(slack, 0.3, 3)
    }

    pub fn with_params(slack: f64, alpha: f64, warmup: usize) -> AdaptiveDeadlineObserver {
        assert!(slack > 0.0 && slack.is_finite(), "slack must be positive");
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        AdaptiveDeadlineObserver {
            alpha,
            slack,
            warmup,
            ewma: None,
            seen: 0,
            clusters: None,
            pending: None,
            tracer: Tracer::off(),
        }
    }

    /// Emit a `control`/`deadline.set` instant every time this observer
    /// overrides the round deadline.
    pub fn with_tracer(mut self, tracer: Tracer) -> AdaptiveDeadlineObserver {
        self.tracer = tracer;
        self
    }

    /// Track one deadline EWMA per planned cluster instead of a single
    /// global estimate.  Rounds planned without a distinguished cluster
    /// (FedAvg-style, `cluster == usize::MAX`) only feed the global
    /// estimate.
    pub fn per_cluster(mut self) -> AdaptiveDeadlineObserver {
        self.clusters = Some(BTreeMap::new());
        self
    }

    /// Current estimate of the per-round network makespan (None until
    /// the first traffic-moving round completes).
    pub fn estimate_s(&self) -> Option<f64> {
        self.ewma
    }

    /// Per-cluster makespan estimate (None while that cluster has no
    /// samples, or when per-cluster tracking is off).
    pub fn cluster_estimate_s(&self, cluster: usize) -> Option<f64> {
        self.clusters.as_ref().and_then(|m| m.get(&cluster)).map(|&(e, _)| e)
    }

    fn trace_deadline(&self, t: usize, cluster: usize, deadline_s: f64) {
        let mut attrs = vec![
            ("round", t.into()),
            ("deadline_s", crate::util::json::Json::Num(deadline_s)),
        ];
        if cluster != usize::MAX {
            attrs.push(("cluster", cluster.into()));
        }
        self.tracer.instant(TraceLevel::Round, "control", "deadline.set", "main", None, attrs);
    }
}

impl RoundObserver for AdaptiveDeadlineObserver {
    fn on_plan(&mut self, t: usize, plan: &RoundPlan, ctl: &mut RoundControl) {
        self.pending = Some(plan.cluster);
        if plan.cluster != usize::MAX {
            if let Some(map) = &self.clusters {
                if let Some(&(e, samples)) = map.get(&plan.cluster) {
                    if samples >= self.warmup {
                        ctl.set_deadline_s(self.slack * e);
                        self.trace_deadline(t, plan.cluster, self.slack * e);
                        return;
                    }
                }
            }
        }
        if self.seen >= self.warmup {
            if let Some(e) = self.ewma {
                ctl.set_deadline_s(self.slack * e);
                self.trace_deadline(t, plan.cluster, self.slack * e);
            }
        }
    }

    fn on_comm(
        &mut self,
        _t: usize,
        _comm: &RoundComm,
        net_s: f64,
        _stragglers: &[usize],
        _ctl: &mut RoundControl,
    ) {
        let cluster = self.pending.take();
        if !net_s.is_finite() || net_s <= 0.0 {
            return;
        }
        self.ewma = Some(match self.ewma {
            None => net_s,
            Some(e) => self.alpha * net_s + (1.0 - self.alpha) * e,
        });
        self.seen += 1;
        if let (Some(map), Some(c)) = (&mut self.clusters, cluster) {
            if c != usize::MAX {
                let entry = map.entry(c).or_insert((net_s, 0));
                if entry.1 > 0 {
                    entry.0 = self.alpha * net_s + (1.0 - self.alpha) * entry.0;
                }
                entry.1 += 1;
            }
        }
    }
}

/// Built-in observer: **early stopping on a test-loss plateau**.
///
/// Watches every *evaluated* round (`test_loss` is NaN on rounds the
/// eval cadence skipped, and those don't count either way).  A round
/// whose loss fails to undercut the best seen so far by more than
/// `min_delta` extends the plateau; once `patience` consecutive
/// evaluated rounds have failed, the observer calls
/// [`RoundControl::request_stop`] and the session ends after that
/// round.  The stop rides the normal control channel, so the
/// checkpointed round cursor still resumes bit-identically — a resumed
/// run re-warms the observer and may stop later, never corrupt state.
#[derive(Debug)]
pub struct PlateauStopObserver {
    /// Consecutive non-improving evaluated rounds before stopping.
    patience: usize,
    /// An improvement must beat the best loss by more than this.
    min_delta: f64,
    best: Option<f64>,
    streak: usize,
    /// Control-decision tracing (`plateau.stop` instant); off by
    /// default.
    tracer: Tracer,
}

impl PlateauStopObserver {
    pub fn new(patience: usize, min_delta: f64) -> PlateauStopObserver {
        assert!(patience > 0, "patience must be positive (0 means: don't build one)");
        assert!(min_delta.is_finite() && min_delta >= 0.0, "min_delta must be finite and >= 0");
        PlateauStopObserver {
            patience,
            min_delta,
            best: None,
            streak: 0,
            tracer: Tracer::off(),
        }
    }

    /// Emit a `control`/`plateau.stop` instant when the stop fires.
    pub fn with_tracer(mut self, tracer: Tracer) -> PlateauStopObserver {
        self.tracer = tracer;
        self
    }

    /// Evaluated rounds since the last improvement.
    pub fn plateau_len(&self) -> usize {
        self.streak
    }
}

impl RoundObserver for PlateauStopObserver {
    fn on_round_end(
        &mut self,
        t: usize,
        outcome: &RoundOutcome,
        ctl: &mut RoundControl,
    ) {
        let loss = outcome.record().test_loss;
        if !loss.is_finite() {
            return; // not an evaluated round
        }
        let improved = match self.best {
            None => true,
            Some(best) => loss < best - self.min_delta,
        };
        if improved {
            self.best = Some(loss);
            self.streak = 0;
        } else {
            self.streak += 1;
            if self.streak >= self.patience {
                ctl.request_stop();
                self.tracer.instant(
                    TraceLevel::Round,
                    "control",
                    "plateau.stop",
                    "main",
                    None,
                    vec![
                        ("round", t.into()),
                        ("plateau", self.streak.into()),
                        (
                            "best_test_loss",
                            crate::util::json::Json::Num(self.best.unwrap_or(f64::NAN)),
                        ),
                    ],
                );
            }
        }
    }
}

/// One straggler's late local update, held for re-inclusion.
#[derive(Debug, Clone)]
pub struct DeferredUpdate {
    pub client: usize,
    /// Round the update was trained in (against that round's opening
    /// global state).
    pub round: usize,
    /// Eq. 3 aggregation weight (the client's sample count).
    pub weight: f64,
    /// The update's training loss, folded into the destination round's
    /// weighted `train_loss` alongside its state.
    pub loss: f64,
    pub state: ModelState,
}

/// Session state for straggler re-inclusion: at most one pending update
/// per client, kept sorted by client id so the fold order (and therefore
/// every f32 rounding decision downstream) is deterministic.
#[derive(Debug, Clone, Default)]
pub struct DeferredPool {
    entries: Vec<DeferredUpdate>,
}

impl DeferredPool {
    /// Hold a late update.  A client that straggles again while an older
    /// update of theirs is still pending (possible when lost rounds keep
    /// the pool from draining) *replaces* it — folding both would
    /// double-count the client in one reduction.
    pub fn defer(&mut self, u: DeferredUpdate) {
        match self.entries.binary_search_by_key(&u.client, |d| d.client) {
            Ok(i) => self.entries[i] = u,
            Err(i) => self.entries.insert(i, u),
        }
    }

    /// Take every pending update, in client-id order, leaving the pool
    /// empty.
    pub fn drain_sorted(&mut self) -> Vec<DeferredUpdate> {
        std::mem::take(&mut self.entries)
    }

    /// Pending updates, in client-id order.
    pub fn entries(&self) -> &[DeferredUpdate] {
        &self.entries
    }

    /// Pending client ids, ascending.
    pub fn clients(&self) -> Vec<usize> {
        self.entries.iter().map(|d| d.client).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{TensorSpec, VariantSpec};
    use crate::runtime::params::StateLayout;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    fn tiny_layout() -> Arc<StateLayout> {
        let v = VariantSpec {
            name: "t".into(),
            arch: "mlp".into(),
            image: (1, 1, 1),
            classes: 2,
            train_batch: 1,
            eval_batch: 1,
            k_values: vec![1],
            optimizers: vec!["sgd".into()],
            params: vec![TensorSpec { name: "w".into(), shape: vec![2] }],
            bn_state: vec![],
            opt_state: BTreeMap::from([("sgd".to_string(), vec![])]),
            init_blob: BTreeMap::new(),
            eval_exe: "e".into(),
            local_update: BTreeMap::new(),
        };
        StateLayout::new(&v, "sgd").unwrap()
    }

    fn update(client: usize, round: usize, fill: f32) -> DeferredUpdate {
        let mut state = ModelState::zeros(tiny_layout());
        state.data.fill(fill);
        DeferredUpdate { client, round, weight: 10.0, loss: 1.0, state }
    }

    #[test]
    fn pool_keeps_client_order_and_drains_empty() {
        let mut p = DeferredPool::default();
        assert!(p.is_empty());
        p.defer(update(7, 0, 1.0));
        p.defer(update(2, 0, 2.0));
        p.defer(update(5, 0, 3.0));
        assert_eq!(p.clients(), vec![2, 5, 7]);
        assert_eq!(p.len(), 3);
        let drained = p.drain_sorted();
        assert_eq!(
            drained.iter().map(|d| d.client).collect::<Vec<_>>(),
            vec![2, 5, 7]
        );
        assert!(p.is_empty());
        assert!(p.drain_sorted().is_empty());
    }

    #[test]
    fn double_straggler_replaces_never_double_counts() {
        // A client straggling twice before the pool drains must end up
        // with exactly one pending update — the newest.
        let mut p = DeferredPool::default();
        p.defer(update(3, 0, 1.0));
        p.defer(update(4, 0, 1.0));
        p.defer(update(3, 2, 9.0)); // client 3 straggles again
        assert_eq!(p.len(), 2, "no duplicate entry for client 3");
        assert_eq!(p.clients(), vec![3, 4]);
        let d3 = &p.entries()[0];
        assert_eq!(d3.client, 3);
        assert_eq!(d3.round, 2, "the newer update wins");
        assert_eq!(d3.state.data[0], 9.0);
    }

    #[test]
    fn adaptive_deadline_warms_up_then_tracks_ewma() {
        let mut obs = AdaptiveDeadlineObserver::with_params(1.5, 0.5, 2);
        let plan = RoundPlan {
            cluster: 0,
            groups: Vec::new(),
            aggregation: crate::fl::strategy::AggregationSite::None,
            migration: None,
        };
        let comm = RoundComm { byte_hops: 0, uploads: Vec::new(), submitted: Vec::new() };
        let mut ctl = RoundControl::default();

        // Warmup: no deadline request while fewer than 2 rounds observed.
        obs.on_plan(0, &plan, &mut ctl);
        assert_eq!(ctl.deadline_override(), None);
        obs.on_comm(0, &comm, 2.0, &[], &mut ctl);
        obs.on_plan(1, &plan, &mut ctl);
        assert_eq!(ctl.deadline_override(), None);
        obs.on_comm(1, &comm, 4.0, &[], &mut ctl);
        // EWMA after 2.0 then 4.0 at alpha 0.5: 3.0.
        assert_eq!(obs.estimate_s(), Some(3.0));

        // Warm: the planned round gets slack x EWMA.
        obs.on_plan(2, &plan, &mut ctl);
        assert_eq!(ctl.deadline_override(), Some(4.5));

        // Lost rounds (no traffic -> net_s 0) leave the estimate alone.
        obs.on_comm(2, &comm, 0.0, &[], &mut ctl);
        assert_eq!(obs.estimate_s(), Some(3.0));
    }

    fn plan_for(cluster: usize) -> RoundPlan {
        RoundPlan {
            cluster,
            groups: Vec::new(),
            aggregation: crate::fl::strategy::AggregationSite::None,
            migration: None,
        }
    }

    fn evaluated(t: usize, test_loss: f64) -> RoundOutcome {
        let record = RoundRecord {
            round: t,
            cluster: 0,
            train_loss: 1.0,
            test_accuracy: 0.5,
            test_loss,
            comm_byte_hops: 0,
            train_s: 0.0,
            aggregate_s: 0.0,
            net_s: 0.0,
            clock_s: 0.0,
            stragglers: Vec::new(),
            deferred: Vec::new(),
        };
        RoundOutcome::Completed { record, migration: None }
    }

    #[test]
    fn per_cluster_deadlines_diverge_and_fall_back_to_global() {
        // alpha 1.0 -> EWMA == last sample, so expectations are exact.
        let comm = RoundComm { byte_hops: 0, uploads: Vec::new(), submitted: Vec::new() };
        let mut obs = AdaptiveDeadlineObserver::with_params(2.0, 1.0, 1).per_cluster();
        let mut ctl = RoundControl::default();

        // Cluster 0 is fast (2 s), cluster 1 is slow (10 s).
        obs.on_plan(0, &plan_for(0), &mut ctl);
        obs.on_comm(0, &comm, 2.0, &[], &mut ctl);
        obs.on_plan(1, &plan_for(1), &mut ctl);
        obs.on_comm(1, &comm, 10.0, &[], &mut ctl);
        assert_eq!(obs.cluster_estimate_s(0), Some(2.0));
        assert_eq!(obs.cluster_estimate_s(1), Some(10.0));

        // Each cluster gets a deadline from its own estimate — the
        // global path would hand both the blended 10.0 (last sample).
        let mut ctl = RoundControl::default();
        obs.on_plan(2, &plan_for(0), &mut ctl);
        assert_eq!(ctl.deadline_override(), Some(4.0), "fast cluster: 2 x 2.0");
        let mut ctl = RoundControl::default();
        obs.on_plan(3, &plan_for(1), &mut ctl);
        assert_eq!(ctl.deadline_override(), Some(20.0), "slow cluster: 2 x 10.0");

        // A cluster with no samples of its own rides the global EWMA —
        // exactly what the global-only observer would have set.
        let mut global = AdaptiveDeadlineObserver::with_params(2.0, 1.0, 1);
        global.on_plan(0, &plan_for(0), &mut ctl);
        global.on_comm(0, &comm, 2.0, &[], &mut ctl);
        global.on_plan(1, &plan_for(1), &mut ctl);
        global.on_comm(1, &comm, 10.0, &[], &mut ctl);
        let mut ctl_new = RoundControl::default();
        let mut ctl_old = RoundControl::default();
        obs.on_plan(4, &plan_for(7), &mut ctl_new);
        global.on_plan(4, &plan_for(7), &mut ctl_old);
        assert_eq!(ctl_new.deadline_override(), Some(20.0), "global fallback");
        assert_eq!(
            ctl_new.deadline_override(),
            ctl_old.deadline_override(),
            "cold cluster matches the global-only path"
        );
        assert_eq!(obs.cluster_estimate_s(7), None);
    }

    #[test]
    fn per_cluster_ignores_clusterless_rounds() {
        // FedAvg-style rounds plan with cluster == usize::MAX; they feed
        // the global estimate but never mint a per-cluster entry.
        let comm = RoundComm { byte_hops: 0, uploads: Vec::new(), submitted: Vec::new() };
        let mut obs = AdaptiveDeadlineObserver::with_params(1.0, 1.0, 1).per_cluster();
        let mut ctl = RoundControl::default();
        obs.on_plan(0, &plan_for(usize::MAX), &mut ctl);
        obs.on_comm(0, &comm, 3.0, &[], &mut ctl);
        assert_eq!(obs.estimate_s(), Some(3.0));
        assert_eq!(obs.cluster_estimate_s(usize::MAX), None);
        let mut ctl = RoundControl::default();
        obs.on_plan(1, &plan_for(usize::MAX), &mut ctl);
        assert_eq!(ctl.deadline_override(), Some(3.0), "global path still works");
    }

    #[test]
    fn plateau_stop_fires_after_patience_without_improvement() {
        let mut obs = PlateauStopObserver::new(2, 0.25);
        let mut ctl = RoundControl::default();

        obs.on_round_end(0, &evaluated(0, 1.0), &mut ctl); // first eval = best
        assert!(!ctl.stop_requested());
        assert_eq!(obs.plateau_len(), 0);

        // 0.1 better, but under min_delta: counts as no improvement.
        obs.on_round_end(1, &evaluated(1, 0.9), &mut ctl);
        assert!(!ctl.stop_requested());
        assert_eq!(obs.plateau_len(), 1);

        // Skipped-eval rounds (NaN loss) neither extend nor reset.
        obs.on_round_end(2, &evaluated(2, f64::NAN), &mut ctl);
        assert_eq!(obs.plateau_len(), 1);
        assert!(!ctl.stop_requested());

        obs.on_round_end(3, &evaluated(3, 0.95), &mut ctl);
        assert!(ctl.stop_requested(), "second miss exhausts patience 2");
    }

    #[test]
    fn plateau_resets_on_genuine_improvement() {
        let mut obs = PlateauStopObserver::new(2, 0.0);
        let mut ctl = RoundControl::default();
        obs.on_round_end(0, &evaluated(0, 1.0), &mut ctl);
        obs.on_round_end(1, &evaluated(1, 1.0), &mut ctl); // equal != better
        assert_eq!(obs.plateau_len(), 1);
        obs.on_round_end(2, &evaluated(2, 0.5), &mut ctl); // strict decrease
        assert_eq!(obs.plateau_len(), 0);
        assert!(!ctl.stop_requested());
        obs.on_round_end(3, &evaluated(3, 0.6), &mut ctl);
        obs.on_round_end(4, &evaluated(4, 0.55), &mut ctl);
        assert!(ctl.stop_requested(), "plateau of 2 after the reset");
    }

    #[test]
    fn control_carries_stop_and_deadline() {
        let mut c = RoundControl::default();
        assert!(!c.stop_requested());
        assert_eq!(c.deadline_override(), None);
        c.request_stop();
        c.set_deadline_s(2.5);
        assert!(c.stop_requested());
        assert_eq!(c.deadline_override(), Some(2.5));
    }

    #[test]
    fn csv_observer_appends_rows_identical_to_batch_export() {
        // The live exporter writes the header once and appends one row
        // per round; the result must equal the batch export byte for
        // byte (the old implementation rewrote the whole file every
        // round — O(R^2) I/O on long runs).
        let path = std::env::temp_dir().join("edgeflow_live_csv_append_test.csv");
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let mut records = Vec::new();
        for t in 0..4usize {
            let mut r = RoundRecord {
                round: t,
                cluster: t % 2,
                train_loss: 0.5 + t as f64,
                test_accuracy: if t % 2 == 0 { 0.25 } else { f64::NAN },
                test_loss: 1.0,
                comm_byte_hops: 100 * t as u64,
                train_s: 0.0,
                aggregate_s: 0.0,
                net_s: 0.125,
                clock_s: t as f64,
                stragglers: Vec::new(),
                deferred: Vec::new(),
            };
            if t == 2 {
                r.stragglers = vec![3, 7];
                r.deferred = vec![1];
            }
            records.push(r);
        }
        let mut obs = MetricsCsvObserver::new(&path_s);
        let mut ctl = RoundControl::default();
        for r in &records {
            let outcome =
                RoundOutcome::Completed { record: r.clone(), migration: None };
            obs.on_round_end(r.round, &outcome, &mut ctl);
        }
        let live = std::fs::read(&path).unwrap();
        let batch = ExperimentMetrics { rounds: records };
        assert_eq!(live, batch.to_csv().as_bytes(), "live file == batch export");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_observer_heals_after_external_file_loss() {
        // An append can fail (transient I/O error, live file deleted
        // out from under the run).  The observer retains every record,
        // so the next export rewrites the whole document instead of
        // silently dropping rows forever.
        let path = std::env::temp_dir().join("edgeflow_live_csv_heal_test.csv");
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let rec = |t: usize| RoundRecord {
            round: t,
            cluster: t % 2,
            train_loss: t as f64,
            test_accuracy: f64::NAN,
            test_loss: 1.0,
            comm_byte_hops: 7,
            train_s: 0.0,
            aggregate_s: 0.0,
            net_s: 0.0,
            clock_s: 0.0,
            stragglers: Vec::new(),
            deferred: Vec::new(),
        };
        let mut obs = MetricsCsvObserver::new(&path_s);
        let mut ctl = RoundControl::default();
        let mut records = Vec::new();
        for t in 0..4usize {
            if t == 2 {
                // the live file vanishes between rounds
                std::fs::remove_file(&path).unwrap();
            }
            let r = rec(t);
            records.push(r.clone());
            let outcome = RoundOutcome::Completed { record: r, migration: None };
            obs.on_round_end(t, &outcome, &mut ctl);
        }
        let live = std::fs::read(&path).unwrap();
        let batch = ExperimentMetrics { rounds: records };
        assert_eq!(live, batch.to_csv().as_bytes(), "healed file == batch export");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn outcome_accessors() {
        let record = RoundRecord {
            round: 4,
            cluster: 1,
            train_loss: f64::NAN,
            test_accuracy: f64::NAN,
            test_loss: f64::NAN,
            comm_byte_hops: 0,
            train_s: 0.0,
            aggregate_s: 0.0,
            net_s: 0.0,
            clock_s: 0.0,
            stragglers: Vec::new(),
            deferred: Vec::new(),
        };
        let lost = RoundOutcome::Lost { record, cause: LostCause::AllDropped };
        assert!(lost.is_lost());
        assert_eq!(lost.round(), 4);
        assert!(lost.record().train_loss.is_nan());
    }
}
