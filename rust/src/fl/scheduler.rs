//! Cluster scheduling: which cluster is active at round `t`, and in what
//! order the model migrates (the paper's `m(t)`).

use crate::netsim::NetSim;
use crate::rng::Rng;
use crate::topology::graph::Topology;
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// EdgeFLow's inter-cluster migration order.
#[derive(Debug)]
pub enum ClusterSchedule {
    /// Fixed cyclic order 0, 1, ..., M-1, 0, ... (EdgeFLowSeq).
    // lint:allow(checkpoint-parity): the active cluster is a pure function
    // of (clusters, t); restore rebuilds the schedule from config.
    Sequential { clusters: usize },
    /// Uniform random next cluster, never repeating the current one when
    /// M > 1 (EdgeFLowRand).  The draw at round `t` is a pure function of
    /// `(seed, t)` — calls may skip ahead or replay; `cache` only
    /// memoizes the last computed `(t, cluster)` so consecutive calls
    /// stay O(1).
    // lint:allow(checkpoint-parity): `clusters`/`seed` come back from the
    // config rebuild on restore; the draw is a pure function of (seed, t)
    // and the cache is a recomputable memo.
    Random { clusters: usize, seed: u64, cache: Option<(usize, usize)> },
    /// Hop-aware circuit (the paper's "wireless-aware scheduling" future
    /// work): a greedy nearest-neighbor tour over the BS hop-distance
    /// matrix — every cluster once per cycle, migrations ride the
    /// cheapest available links.
    // lint:allow(checkpoint-parity): the greedy tour is recomputed from the
    // config topology on restore — `order` is derived, not state.
    HopAware { order: Vec<usize> },
    /// Latency-aware tour: the next migration target is the unvisited
    /// cluster with the smallest *simulated* BS->BS transfer time on the
    /// current network state (candidate transfers probed on a cloned
    /// [`NetSim`] over the bandwidth-aware transfer-time `RouteTable`
    /// sized to the migrating model), ties broken by the
    /// HopAware tour position.  Every cluster is still visited once per
    /// cycle.  The probe accounts for bandwidth, store-and-forward and
    /// queueing — unlike hop counts — and steers around congestion
    /// whenever the supplied sim carries in-flight traffic; a caller that
    /// drains every round (the runner's synchronous barriers) probes the
    /// idle-at-round-boundary network, and without a live sim the probe
    /// degenerates to a static latency-optimal tour.
    LatencyAware {
        // lint:allow(checkpoint-parity): rebuilt from config on restore.
        topo: Topology,
        /// HopAware tour of the same topology: tie-break ranking + cycle
        /// anchor.
        // lint:allow(checkpoint-parity): derived tour of the config
        // topology; restore recomputes it.
        hop_order: Vec<usize>,
        visited: Vec<bool>,
        current: usize,
        /// Probe transfer size (the migrating model's wire bytes).
        // lint:allow(checkpoint-parity): sized from the config model/codec
        // on restore.
        model_bytes: u64,
        /// Last `(t, pick)`: re-asking for the same round returns the
        /// memoized pick instead of advancing the tour twice.
        cache: Option<(usize, usize)>,
    },
}

impl ClusterSchedule {
    pub fn sequential(clusters: usize) -> ClusterSchedule {
        assert!(clusters > 0);
        ClusterSchedule::Sequential { clusters }
    }

    pub fn random(clusters: usize, seed: u64) -> ClusterSchedule {
        assert!(clusters > 0);
        ClusterSchedule::Random { clusters, seed, cache: None }
    }

    /// Greedy nearest-neighbor tour over a pairwise hop matrix
    /// (`hops[i][j]` = hop distance between BS i and BS j).
    pub fn hop_aware(hops: &[Vec<usize>]) -> ClusterSchedule {
        ClusterSchedule::HopAware { order: greedy_tour(hops) }
    }

    /// Latency-aware schedule over `topo`'s base stations; candidate
    /// migrations are probed as `model_bytes` transfers.
    pub fn latency_aware(topo: &Topology, model_bytes: u64) -> ClusterSchedule {
        let bs = topo.base_stations();
        assert!(!bs.is_empty(), "latency_aware needs base stations");
        let rt = RouteTable::hops(topo);
        let hops: Vec<Vec<usize>> = bs
            .iter()
            .map(|&a| {
                bs.iter()
                    .map(|&b| rt.dist(a, b).unwrap_or(usize::MAX / 2))
                    .collect()
            })
            .collect();
        ClusterSchedule::LatencyAware {
            topo: topo.clone(),
            hop_order: greedy_tour(&hops),
            visited: vec![false; bs.len()],
            current: 0,
            model_bytes,
            cache: None,
        }
    }

    /// The active cluster for round `t`.  Equivalent to
    /// [`ClusterSchedule::next_on`] with no live network state.
    pub fn next(&mut self, t: usize) -> usize {
        self.next_on(t, None)
    }

    /// The active cluster for round `t`, optionally informed by the live
    /// network state `net` (only the latency-aware schedule reads it).
    /// Contracts: `Sequential`/`HopAware` are pure functions of `t`;
    /// `Random` is a pure function of `(seed, t)` and accepts arbitrary
    /// (skip-ahead / replayed) `t`; `LatencyAware` advances tour state
    /// and must be called with consecutive rounds — though re-asking for
    /// the *same* `t` returns the memoized pick instead of advancing.
    pub fn next_on(&mut self, t: usize, net: Option<&NetSim>) -> usize {
        match self {
            ClusterSchedule::Sequential { clusters } => t % *clusters,
            ClusterSchedule::HopAware { order } => order[t % order.len()],
            ClusterSchedule::Random { clusters, seed, cache } => {
                let m = *clusters;
                if m == 1 {
                    return 0;
                }
                // Replay the chain c(i) = (c(i-1) + 1 + r(i)) mod m from
                // the nearest memoized point at or before `t`; each step
                // offset r(i) in [0, m-2] keeps consecutive rounds on
                // different clusters.
                let (mut i, mut c) = match *cache {
                    Some((ct, cc)) if ct <= t => (ct, cc),
                    _ => (0, random_draw(*seed, 0).below(m)),
                };
                while i < t {
                    i += 1;
                    c = (c + 1 + random_draw(*seed, i).below(m - 1)) % m;
                }
                *cache = Some((t, c));
                c
            }
            ClusterSchedule::LatencyAware {
                topo,
                hop_order,
                visited,
                current,
                model_bytes,
                cache,
            } => {
                let m = visited.len();
                if m == 1 {
                    return 0;
                }
                if let Some((ct, cp)) = *cache {
                    if ct == t {
                        // Same round re-planned: don't advance the tour.
                        return cp;
                    }
                }
                if t == 0 {
                    // Anchor the tour where HopAware anchors it.
                    visited.fill(false);
                    let start = hop_order[0];
                    visited[start] = true;
                    *current = start;
                    *cache = Some((0, start));
                    return start;
                }
                if visited.iter().all(|&v| v) {
                    // Cycle complete: everything is fair game again except
                    // an immediate repeat of the current cluster (it stays
                    // eligible as soon as the tour moves off it).
                    visited.fill(false);
                }
                // The route table is O(1) to build (paths are computed on
                // demand); the idle fallback sim is hoisted so candidates
                // clone an Arc-shared handle, not the topology.  Probes
                // ride the bandwidth-aware routes the runner's DES rides
                // for model-sized transfers, so the predicted and actual
                // migration paths agree.
                let rt = RouteTable::transfer_time(topo, *model_bytes);
                let idle;
                let base: &NetSim = match net {
                    Some(n) => n,
                    None => {
                        idle = NetSim::new(topo);
                        &idle
                    }
                };
                // lint:allow(unwrap-in-library): cluster indices come
                // from the topology itself (0..m), so every cluster
                // has an edge BS.
                let src = topo.edge_bs(*current).expect("current BS");
                let mut best: Option<(f64, usize, usize)> = None;
                for j in 0..m {
                    if visited[j] || j == *current {
                        continue;
                    }
                    // lint:allow(unwrap-in-library): j ranges over the
                    // same 0..m cluster indices as `current` above.
                    let dst = topo.edge_bs(j).expect("candidate BS");
                    let mut probe = base.clone();
                    let at = probe.now_s();
                    let secs = match probe.submit(&rt, src, dst, *model_bytes, at) {
                        Ok(id) => probe
                            .run()
                            .into_iter()
                            .find(|o| o.id == id)
                            .map(|o| o.delivered_s - at)
                            .unwrap_or(f64::INFINITY),
                        Err(_) => f64::INFINITY,
                    };
                    let rank = hop_order
                        .iter()
                        .position(|&x| x == j)
                        .unwrap_or(usize::MAX);
                    let cand = (secs, rank, j);
                    best = Some(match best {
                        None => cand,
                        Some(b) if cand < b => cand,
                        Some(b) => b,
                    });
                }
                let pick = best.map(|(_, _, j)| j).unwrap_or(*current);
                visited[pick] = true;
                *current = pick;
                *cache = Some((t, pick));
                pick
            }
        }
    }

    pub fn clusters(&self) -> usize {
        match self {
            ClusterSchedule::Sequential { clusters } => *clusters,
            ClusterSchedule::Random { clusters, .. } => *clusters,
            ClusterSchedule::HopAware { order } => order.len(),
            ClusterSchedule::LatencyAware { visited, .. } => visited.len(),
        }
    }

    /// Serializable tour state for checkpoint/resume.  `Sequential`,
    /// `HopAware` and `Random` are (pure) functions of `t` and carry no
    /// state worth saving; `LatencyAware` must persist its cycle
    /// bookkeeping (visited set, tour position, last-round memo) so a
    /// restored schedule continues the exact same tour.
    pub fn checkpoint(&self) -> Json {
        match self {
            ClusterSchedule::Sequential { .. } => {
                Json::obj(vec![("kind", "sequential".into())])
            }
            ClusterSchedule::Random { .. } => {
                Json::obj(vec![("kind", "random".into())])
            }
            ClusterSchedule::HopAware { .. } => {
                Json::obj(vec![("kind", "hop_aware".into())])
            }
            ClusterSchedule::LatencyAware { visited, current, cache, .. } => {
                Json::obj(vec![
                    ("kind", "latency_aware".into()),
                    ("visited", Json::arr(visited.iter().map(|&v| Json::from(v)))),
                    ("current", (*current).into()),
                    (
                        "cache",
                        match cache {
                            Some((t, pick)) => Json::arr(vec![
                                Json::from(*t),
                                Json::from(*pick),
                            ]),
                            None => Json::Null,
                        },
                    ),
                ])
            }
        }
    }

    /// Restore a [`ClusterSchedule::checkpoint`] snapshot onto a schedule
    /// built from the same config; the continuation is identical to the
    /// uninterrupted schedule's.
    pub fn restore(&mut self, j: &Json) -> Result<()> {
        let kind = j.str_field("kind")?;
        let want = match self {
            ClusterSchedule::Sequential { .. } => "sequential",
            ClusterSchedule::Random { .. } => "random",
            ClusterSchedule::HopAware { .. } => "hop_aware",
            ClusterSchedule::LatencyAware { .. } => "latency_aware",
        };
        if kind != want {
            return Err(Error::Config(format!(
                "checkpoint schedule kind {kind:?} does not match the \
                 configured {want:?}"
            )));
        }
        if let ClusterSchedule::LatencyAware { visited, current, cache, .. } = self
        {
            let vj = j
                .req("visited")?
                .as_arr()
                .ok_or_else(|| Error::Json("visited must be an array".into()))?;
            if vj.len() != visited.len() {
                return Err(Error::Config(format!(
                    "checkpoint tour covers {} clusters, schedule has {}",
                    vj.len(),
                    visited.len()
                )));
            }
            for (slot, v) in visited.iter_mut().zip(vj) {
                *slot = v
                    .as_bool()
                    .ok_or_else(|| Error::Json("visited entry must be a bool".into()))?;
            }
            *current = j.usize_field("current")?;
            *cache = match j.req("cache")? {
                Json::Null => None,
                v => {
                    let pair = v
                        .as_arr()
                        .ok_or_else(|| Error::Json("cache must be [t, pick]".into()))?;
                    if pair.len() != 2 {
                        return Err(Error::Json("cache must be [t, pick]".into()));
                    }
                    let get = |x: &Json| {
                        x.as_usize().ok_or_else(|| {
                            Error::Json("cache entries must be integers".into())
                        })
                    };
                    Some((get(&pair[0])?, get(&pair[1])?))
                }
            };
        }
        Ok(())
    }
}

/// Greedy nearest-neighbor tour over a pairwise distance matrix, anchored
/// at 0, ties broken by index.
fn greedy_tour(dist: &[Vec<usize>]) -> Vec<usize> {
    let m = dist.len();
    assert!(m > 0);
    let mut order = Vec::with_capacity(m);
    let mut visited = vec![false; m];
    let mut cur = 0usize;
    order.push(0);
    visited[0] = true;
    for _ in 1..m {
        let next = (0..m)
            .filter(|&j| !visited[j])
            .min_by_key(|&j| (dist[cur][j], j))
            // lint:allow(unwrap-in-library): the loop runs m-1 times
            // over m nodes, so an unvisited node always remains.
            .unwrap();
        order.push(next);
        visited[next] = true;
        cur = next;
    }
    order
}

/// Stateless per-round stream for the random schedule: a fresh generator
/// keyed by `(seed, t)` (odd-constant mix keeps the keys distinct).
fn random_draw(seed: u64, t: usize) -> Rng {
    Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::topology::builder::{build, TopologyParams};

    #[test]
    fn sequential_covers_all_every_m_rounds() {
        let mut s = ClusterSchedule::sequential(4);
        let order: Vec<usize> = (0..8).map(|t| s.next(t)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn random_never_repeats_consecutively() {
        let mut s = ClusterSchedule::random(5, 42);
        let mut last = usize::MAX;
        for t in 0..200 {
            let m = s.next(t);
            assert!(m < 5);
            assert_ne!(m, last);
            last = m;
        }
    }

    #[test]
    fn random_visits_all_clusters_uniformly() {
        let mut s = ClusterSchedule::random(5, 7);
        let mut counts = [0usize; 5];
        for t in 0..5000 {
            counts[s.next(t)] += 1;
        }
        for c in counts {
            // expectation 1000 each
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn single_cluster_degenerates() {
        let mut s = ClusterSchedule::random(1, 0);
        assert_eq!(s.next(0), 0);
        assert_eq!(s.next(1), 0);
    }

    #[test]
    fn random_skip_ahead_matches_sequential_replay() {
        // The draw is a function of (seed, t): jumping straight to any t —
        // forward or backward — must reproduce the consecutively-generated
        // value at that round.
        let mut seq = ClusterSchedule::random(5, 42);
        let vals: Vec<usize> = (0..30).map(|t| seq.next(t)).collect();
        let mut skip = ClusterSchedule::random(5, 42);
        assert_eq!(skip.next(17), vals[17]);
        assert_eq!(skip.next(3), vals[3], "replay before the cache point");
        assert_eq!(skip.next(29), vals[29]);
        assert_eq!(skip.next(0), vals[0]);
        assert_eq!(skip.next(29), vals[29], "same t twice");
    }

    #[test]
    fn hop_aware_visits_all_following_cheap_links() {
        // Line graph distances: 0-1-2-3 => tour must be 0,1,2,3.
        let hops = vec![
            vec![0, 1, 2, 3],
            vec![1, 0, 1, 2],
            vec![2, 1, 0, 1],
            vec![3, 2, 1, 0],
        ];
        let mut s = ClusterSchedule::hop_aware(&hops);
        let tour: Vec<usize> = (0..4).map(|t| s.next(t)).collect();
        assert_eq!(tour, vec![0, 1, 2, 3]);
        // cycles
        assert_eq!(s.next(4), 0);
        assert_eq!(s.clusters(), 4);
    }

    #[test]
    fn hop_aware_prefers_near_over_far() {
        // Star around 0 with one distant node 3.
        let hops = vec![
            vec![0, 1, 1, 5],
            vec![1, 0, 2, 6],
            vec![1, 2, 0, 6],
            vec![5, 6, 6, 0],
        ];
        let mut s = ClusterSchedule::hop_aware(&hops);
        let tour: Vec<usize> = (0..4).map(|t| s.next(t)).collect();
        assert_eq!(tour[3], 3, "distant cluster visited last: {tour:?}");
    }

    #[test]
    fn random_is_seed_deterministic() {
        let mut a = ClusterSchedule::random(6, 9);
        let mut b = ClusterSchedule::random(6, 9);
        for t in 0..50 {
            assert_eq!(a.next(t), b.next(t));
        }
    }

    #[test]
    fn latency_aware_tours_every_cluster_each_cycle() {
        let topo =
            build(&TopologyParams::new(TopologyKind::Hybrid, 8, 2)).unwrap();
        let mut s = ClusterSchedule::latency_aware(&topo, 100_000);
        assert_eq!(s.clusters(), 8);
        for cycle in 0..3 {
            let mut seen: Vec<usize> =
                (cycle * 8..cycle * 8 + 8).map(|t| s.next(t)).collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..8).collect::<Vec<_>>(), "cycle {cycle}");
        }
    }

    #[test]
    fn latency_aware_same_round_is_idempotent() {
        let topo =
            build(&TopologyParams::new(TopologyKind::DepthLinear, 5, 1))
                .unwrap();
        let mut s = ClusterSchedule::latency_aware(&topo, 10_000);
        assert_eq!(s.next(0), s.next(0));
        let a = s.next(1);
        assert_eq!(s.next(1), a, "re-planning a round must not advance");
        let b = s.next(2);
        assert_ne!(a, b);
    }

    #[test]
    fn latency_aware_never_repeats_consecutively() {
        let topo =
            build(&TopologyParams::new(TopologyKind::BreadthParallel, 6, 2))
                .unwrap();
        let mut s = ClusterSchedule::latency_aware(&topo, 50_000);
        let mut last = usize::MAX;
        for t in 0..24 {
            let m = s.next(t);
            assert_ne!(m, last, "round {t}");
            last = m;
        }
    }

    #[test]
    fn latency_aware_idle_matches_hop_aware_on_uniform_links() {
        // DepthLinear's BS chain has uniform per-hop latency, so the idle
        // latency probe ranks candidates exactly like hop counts: the two
        // tours coincide over the first cycle.  (Later cycles diverge by
        // design: HopAware replays its fixed order while LatencyAware
        // re-plans from wherever the previous cycle ended.)
        let topo =
            build(&TopologyParams::new(TopologyKind::DepthLinear, 6, 2))
                .unwrap();
        let mut lat = ClusterSchedule::latency_aware(&topo, 100_000);
        let bs = topo.base_stations();
        let rt = RouteTable::hops(&topo);
        let hops: Vec<Vec<usize>> = bs
            .iter()
            .map(|&a| bs.iter().map(|&b| rt.dist(a, b).unwrap()).collect())
            .collect();
        let mut hop = ClusterSchedule::hop_aware(&hops);
        for t in 0..6 {
            assert_eq!(lat.next(t), hop.next(t), "round {t}");
        }
    }

    #[test]
    fn latency_aware_checkpoint_resumes_the_same_tour() {
        // Run one schedule straight through; checkpoint a second copy
        // mid-cycle (through a JSON text round-trip, like a checkpoint
        // file) and restore into a third built from the same config —
        // the continuation must reproduce the uninterrupted tour.
        let topo =
            build(&TopologyParams::new(TopologyKind::Hybrid, 8, 2)).unwrap();
        let mut whole = ClusterSchedule::latency_aware(&topo, 100_000);
        let reference: Vec<usize> = (0..16).map(|t| whole.next(t)).collect();

        let mut first = ClusterSchedule::latency_aware(&topo, 100_000);
        for (t, &want) in reference.iter().enumerate().take(5) {
            assert_eq!(first.next(t), want);
        }
        let text = first.checkpoint().dump();
        let snap = crate::util::json::Json::parse(&text).unwrap();
        let mut resumed = ClusterSchedule::latency_aware(&topo, 100_000);
        resumed.restore(&snap).unwrap();
        for (t, &want) in reference.iter().enumerate().skip(5) {
            assert_eq!(resumed.next(t), want, "round {t}");
        }
    }

    #[test]
    fn restore_rejects_mismatched_kind_and_size() {
        let topo =
            build(&TopologyParams::new(TopologyKind::DepthLinear, 4, 1)).unwrap();
        let mut lat = ClusterSchedule::latency_aware(&topo, 1_000);
        let seq_snap = ClusterSchedule::sequential(4).checkpoint();
        assert!(lat.restore(&seq_snap).is_err(), "kind mismatch");
        let bigger =
            build(&TopologyParams::new(TopologyKind::DepthLinear, 6, 1)).unwrap();
        let big_snap = ClusterSchedule::latency_aware(&bigger, 1_000).checkpoint();
        assert!(lat.restore(&big_snap).is_err(), "cluster-count mismatch");
        // Matching snapshot restores fine.
        let ok = ClusterSchedule::latency_aware(&topo, 1_000).checkpoint();
        assert!(lat.restore(&ok).is_ok());
    }

    #[test]
    fn latency_aware_prefers_the_less_congested_target() {
        // BreadthParallel's BS ring: after 0 -> 1 the idle tour continues
        // to the adjacent BS2 (one 9 ms hop beats two to BS3).  Saturating
        // the BS1-BS2 ring link must flip the pick to BS3, whose latency
        // route rides the other side of the ring (BS1-BS0-BS3) and stays
        // clean.
        let topo =
            build(&TopologyParams::new(TopologyKind::BreadthParallel, 4, 1))
                .unwrap();
        let mk = || {
            let mut s = ClusterSchedule::latency_aware(&topo, 1_000_000);
            assert_eq!(s.next(0), 0); // anchor
            assert_eq!(s.next(1), 1); // nearest, hop-order tie-break
            s
        };
        let mut idle = mk();
        assert_eq!(idle.next(2), 2, "idle network continues around the ring");

        let mut busy = mk();
        let rt = RouteTable::latency(&topo);
        let mut sim = NetSim::new(&topo);
        let a = topo.edge_bs(1).unwrap();
        let b = topo.edge_bs(2).unwrap();
        for _ in 0..50 {
            sim.submit(&rt, a, b, 10_000_000, 0.0).unwrap();
        }
        assert_eq!(
            busy.next_on(2, Some(&sim)),
            3,
            "congestion on BS1-BS2 must steer the tour to BS3"
        );
    }
}
