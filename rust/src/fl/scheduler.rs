//! Cluster scheduling: which cluster is active at round `t`, and in what
//! order the model migrates (the paper's `m(t)`).

use crate::rng::Rng;

/// EdgeFLow's inter-cluster migration order.
#[derive(Debug)]
pub enum ClusterSchedule {
    /// Fixed cyclic order 0, 1, ..., M-1, 0, ... (EdgeFLowSeq).
    Sequential { clusters: usize },
    /// Uniform random next cluster, never repeating the current one when
    /// M > 1 (EdgeFLowRand).
    Random { clusters: usize, rng: Rng, last: Option<usize> },
    /// Hop-aware circuit (the paper's "wireless-aware scheduling" future
    /// work): a greedy nearest-neighbor tour over the BS hop-distance
    /// matrix — every cluster once per cycle, migrations ride the
    /// cheapest available links.
    HopAware { order: Vec<usize> },
}

impl ClusterSchedule {
    pub fn sequential(clusters: usize) -> ClusterSchedule {
        assert!(clusters > 0);
        ClusterSchedule::Sequential { clusters }
    }

    pub fn random(clusters: usize, seed: u64) -> ClusterSchedule {
        assert!(clusters > 0);
        ClusterSchedule::Random { clusters, rng: Rng::new(seed), last: None }
    }

    /// Greedy nearest-neighbor tour over a pairwise hop matrix
    /// (`hops[i][j]` = hop distance between BS i and BS j).
    pub fn hop_aware(hops: &[Vec<usize>]) -> ClusterSchedule {
        let m = hops.len();
        assert!(m > 0);
        let mut order = Vec::with_capacity(m);
        let mut visited = vec![false; m];
        let mut cur = 0usize;
        order.push(0);
        visited[0] = true;
        for _ in 1..m {
            let next = (0..m)
                .filter(|&j| !visited[j])
                .min_by_key(|&j| (hops[cur][j], j))
                .unwrap();
            order.push(next);
            visited[next] = true;
            cur = next;
        }
        ClusterSchedule::HopAware { order }
    }

    /// The active cluster for round `t`.  For the random schedule this
    /// must be called with consecutive `t` (it advances internal state).
    pub fn next(&mut self, t: usize) -> usize {
        match self {
            ClusterSchedule::Sequential { clusters } => t % *clusters,
            ClusterSchedule::HopAware { order } => order[t % order.len()],
            ClusterSchedule::Random { clusters, rng, last } => {
                let m = if *clusters == 1 {
                    0
                } else {
                    // Avoid training the same cluster twice in a row: the
                    // migration "flow" always moves.
                    loop {
                        let c = rng.below(*clusters);
                        if Some(c) != *last {
                            break c;
                        }
                    }
                };
                *last = Some(m);
                m
            }
        }
    }

    pub fn clusters(&self) -> usize {
        match self {
            ClusterSchedule::Sequential { clusters } => *clusters,
            ClusterSchedule::Random { clusters, .. } => *clusters,
            ClusterSchedule::HopAware { order } => order.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_covers_all_every_m_rounds() {
        let mut s = ClusterSchedule::sequential(4);
        let order: Vec<usize> = (0..8).map(|t| s.next(t)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn random_never_repeats_consecutively() {
        let mut s = ClusterSchedule::random(5, 42);
        let mut last = usize::MAX;
        for t in 0..200 {
            let m = s.next(t);
            assert!(m < 5);
            assert_ne!(m, last);
            last = m;
        }
    }

    #[test]
    fn random_visits_all_clusters_uniformly() {
        let mut s = ClusterSchedule::random(5, 7);
        let mut counts = [0usize; 5];
        for t in 0..5000 {
            counts[s.next(t)] += 1;
        }
        for c in counts {
            // expectation 1000 each
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn single_cluster_degenerates() {
        let mut s = ClusterSchedule::random(1, 0);
        assert_eq!(s.next(0), 0);
        assert_eq!(s.next(1), 0);
    }

    #[test]
    fn hop_aware_visits_all_following_cheap_links() {
        // Line graph distances: 0-1-2-3 => tour must be 0,1,2,3.
        let hops = vec![
            vec![0, 1, 2, 3],
            vec![1, 0, 1, 2],
            vec![2, 1, 0, 1],
            vec![3, 2, 1, 0],
        ];
        let mut s = ClusterSchedule::hop_aware(&hops);
        let tour: Vec<usize> = (0..4).map(|t| s.next(t)).collect();
        assert_eq!(tour, vec![0, 1, 2, 3]);
        // cycles
        assert_eq!(s.next(4), 0);
        assert_eq!(s.clusters(), 4);
    }

    #[test]
    fn hop_aware_prefers_near_over_far() {
        // Star around 0 with one distant node 3.
        let hops = vec![
            vec![0, 1, 1, 5],
            vec![1, 0, 2, 6],
            vec![1, 2, 0, 6],
            vec![5, 6, 6, 0],
        ];
        let mut s = ClusterSchedule::hop_aware(&hops);
        let tour: Vec<usize> = (0..4).map(|t| s.next(t)).collect();
        assert_eq!(tour[3], 3, "distant cluster visited last: {tour:?}");
    }

    #[test]
    fn random_is_seed_deterministic() {
        let mut a = ClusterSchedule::random(6, 9);
        let mut b = ClusterSchedule::random(6, 9);
        for t in 0..50 {
            assert_eq!(a.next(t), b.next(t));
        }
    }
}
