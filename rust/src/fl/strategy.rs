//! Round planning for every FL algorithm.
//!
//! A [`Strategy`] decides, per round, which clients train, where the
//! aggregation happens, and where the model lives afterwards.  The runner
//! executes the plan (local updates + aggregation) and [`super::comm`]
//! turns it into transfers over the topology.

use crate::config::{Algorithm, ExperimentConfig};
use crate::data::partition::Federation;
use crate::fl::scheduler::ClusterSchedule;
use crate::netsim::NetSim;
use crate::rng::{Rng, RngState};
use crate::topology::graph::Topology;
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Where this round's aggregation runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregationSite {
    Cloud,
    /// Edge base station of cluster m.
    EdgeBs(usize),
    /// No aggregation (sequential pass-through).
    None,
}

/// One round's execution plan.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Clients that run local updates this round, grouped by cluster
    /// (cluster id, member client ids).  FedAvg uses a single pseudo-group
    /// tagged with cluster = usize::MAX.
    pub groups: Vec<(usize, Vec<usize>)>,
    /// Reporting label: "the" active cluster (first group).
    pub cluster: usize,
    pub aggregation: AggregationSite,
    /// Where the model migrates after aggregation (EdgeFLow only):
    /// (from_cluster, to_cluster).
    pub migration: Option<(usize, usize)>,
}

impl RoundPlan {
    /// All participant ids, flattened.
    pub fn participants(&self) -> Vec<usize> {
        self.groups.iter().flat_map(|(_, v)| v.iter().copied()).collect()
    }
}

/// Per-algorithm round planner.
#[derive(Debug)]
pub enum Strategy {
    /// Random `n_sample` clients/round, cloud aggregation.
    FedAvg { rng: Rng, n_sample: usize },
    /// All clusters train; edge pre-aggregation then cloud aggregation.
    HierFl,
    /// One client per round; model hops client -> client.
    // lint:allow(checkpoint-parity): `order` is a pure function of the
    // config seed (Rng::new(seed).shuffle) and is rebuilt on restore;
    // only the cursor/last_cluster travel in the checkpoint.
    SeqFl { order: Vec<usize>, cursor: usize, last_cluster: Option<usize> },
    /// EdgeFLow: one active cluster per round, model migrates BS -> BS.
    EdgeFlow { schedule: ClusterSchedule, current: usize },
}

impl Strategy {
    /// Build the strategy for an experiment config.  `topo` supplies the
    /// BS hop-distance matrix for the hop-aware migration circuit;
    /// `model_bytes` sizes the latency-aware schedule's probe transfers
    /// (the migrating model's wire bytes).
    pub fn for_config(
        cfg: &ExperimentConfig,
        fed: &Federation,
        topo: &Topology,
        model_bytes: u64,
    ) -> Strategy {
        let seed = cfg.seed ^ 0x57A7E617;
        match cfg.algorithm {
            Algorithm::EdgeFlowLatency => Strategy::EdgeFlow {
                schedule: ClusterSchedule::latency_aware(topo, model_bytes),
                current: 0,
            },
            Algorithm::EdgeFlowHop => {
                let bs = topo.base_stations();
                let rt = RouteTable::hops(topo);
                let hops: Vec<Vec<usize>> = bs
                    .iter()
                    .map(|&a| {
                        bs.iter()
                            .map(|&b| rt.dist(a, b).unwrap_or(usize::MAX / 2))
                            .collect()
                    })
                    .collect();
                Strategy::EdgeFlow {
                    schedule: ClusterSchedule::hop_aware(&hops),
                    current: 0,
                }
            }
            Algorithm::FedAvg => Strategy::FedAvg {
                rng: Rng::new(seed),
                n_sample: cfg.cluster_size(),
            },
            Algorithm::HierFl => Strategy::HierFl,
            Algorithm::SeqFl => {
                let mut order: Vec<usize> = (0..fed.clients.len()).collect();
                Rng::new(seed).shuffle(&mut order);
                Strategy::SeqFl { order, cursor: 0, last_cluster: None }
            }
            Algorithm::EdgeFlowRand => Strategy::EdgeFlow {
                schedule: ClusterSchedule::random(cfg.clusters, seed),
                current: 0,
            },
            Algorithm::EdgeFlowSeq => Strategy::EdgeFlow {
                schedule: ClusterSchedule::sequential(cfg.clusters),
                current: 0,
            },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::FedAvg { .. } => "fedavg",
            Strategy::HierFl => "hierfl",
            Strategy::SeqFl { .. } => "seqfl",
            Strategy::EdgeFlow { schedule, .. } => match schedule {
                ClusterSchedule::Sequential { .. } => "edgeflow_seq",
                ClusterSchedule::Random { .. } => "edgeflow_rand",
                ClusterSchedule::HopAware { .. } => "edgeflow_hop",
                ClusterSchedule::LatencyAware { .. } => "edgeflow_latency",
            },
        }
    }

    /// Plan round `t`.  `net` is the live network state, read only by the
    /// latency-aware migration schedule (pass `None` for the static
    /// planners — they ignore it).
    pub fn plan_round(
        &mut self,
        t: usize,
        fed: &Federation,
        net: Option<&NetSim>,
    ) -> RoundPlan {
        match self {
            Strategy::FedAvg { rng, n_sample } => {
                let all = fed.clients.len();
                let mut picks = rng.sample_indices(all, (*n_sample).min(all));
                // Sort so aggregation order (and hence f32 rounding) is a
                // function of the participant *set*, not the draw order —
                // keeps e.g. full participation bit-identical to EdgeFLow
                // with M = 1.
                picks.sort_unstable();
                RoundPlan {
                    groups: vec![(usize::MAX, picks)],
                    cluster: usize::MAX,
                    aggregation: AggregationSite::Cloud,
                    migration: None,
                }
            }
            Strategy::HierFl => {
                let groups = (0..fed.clusters)
                    .map(|m| (m, fed.cluster_members(m)))
                    .collect();
                RoundPlan {
                    groups,
                    cluster: usize::MAX,
                    aggregation: AggregationSite::Cloud,
                    migration: None,
                }
            }
            Strategy::SeqFl { order, cursor, last_cluster } => {
                let id = order[*cursor % order.len()];
                *cursor += 1;
                let cluster = fed.clients[id].cluster;
                // The model hops from the previous trainer's site to this
                // one — that inter-site transfer is SeqFl's whole comm
                // story and must be accounted.
                let migration = last_cluster
                    .filter(|&c| c != cluster)
                    .map(|c| (c, cluster));
                *last_cluster = Some(cluster);
                RoundPlan {
                    groups: vec![(cluster, vec![id])],
                    cluster,
                    aggregation: AggregationSite::None,
                    migration,
                }
            }
            Strategy::EdgeFlow { schedule, current } => {
                let m = schedule.next_on(t, net);
                let from = *current;
                *current = m;
                RoundPlan {
                    groups: vec![(m, fed.cluster_members(m))],
                    cluster: m,
                    aggregation: AggregationSite::EdgeBs(m),
                    // Migration happens *after* the round: recorded as the
                    // hop taken to reach the next cluster; for reporting we
                    // attribute the hop from the previous active cluster.
                    migration: if t == 0 { None } else { Some((from, m)) },
                }
            }
        }
    }

    /// Serializable planner state for checkpoint/resume: the FedAvg
    /// sampling stream, SeqFL's tour cursor and previous site, and
    /// EdgeFLow's current cluster + schedule bookkeeping — everything
    /// that makes round `t+1`'s plan depend on history.  (`HierFl` plans
    /// are stateless.)
    pub fn checkpoint(&self) -> Json {
        match self {
            Strategy::FedAvg { rng, n_sample } => Json::obj(vec![
                ("kind", "fedavg".into()),
                ("rng", rng.state().to_json()),
                ("n_sample", (*n_sample).into()),
            ]),
            Strategy::HierFl => Json::obj(vec![("kind", "hierfl".into())]),
            Strategy::SeqFl { cursor, last_cluster, .. } => Json::obj(vec![
                ("kind", "seqfl".into()),
                ("cursor", (*cursor).into()),
                (
                    "last_cluster",
                    match last_cluster {
                        Some(c) => Json::from(*c),
                        None => Json::Null,
                    },
                ),
            ]),
            Strategy::EdgeFlow { schedule, current } => Json::obj(vec![
                ("kind", "edgeflow".into()),
                ("current", (*current).into()),
                ("schedule", schedule.checkpoint()),
            ]),
        }
    }

    /// Restore a [`Strategy::checkpoint`] snapshot onto a strategy built
    /// from the same config (the derived pieces — SeqFL's shuffled
    /// order, EdgeFLow's tour matrices — are rebuilt by
    /// [`Strategy::for_config`]; only the mutable cursors travel).
    pub fn restore(&mut self, j: &Json) -> Result<()> {
        let kind = j.str_field("kind")?;
        match (self, kind) {
            (Strategy::FedAvg { rng, n_sample }, "fedavg") => {
                *rng = Rng::from_state(&RngState::from_json(j.req("rng")?)?);
                *n_sample = j.usize_field("n_sample")?;
            }
            (Strategy::HierFl, "hierfl") => {}
            (Strategy::SeqFl { cursor, last_cluster, .. }, "seqfl") => {
                *cursor = j.usize_field("cursor")?;
                *last_cluster = match j.req("last_cluster")? {
                    Json::Null => None,
                    v => Some(v.as_usize().ok_or_else(|| {
                        Error::Json("last_cluster must be an integer".into())
                    })?),
                };
            }
            (Strategy::EdgeFlow { schedule, current }, "edgeflow") => {
                *current = j.usize_field("current")?;
                schedule.restore(j.req("schedule")?)?;
            }
            (other, kind) => {
                return Err(Error::Config(format!(
                    "checkpoint strategy kind {kind:?} does not match the \
                     configured {:?}",
                    other.name()
                )))
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, Distribution, TopologyKind};
    use crate::data::partition::build_federation;
    use crate::topology::builder::{build, TopologyParams};

    fn fed() -> Federation {
        build_federation(
            DatasetKind::SynthFashion,
            &Distribution::Iid,
            20,
            4,
            40,
            20,
            1,
        )
        .unwrap()
    }

    fn topo() -> Topology {
        build(&TopologyParams::new(TopologyKind::DepthLinear, 4, 5)).unwrap()
    }

    fn cfg(alg: Algorithm) -> ExperimentConfig {
        ExperimentConfig {
            algorithm: alg,
            clients: 20,
            clusters: 4,
            samples_per_client: 40,
            batch_size: 8,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn fedavg_samples_cluster_size_clients() {
        let f = fed();
        let mut s = Strategy::for_config(&cfg(Algorithm::FedAvg), &f, &topo(), 40_000);
        let p = s.plan_round(0, &f, None);
        assert_eq!(p.participants().len(), 5);
        assert_eq!(p.aggregation, AggregationSite::Cloud);
        assert!(p.migration.is_none());
        // distinct clients
        let mut ids = p.participants();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5);
    }

    #[test]
    fn fedavg_resamples_every_round() {
        let f = fed();
        let mut s = Strategy::for_config(&cfg(Algorithm::FedAvg), &f, &topo(), 40_000);
        let a = s.plan_round(0, &f, None).participants();
        let b = s.plan_round(1, &f, None).participants();
        assert_ne!(a, b); // overwhelmingly likely with 20 choose 5
    }

    #[test]
    fn hierfl_includes_everyone_grouped() {
        let f = fed();
        let mut s = Strategy::for_config(&cfg(Algorithm::HierFl), &f, &topo(), 40_000);
        let p = s.plan_round(0, &f, None);
        assert_eq!(p.groups.len(), 4);
        assert_eq!(p.participants().len(), 20);
    }

    #[test]
    fn seqfl_walks_one_client_at_a_time() {
        let f = fed();
        let mut s = Strategy::for_config(&cfg(Algorithm::SeqFl), &f, &topo(), 40_000);
        let mut seen = std::collections::BTreeSet::new();
        for t in 0..20 {
            let p = s.plan_round(t, &f, None);
            assert_eq!(p.participants().len(), 1);
            assert_eq!(p.aggregation, AggregationSite::None);
            seen.insert(p.participants()[0]);
        }
        assert_eq!(seen.len(), 20); // full permutation before repeats
    }

    #[test]
    fn edgeflow_seq_activates_whole_cluster_cyclically() {
        let f = fed();
        let mut s = Strategy::for_config(&cfg(Algorithm::EdgeFlowSeq), &f, &topo(), 40_000);
        for t in 0..8 {
            let p = s.plan_round(t, &f, None);
            assert_eq!(p.cluster, t % 4);
            assert_eq!(p.groups[0].1.len(), 5);
            assert_eq!(p.aggregation, AggregationSite::EdgeBs(t % 4));
            if t > 0 {
                assert_eq!(p.migration, Some(((t - 1) % 4, t % 4)));
            }
        }
    }

    #[test]
    fn edgeflow_latency_tours_all_clusters() {
        let f = fed();
        let t = topo();
        let mut s =
            Strategy::for_config(&cfg(Algorithm::EdgeFlowLatency), &f, &t, 40_000);
        assert_eq!(s.name(), "edgeflow_latency");
        let mut seen = std::collections::BTreeSet::new();
        for t_round in 0..4 {
            let p = s.plan_round(t_round, &f, None);
            assert_eq!(p.aggregation, AggregationSite::EdgeBs(p.cluster));
            assert_eq!(p.groups[0].1.len(), 5);
            if t_round > 0 {
                let (from, to) = p.migration.unwrap();
                assert_ne!(from, to, "tour must keep moving");
            }
            seen.insert(p.cluster);
        }
        assert_eq!(seen.len(), 4, "every cluster visited in one cycle");
    }

    #[test]
    fn checkpoint_resumes_fedavg_sampling_stream() {
        let f = fed();
        let t = topo();
        let mut whole = Strategy::for_config(&cfg(Algorithm::FedAvg), &f, &t, 40_000);
        let reference: Vec<Vec<usize>> =
            (0..8).map(|r| whole.plan_round(r, &f, None).participants()).collect();

        let mut first = Strategy::for_config(&cfg(Algorithm::FedAvg), &f, &t, 40_000);
        for (r, want) in reference.iter().enumerate().take(3) {
            assert_eq!(&first.plan_round(r, &f, None).participants(), want);
        }
        let snap_text = first.checkpoint().dump();
        let snap = crate::util::json::Json::parse(&snap_text).unwrap();
        let mut resumed =
            Strategy::for_config(&cfg(Algorithm::FedAvg), &f, &t, 40_000);
        resumed.restore(&snap).unwrap();
        for (r, want) in reference.iter().enumerate().skip(3) {
            assert_eq!(
                &resumed.plan_round(r, &f, None).participants(),
                want,
                "round {r}"
            );
        }
    }

    #[test]
    fn checkpoint_resumes_seqfl_and_edgeflow_migration_state() {
        let f = fed();
        let t = topo();
        for alg in [Algorithm::SeqFl, Algorithm::EdgeFlowSeq, Algorithm::EdgeFlowHop]
        {
            let mut whole = Strategy::for_config(&cfg(alg), &f, &t, 40_000);
            let reference: Vec<(Vec<usize>, Option<(usize, usize)>)> = (0..8)
                .map(|r| {
                    let p = whole.plan_round(r, &f, None);
                    (p.participants(), p.migration)
                })
                .collect();
            let mut first = Strategy::for_config(&cfg(alg), &f, &t, 40_000);
            for r in 0..4 {
                first.plan_round(r, &f, None);
            }
            let snap = crate::util::json::Json::parse(&first.checkpoint().dump())
                .unwrap();
            let mut resumed = Strategy::for_config(&cfg(alg), &f, &t, 40_000);
            resumed.restore(&snap).unwrap();
            for (r, want) in reference.iter().enumerate().skip(4) {
                let p = resumed.plan_round(r, &f, None);
                assert_eq!(p.participants(), want.0, "{alg:?} round {r}");
                assert_eq!(
                    p.migration, want.1,
                    "{alg:?} round {r}: migration state must survive restore"
                );
            }
        }
    }

    #[test]
    fn restore_rejects_wrong_strategy_kind() {
        let f = fed();
        let t = topo();
        let snap = Strategy::for_config(&cfg(Algorithm::HierFl), &f, &t, 40_000)
            .checkpoint();
        let mut fedavg = Strategy::for_config(&cfg(Algorithm::FedAvg), &f, &t, 40_000);
        assert!(fedavg.restore(&snap).is_err());
    }

    #[test]
    fn edgeflow_members_match_federation() {
        let f = fed();
        let mut s = Strategy::for_config(&cfg(Algorithm::EdgeFlowRand), &f, &topo(), 40_000);
        for t in 0..10 {
            let p = s.plan_round(t, &f, None);
            let m = p.cluster;
            for &id in &p.groups[0].1 {
                assert_eq!(f.clients[id].cluster, m);
            }
        }
    }
}
