//! Declarative experiment campaigns — the measurement layer over
//! [`crate::fl::experiments`].
//!
//! A campaign is a JSON spec: a `base` [`crate::config::ExperimentConfig`]
//! plus named sweep axes (algorithm × topology × codec × optimizer ×
//! engine × straggler policy × deadline/plateau knobs — any config field),
//! expanded into a cell grid with deterministic per-cell seeds and run on
//! the experiments cell pool via the stepwise `Runner::step()` path.
//!
//! * [`spec`] — the spec vocabulary: [`CampaignSpec`] / [`Axis`] /
//!   [`AxisCell`], grid expansion, per-cell seed derivation, the
//!   semantic digest that binds journals and reports to a spec.
//! * [`exec`] — pool execution with an append-only JSONL journal:
//!   completed cells are checkpointed per record, so a killed campaign
//!   resumes by skipping them, and the resumed report is byte-identical
//!   to an uninterrupted run's.
//! * [`report`] — the schema-versioned comparison report (per-cell
//!   metrics + cross-cell winner tables), the `--baseline` regression
//!   check (fails only on metric regressions beyond a tolerance,
//!   mirroring the lint's baseline workflow), and the
//!   `BENCH_campaign.json` trajectory emitter.
//!
//! The CLI front end is `edgeflow campaign run|validate|report`.

pub mod exec;
pub mod report;
pub mod spec;

pub use exec::{run_campaign, CampaignOptions, CampaignOutcome};
pub use report::{
    append_bench, parse_baseline, regressions, render_report, winners,
    BaselineCell, CellResult,
};
pub use spec::{cell_seed, Axis, AxisCell, CampaignCell, CampaignSpec};
