//! Campaign comparison reports, baseline regression checks, and the
//! `BENCH_campaign.json` trajectory emitter.
//!
//! A report is schema-versioned JSON: per-cell headline metrics (decimal
//! for humans, hex bit patterns for bit-exact comparison) plus the
//! deterministic per-round records, and cross-cell winner tables.
//! Wall-clock fields (`train_s`, `aggregate_s`, phase timings) are
//! excluded on purpose — they measure the host process, not the run, and
//! the report contract is *byte-identical output for the same spec* at
//! any worker split, resumed or not.
//!
//! `--baseline` mirrors the lint's workflow: parse an older report,
//! match cells **by id** (immune to grid reordering), and fail only on
//! metric regressions beyond a relative tolerance.

use std::collections::BTreeMap;

use crate::fl::runner::RunReport;
use crate::metrics::RoundRecord;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::{f64_from_hex, f64_to_hex, u64_from_hex, u64_to_hex};

use super::spec::{CampaignCell, CampaignSpec};

/// Report schema version (`"version"` in the JSON).
pub const REPORT_VERSION: u64 = 1;
/// Trajectory file schema version.
pub const BENCH_VERSION: u64 = 1;

/// One completed cell's results — the unit the journal persists and the
/// report renders.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub index: usize,
    pub id: String,
    pub seed: u64,
    /// The resolved cell config, execution knobs stripped (a report must
    /// not change when only the worker split does).
    pub config: Json,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    /// Cumulative wire bytes (codec-accounted byte-hops).
    pub wire_bytes: u64,
    /// Simulated makespan: the DES clock at the end of the last round.
    pub clock_s: f64,
    /// Rounds actually executed (early stop included).
    pub rounds: usize,
    pub records: Vec<RoundRecord>,
}

/// `cfg.to_json()` with the execution knobs removed.
fn strip_exec_knobs(config: Json) -> Json {
    match config {
        Json::Obj(mut m) => {
            m.remove("workers");
            Json::Obj(m)
        }
        other => other,
    }
}

impl CellResult {
    pub fn from_report(cell: &CampaignCell, report: &RunReport) -> CellResult {
        CellResult {
            index: cell.index,
            id: cell.id.clone(),
            seed: cell.seed,
            config: strip_exec_knobs(cell.cfg.to_json()),
            final_accuracy: report.final_accuracy,
            best_accuracy: report.best_accuracy,
            final_loss: report.final_loss,
            wire_bytes: report.total_byte_hops,
            clock_s: report
                .metrics
                .rounds
                .last()
                .map(|r| r.clock_s)
                .unwrap_or(f64::NAN),
            rounds: report.rounds,
            records: report.metrics.rounds.clone(),
        }
    }

    // ------------------------------------------------------------- journal

    /// Checkpoint-grade JSON for the campaign journal: every float as a
    /// bit pattern, records in [`RoundRecord::to_ckpt_json`] form — a
    /// resumed campaign re-renders the exact bytes the cell produced.
    pub fn to_journal_json(&self) -> Json {
        Json::obj(vec![
            ("index", self.index.into()),
            ("id", self.id.as_str().into()),
            ("seed", self.seed.into()),
            ("config", self.config.clone()),
            ("final_accuracy_hex", f64_to_hex(self.final_accuracy).as_str().into()),
            ("best_accuracy_hex", f64_to_hex(self.best_accuracy).as_str().into()),
            ("final_loss_hex", f64_to_hex(self.final_loss).as_str().into()),
            ("wire_bytes_hex", u64_to_hex(self.wire_bytes).as_str().into()),
            ("clock_s_hex", f64_to_hex(self.clock_s).as_str().into()),
            ("rounds", self.rounds.into()),
            (
                "records",
                Json::arr(self.records.iter().map(RoundRecord::to_ckpt_json)),
            ),
        ])
    }

    /// Inverse of [`CellResult::to_journal_json`].
    pub fn from_journal_json(j: &Json) -> Result<CellResult> {
        let hex_f64 = |k: &str| -> Result<f64> { f64_from_hex(j.str_field(k)?) };
        let records = j
            .req("records")?
            .as_arr()
            .ok_or_else(|| Error::Json("journal \"records\" must be an array".into()))?
            .iter()
            .map(RoundRecord::from_ckpt_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(CellResult {
            index: j.usize_field("index")?,
            id: j.str_field("id")?.to_string(),
            seed: j.req("seed")?.as_u64().ok_or_else(|| {
                Error::Json("journal \"seed\" must be an integer".into())
            })?,
            config: j.req("config")?.clone(),
            final_accuracy: hex_f64("final_accuracy_hex")?,
            best_accuracy: hex_f64("best_accuracy_hex")?,
            final_loss: hex_f64("final_loss_hex")?,
            wire_bytes: u64_from_hex(j.str_field("wire_bytes_hex")?)?,
            clock_s: hex_f64("clock_s_hex")?,
            rounds: j.usize_field("rounds")?,
            records,
        })
    }

    // -------------------------------------------------------------- report

    /// The deterministic slice of a round record: wall-clock `train_s` /
    /// `aggregate_s` are dropped (see the module docs); `cluster` rides
    /// as hex because the "no cluster" sentinel is `usize::MAX`.
    fn det_record_json(r: &RoundRecord) -> Json {
        Json::obj(vec![
            ("round", r.round.into()),
            ("cluster", u64_to_hex(r.cluster as u64).as_str().into()),
            ("train_loss", r.train_loss.into()),
            ("test_accuracy", r.test_accuracy.into()),
            ("test_loss", r.test_loss.into()),
            ("comm_byte_hops", r.comm_byte_hops.into()),
            ("net_s", r.net_s.into()),
            ("clock_s", r.clock_s.into()),
            ("stragglers", Json::arr(r.stragglers.iter().map(|&s| Json::from(s)))),
            ("deferred", Json::arr(r.deferred.iter().map(|&s| Json::from(s)))),
        ])
    }

    /// This cell's report entry: headline metrics in decimal (human) and
    /// hex (bit-exact baseline comparison) plus the deterministic records.
    pub fn report_json(&self) -> Json {
        Json::obj(vec![
            ("index", self.index.into()),
            ("id", self.id.as_str().into()),
            ("seed", self.seed.into()),
            ("config", self.config.clone()),
            ("final_accuracy", self.final_accuracy.into()),
            ("final_accuracy_hex", f64_to_hex(self.final_accuracy).as_str().into()),
            ("best_accuracy", self.best_accuracy.into()),
            ("best_accuracy_hex", f64_to_hex(self.best_accuracy).as_str().into()),
            ("final_loss", self.final_loss.into()),
            ("final_loss_hex", f64_to_hex(self.final_loss).as_str().into()),
            ("wire_bytes", self.wire_bytes.into()),
            ("clock_s", self.clock_s.into()),
            ("clock_s_hex", f64_to_hex(self.clock_s).as_str().into()),
            ("rounds", self.rounds.into()),
            ("records", Json::arr(self.records.iter().map(Self::det_record_json))),
        ])
    }
}

// ------------------------------------------------------------------ winners

/// Pick the best finite cell under `metric`; ties keep the lowest index.
fn best_by(
    cells: &[CellResult],
    metric: fn(&CellResult) -> f64,
    minimize: bool,
) -> Json {
    let mut best: Option<(usize, f64)> = None;
    for (i, c) in cells.iter().enumerate() {
        let v = metric(c);
        if !v.is_finite() {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, bv)) => {
                let ord = v.total_cmp(&bv);
                if minimize {
                    ord == std::cmp::Ordering::Less
                } else {
                    ord == std::cmp::Ordering::Greater
                }
            }
        };
        if better {
            best = Some((i, v));
        }
    }
    match best {
        None => Json::Null,
        Some((i, v)) => Json::obj(vec![
            ("cell", cells[i].id.as_str().into()),
            ("value", v.into()),
        ]),
    }
}

/// Cross-cell winner tables: final loss/accuracy, cumulative wire bytes,
/// simulated makespan.  A metric nobody evaluated is `null`.
pub fn winners(cells: &[CellResult]) -> Json {
    let min_wire = cells
        .iter()
        .min_by_key(|c| (c.wire_bytes, c.index))
        .map(|c| {
            Json::obj(vec![
                ("cell", c.id.as_str().into()),
                ("value", c.wire_bytes.into()),
            ])
        })
        .unwrap_or(Json::Null);
    Json::obj(vec![
        ("max_final_accuracy", best_by(cells, |c| c.final_accuracy, false)),
        ("min_final_loss", best_by(cells, |c| c.final_loss, true)),
        ("min_wire_bytes", min_wire),
        ("min_clock_s", best_by(cells, |c| c.clock_s, true)),
    ])
}

// ------------------------------------------------------------------- report

/// Render the full comparison report (pretty JSON + trailing newline).
/// Deterministic: cells in grid order, objects key-sorted, no wall-clock
/// fields — the same spec renders the same bytes on any host.
pub fn render_report(spec: &CampaignSpec, cells: &[CellResult]) -> String {
    let j = Json::obj(vec![
        ("version", REPORT_VERSION.into()),
        ("campaign", spec.name.as_str().into()),
        ("seed", spec.seed.into()),
        ("spec_digest", spec.digest().as_str().into()),
        ("cells", Json::arr(cells.iter().map(CellResult::report_json))),
        ("winners", winners(cells)),
    ]);
    let mut out = j.pretty();
    out.push('\n');
    out
}

// ----------------------------------------------------------------- baseline

/// A cell's bit-exact headline metrics as read back from a report — the
/// comparison unit of the `--baseline` workflow.
#[derive(Debug, Clone)]
pub struct BaselineCell {
    pub id: String,
    pub final_accuracy: f64,
    pub final_loss: f64,
    pub wire_bytes: u64,
    pub clock_s: f64,
}

impl BaselineCell {
    pub fn from_result(c: &CellResult) -> BaselineCell {
        BaselineCell {
            id: c.id.clone(),
            final_accuracy: c.final_accuracy,
            final_loss: c.final_loss,
            wire_bytes: c.wire_bytes,
            clock_s: c.clock_s,
        }
    }
}

/// Parse a comparison report into its baseline view.  Rejects other
/// schema versions — regeneration beats misinterpretation, same policy
/// as the lint's baseline parser.
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineCell>> {
    let j = Json::parse(text)
        .map_err(|e| Error::Config(format!("baseline report: {e}")))?;
    match j.get("version").and_then(Json::as_u64) {
        Some(REPORT_VERSION) => {}
        other => {
            return Err(Error::Config(format!(
                "baseline report version {other:?} unsupported (this build reads \
                 {REPORT_VERSION}) — regenerate it"
            )))
        }
    }
    let cells = j
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Config("baseline report has no \"cells\" array".into()))?;
    let mut out = Vec::with_capacity(cells.len());
    for c in cells {
        out.push(BaselineCell {
            id: c.str_field("id")?.to_string(),
            final_accuracy: f64_from_hex(c.str_field("final_accuracy_hex")?)?,
            final_loss: f64_from_hex(c.str_field("final_loss_hex")?)?,
            wire_bytes: c.req("wire_bytes")?.as_u64().ok_or_else(|| {
                Error::Config("baseline cell \"wire_bytes\" must be an integer".into())
            })?,
            clock_s: f64_from_hex(c.str_field("clock_s_hex")?)?,
        });
    }
    Ok(out)
}

/// Compare a run against a baseline: one message per metric regression
/// beyond the relative tolerance, empty when clean.  Cells match by id,
/// so grid reordering and added cells never fail; a baseline cell
/// missing from the new report does.  Strict inequalities mean a
/// bit-identical re-run passes even at tolerance 0.
pub fn regressions(
    new: &[BaselineCell],
    old: &[BaselineCell],
    tolerance: f64,
) -> Vec<String> {
    let by_id: BTreeMap<&str, &BaselineCell> =
        new.iter().map(|c| (c.id.as_str(), c)).collect();
    let mut out = Vec::new();
    for o in old {
        let Some(n) = by_id.get(o.id.as_str()) else {
            out.push(format!(
                "cell {:?}: present in baseline but missing from this report",
                o.id
            ));
            continue;
        };
        // "higher is worse" metrics, then accuracy (lower is worse).
        let worse_up = [
            ("final_loss", o.final_loss, n.final_loss),
            ("wire_bytes", o.wire_bytes as f64, n.wire_bytes as f64),
            ("clock_s", o.clock_s, n.clock_s),
        ];
        for (metric, old_v, new_v) in worse_up {
            if !old_v.is_finite() {
                continue; // nothing to regress from
            }
            if !new_v.is_finite() {
                out.push(format!(
                    "cell {:?}: {metric} became non-finite (baseline {old_v})",
                    o.id
                ));
            } else if new_v > old_v + tolerance * old_v.abs() {
                out.push(format!(
                    "cell {:?}: {metric} regressed {old_v} -> {new_v} \
                     (tolerance {tolerance})",
                    o.id
                ));
            }
        }
        let (old_v, new_v) = (o.final_accuracy, n.final_accuracy);
        if old_v.is_finite() {
            if !new_v.is_finite() {
                out.push(format!(
                    "cell {:?}: final_accuracy became non-finite (baseline {old_v})",
                    o.id
                ));
            } else if new_v < old_v - tolerance * old_v.abs() {
                out.push(format!(
                    "cell {:?}: final_accuracy regressed {old_v} -> {new_v} \
                     (tolerance {tolerance})",
                    o.id
                ));
            }
        }
    }
    out
}

// --------------------------------------------------------------- trajectory

/// Append this campaign's headline results to a `BENCH_campaign.json`
/// trajectory file so quality/perf history accumulates across PRs.  The
/// file is `{"version": 1, "runs": [...]}`; each run records the digest,
/// winners, and per-cell summary — no timestamps (the run's identity is
/// its digest, and trajectory bytes must be reproducible).  The write is
/// atomic (tmp + rename) like checkpoint saves.
pub fn append_bench(
    path: &str,
    spec: &CampaignSpec,
    cells: &[CellResult],
) -> Result<()> {
    let mut runs = match std::fs::read_to_string(path) {
        Ok(text) => {
            let j = Json::parse(&text)
                .map_err(|e| Error::Config(format!("trajectory {path:?}: {e}")))?;
            match j.get("version").and_then(Json::as_u64) {
                Some(BENCH_VERSION) => {}
                other => {
                    return Err(Error::Config(format!(
                        "trajectory {path:?} version {other:?} unsupported (this \
                         build writes {BENCH_VERSION})"
                    )))
                }
            }
            j.get("runs")
                .and_then(Json::as_arr)
                .map(<[Json]>::to_vec)
                .unwrap_or_default()
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(e.into()),
    };
    let summary = cells.iter().map(|c| {
        Json::obj(vec![
            ("id", c.id.as_str().into()),
            ("final_accuracy", c.final_accuracy.into()),
            ("final_loss", c.final_loss.into()),
            ("wire_bytes", c.wire_bytes.into()),
            ("clock_s", c.clock_s.into()),
        ])
    });
    runs.push(Json::obj(vec![
        ("campaign", spec.name.as_str().into()),
        ("spec_digest", spec.digest().as_str().into()),
        ("cells", cells.len().into()),
        ("winners", winners(cells)),
        ("cells_summary", Json::arr(summary)),
    ]));
    let out = Json::obj(vec![
        ("version", BENCH_VERSION.into()),
        ("runs", Json::arr(runs)),
    ]);
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, format!("{}\n", out.pretty()))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(id: &str, idx: usize, loss: f64, acc: f64, wire: u64, clock: f64) -> CellResult {
        CellResult {
            index: idx,
            id: id.into(),
            seed: 1,
            config: Json::obj(vec![]),
            final_accuracy: acc,
            best_accuracy: acc,
            final_loss: loss,
            wire_bytes: wire,
            clock_s: clock,
            rounds: 1,
            records: Vec::new(),
        }
    }

    #[test]
    fn winners_pick_extremes_and_skip_nan() {
        let cells = vec![
            cell("a", 0, 0.5, 0.8, 100, 3.0),
            cell("b", 1, 0.4, f64::NAN, 200, 2.0),
            cell("c", 2, 0.4, 0.9, 300, 4.0),
        ];
        let w = winners(&cells);
        let get = |table: &str| {
            w.get(table)
                .and_then(|t| t.get("cell"))
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_default()
        };
        assert_eq!(get("max_final_accuracy"), "c");
        assert_eq!(get("min_final_loss"), "b", "ties keep the earlier index");
        assert_eq!(get("min_wire_bytes"), "a");
        assert_eq!(get("min_clock_s"), "b");
        // all-NaN metric yields null, not a panic
        let nan = vec![cell("x", 0, f64::NAN, f64::NAN, 1, f64::NAN)];
        assert!(matches!(
            winners(&nan).get("max_final_accuracy"),
            Some(Json::Null)
        ));
    }

    #[test]
    fn regressions_fire_only_beyond_tolerance() {
        let old = vec![cell("a", 0, 0.50, 0.80, 100, 3.0)]
            .iter()
            .map(BaselineCell::from_result)
            .collect::<Vec<_>>();
        // identical run: clean at tolerance 0
        assert!(regressions(&old, &old, 0.0).is_empty());
        // worse loss fails at 0, passes within 10%
        let worse = vec![BaselineCell {
            final_loss: 0.54,
            ..old[0].clone()
        }];
        assert_eq!(regressions(&worse, &old, 0.0).len(), 1);
        assert!(regressions(&worse, &old, 0.1).is_empty());
        // lower accuracy is a regression; higher is not
        let lower = vec![BaselineCell { final_accuracy: 0.7, ..old[0].clone() }];
        assert_eq!(regressions(&lower, &old, 0.0).len(), 1);
        let higher = vec![BaselineCell { final_accuracy: 0.9, ..old[0].clone() }];
        assert!(regressions(&higher, &old, 0.0).is_empty());
        // NaN where the baseline was finite is always a regression
        let nan = vec![BaselineCell { final_accuracy: f64::NAN, ..old[0].clone() }];
        assert_eq!(regressions(&nan, &old, 1.0).len(), 1);
        // a missing cell fails; an added cell does not
        assert_eq!(regressions(&[], &old, 0.0).len(), 1);
        let mut added = vec![old[0].clone()];
        added.push(BaselineCell { id: "new".into(), ..old[0].clone() });
        assert!(regressions(&added, &old, 0.0).is_empty());
    }

    #[test]
    fn journal_round_trip_is_bit_exact() {
        let mut c = cell("a+b", 3, 0.5, f64::NAN, 123, 9.25);
        c.records.push(RoundRecord {
            round: 0,
            cluster: usize::MAX,
            train_loss: 0.5,
            test_accuracy: f64::NAN,
            test_loss: f64::NAN,
            comm_byte_hops: 7,
            train_s: 0.001,
            aggregate_s: 0.002,
            net_s: 1.5,
            clock_s: 9.25,
            stragglers: vec![1, 2],
            deferred: vec![],
        });
        let text = c.to_journal_json().dump();
        let back =
            CellResult::from_journal_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, c.id);
        assert_eq!(back.index, c.index);
        assert_eq!(back.wire_bytes, c.wire_bytes);
        assert_eq!(back.final_loss.to_bits(), c.final_loss.to_bits());
        assert_eq!(back.final_accuracy.to_bits(), c.final_accuracy.to_bits());
        assert_eq!(back.clock_s.to_bits(), c.clock_s.to_bits());
        assert_eq!(back.records.len(), 1);
        assert_eq!(back.records[0].cluster, usize::MAX);
        assert_eq!(
            back.records[0].test_loss.to_bits(),
            c.records[0].test_loss.to_bits()
        );
        // report entries for the original and the round-tripped result
        // render the same bytes (the resume byte-identity contract)
        assert_eq!(back.report_json().pretty(), c.report_json().pretty());
    }
}
