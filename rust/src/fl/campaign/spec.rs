//! Declarative campaign specs: a `base` [`ExperimentConfig`] plus named
//! sweep axes, expanded into a deterministic cell grid.
//!
//! A spec is JSON (see `examples/campaign_small.json`):
//!
//! ```json
//! {
//!   "version": 1,
//!   "name": "sweep",
//!   "seed": 7,
//!   "base": { "engine": "native", "rounds": 4, ... },
//!   "axes": [
//!     { "axis": "algorithm", "cells": [
//!       { "cell": "seq",  "delta": { "algorithm": "edgeflow_seq" } },
//!       { "cell": "hier", "delta": { "algorithm": "hierfl" } } ] },
//!     { "axis": "codec", "cells": [
//!       { "cell": "raw",   "delta": { "codec": "none" } },
//!       { "cell": "top10", "delta": { "codec": "top10" } } ] }
//!   ],
//!   "workers": 2, "cell_workers": 1, "tolerance": 0.0
//! }
//! ```
//!
//! The grid is the cartesian product of the axes in declaration order
//! (last axis fastest).  Each grid cell applies its axis deltas to the
//! base through [`crate::config::apply_json_delta`] — the file parser's
//! own vocabulary and validation — and gets a per-cell seed derived from
//! `(campaign seed, cell index)` by a splitmix64 finalizer, so cells are
//! decorrelated but fully reproducible from the spec alone.  Unknown
//! fields anywhere in the spec are typed errors, not silent no-ops.

use std::collections::BTreeSet;

use crate::config::{apply_json_delta, ExperimentConfig};
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Spec schema version, the `"version"` key of the file format.
pub const SPEC_VERSION: u64 = 1;

/// Top-level keys [`CampaignSpec::from_json`] accepts.
const SPEC_KEYS: [&str; 8] =
    ["version", "name", "seed", "base", "axes", "workers", "cell_workers", "tolerance"];

/// One named choice on an axis: a config delta over the campaign base.
#[derive(Debug, Clone)]
pub struct AxisCell {
    /// Choice label; cell grid ids join these across axes.
    pub name: String,
    /// JSON object of [`ExperimentConfig`] fields this choice overrides.
    pub delta: Json,
}

/// One sweep dimension: a named list of [`AxisCell`] choices.
#[derive(Debug, Clone)]
pub struct Axis {
    pub name: String,
    pub cells: Vec<AxisCell>,
}

/// A declarative experiment campaign (see the module docs for the file
/// format).  Every field round-trips through [`CampaignSpec::to_json`] /
/// [`CampaignSpec::from_json`] — the config-surface-parity lint contract
/// covers this struct like it covers `ExperimentConfig`.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Campaign label: prefixes cell run names and derives the default
    /// report/journal paths.
    pub name: String,
    /// Campaign master seed; per-cell seeds derive from it (see
    /// [`cell_seed`]).
    pub seed: u64,
    /// The config every cell starts from; axis deltas override it.
    pub base: ExperimentConfig,
    /// Sweep axes, outermost first (the last axis varies fastest).
    pub axes: Vec<Axis>,
    /// Core budget for the campaign (0 = one per core), split between
    /// the cell pool and per-cell round pools exactly like
    /// [`crate::fl::experiments::SuiteOptions::workers`].
    pub workers: usize,
    /// Worker threads inside each cell's round loop (the other half of
    /// the budget split; 0/1 = sequential rounds).
    pub cell_workers: usize,
    /// Relative regression tolerance for `--baseline` comparisons
    /// (0 = any worsening beyond bit-equality fails).
    pub tolerance: f64,
}

/// One expanded grid cell: the resolved config plus its identity.
#[derive(Debug, Clone)]
pub struct CampaignCell {
    /// Row-major position in the grid — the seed-derivation input, so
    /// ids can be renamed without reshuffling randomness.
    pub index: usize,
    /// Axis choice names joined with `+` (unique across the grid).
    pub id: String,
    /// Derived per-cell seed (already applied to `cfg`).
    pub seed: u64,
    /// The fully-resolved cell config.
    pub cfg: ExperimentConfig,
    /// The merged delta this cell applied over the base (for display).
    pub delta: Json,
}

/// Derive a cell's seed from the campaign seed and its grid index: a
/// splitmix64 finalizer over the pair, with the index spread by the
/// golden-ratio increment so neighbouring cells land in unrelated
/// streams.  Masked to 48 bits so the value survives the config JSON
/// round-trip (numbers travel as f64) exactly.
pub fn cell_seed(campaign_seed: u64, cell_index: u64) -> u64 {
    let mut z = campaign_seed
        .wrapping_add(cell_index.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)) & 0xFFFF_FFFF_FFFF
}

/// FNV-1a 64-bit, the digest behind [`CampaignSpec::digest`].
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn require_str(v: &Json, key: &str, what: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .filter(|s| !s.is_empty())
        .ok_or_else(|| {
            Error::Config(format!("{what} needs a non-empty string {key:?} field"))
        })
}

fn reject_unknown_keys(v: &Json, known: &[&str], what: &str) -> Result<()> {
    if let Json::Obj(m) = v {
        for k in m.keys() {
            if !known.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "unknown field {k:?} in {what} (known: {})",
                    known.join(", ")
                )));
            }
        }
        Ok(())
    } else {
        Err(Error::Config(format!("{what} must be a JSON object, got {}", v.dump())))
    }
}

impl CampaignSpec {
    // ------------------------------------------------------------- JSON I/O

    pub fn to_json(&self) -> Json {
        let axes = self.axes.iter().map(|ax| {
            Json::obj(vec![
                ("axis", ax.name.as_str().into()),
                (
                    "cells",
                    Json::arr(ax.cells.iter().map(|c| {
                        Json::obj(vec![
                            ("cell", c.name.as_str().into()),
                            ("delta", c.delta.clone()),
                        ])
                    })),
                ),
            ])
        });
        Json::obj(vec![
            ("version", SPEC_VERSION.into()),
            ("name", self.name.as_str().into()),
            ("seed", self.seed.into()),
            ("base", self.base.to_json()),
            ("axes", Json::arr(axes)),
            ("workers", self.workers.into()),
            ("cell_workers", self.cell_workers.into()),
            ("tolerance", self.tolerance.into()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CampaignSpec> {
        reject_unknown_keys(v, &SPEC_KEYS, "campaign spec")?;
        if let Some(ver) = v.get("version") {
            match ver.as_u64() {
                Some(SPEC_VERSION) => {}
                _ => {
                    return Err(Error::Config(format!(
                        "campaign spec version {} unsupported (this build reads {})",
                        ver.dump(),
                        SPEC_VERSION
                    )))
                }
            }
        }
        let name = require_str(v, "name", "campaign spec")?;
        let seed = match v.get("seed") {
            None => 0,
            Some(s) => s.as_u64().ok_or_else(|| {
                Error::Config("campaign \"seed\" must be a non-negative integer".into())
            })?,
        };
        let base = match v.get("base") {
            None => ExperimentConfig::default(),
            Some(b) => ExperimentConfig::from_json(b)?,
        };
        let axes_json = v
            .get("axes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("campaign needs an \"axes\" array".into()))?;
        if axes_json.is_empty() {
            return Err(Error::Config(
                "campaign \"axes\" is empty — a campaign sweeps at least one axis"
                    .into(),
            ));
        }
        let mut axes = Vec::with_capacity(axes_json.len());
        for ax in axes_json {
            reject_unknown_keys(ax, &["axis", "cells"], "axis")?;
            let axis_name = require_str(ax, "axis", "axis")?;
            let cells_json = ax.get("cells").and_then(Json::as_arr).ok_or_else(|| {
                Error::Config(format!("axis {axis_name:?} needs a \"cells\" array"))
            })?;
            if cells_json.is_empty() {
                return Err(Error::Config(format!(
                    "axis {axis_name:?} has no cells — every axis sweeps at least \
                     one choice"
                )));
            }
            let mut cells = Vec::with_capacity(cells_json.len());
            let mut seen = BTreeSet::new();
            for c in cells_json {
                reject_unknown_keys(c, &["cell", "delta"], "axis cell")?;
                let cell_name = require_str(c, "cell", "axis cell")?;
                if !seen.insert(cell_name.clone()) {
                    return Err(Error::Config(format!(
                        "axis {axis_name:?} names cell {cell_name:?} twice"
                    )));
                }
                let delta = c.get("delta").cloned().unwrap_or_else(|| Json::obj(vec![]));
                // Validate the delta's vocabulary eagerly (against the
                // campaign base) so `campaign validate` catches typos even
                // in cells later merges would shadow.
                apply_json_delta(&base, &delta)?;
                cells.push(AxisCell { name: cell_name, delta });
            }
            axes.push(Axis { name: axis_name, cells });
        }
        {
            let mut seen = BTreeSet::new();
            for ax in &axes {
                if !seen.insert(ax.name.clone()) {
                    return Err(Error::Config(format!(
                        "campaign names axis {:?} twice",
                        ax.name
                    )));
                }
            }
        }
        let usize_field = |k: &str, dflt: usize| -> Result<usize> {
            match v.get(k) {
                None => Ok(dflt),
                Some(x) => x.as_usize().ok_or_else(|| {
                    Error::Config(format!("campaign {k:?} must be an integer"))
                }),
            }
        };
        let workers = usize_field("workers", 1)?;
        let cell_workers = usize_field("cell_workers", 1)?;
        let tolerance = match v.get("tolerance") {
            None => 0.0,
            Some(t) => t.as_f64().filter(|t| t.is_finite() && *t >= 0.0).ok_or_else(
                || {
                    Error::Config(
                        "campaign \"tolerance\" must be a finite number >= 0".into(),
                    )
                },
            )?,
        };
        Ok(CampaignSpec { name, seed, base, axes, workers, cell_workers, tolerance })
    }

    /// Load a spec from a JSON file.
    pub fn load(path: &str) -> Result<CampaignSpec> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read campaign spec {path:?}: {e}"))
        })?;
        Self::from_json(&Json::parse(&text)?)
    }

    // ------------------------------------------------------------ expansion

    /// Number of grid cells (product of axis sizes).
    pub fn grid_size(&self) -> usize {
        self.axes.iter().map(|a| a.cells.len()).product()
    }

    /// Expand the axes into the full cell grid, row-major with the last
    /// axis varying fastest.  Deltas apply in axis order; the derived
    /// per-cell seed overrides any `seed` a delta sets (the grid owns
    /// cell randomness — sweep `seed` by adding a campaign, not an axis).
    pub fn expand(&self) -> Result<Vec<CampaignCell>> {
        let total = self.grid_size();
        let mut cells = Vec::with_capacity(total);
        for index in 0..total {
            // Decompose the row-major index into per-axis choices.
            let mut rem = index;
            let mut picks = vec![0usize; self.axes.len()];
            for (ai, ax) in self.axes.iter().enumerate().rev() {
                picks[ai] = rem % ax.cells.len();
                rem /= ax.cells.len();
            }
            let mut cfg = self.base.clone();
            let mut merged = Json::obj(vec![]);
            let mut parts = Vec::with_capacity(self.axes.len());
            for (ax, &pick) in self.axes.iter().zip(&picks) {
                let choice = &ax.cells[pick];
                cfg = apply_json_delta(&cfg, &choice.delta)?;
                if let (Json::Obj(acc), Json::Obj(d)) = (&mut merged, &choice.delta) {
                    for (k, val) in d {
                        acc.insert(k.clone(), val.clone());
                    }
                }
                parts.push(choice.name.as_str());
            }
            let id = parts.join("+");
            let seed = cell_seed(self.seed, index as u64);
            cfg.seed = seed;
            cfg.name = format!("{}_{}", self.name, id);
            cells.push(CampaignCell { index, id, seed, cfg, delta: merged });
        }
        Ok(cells)
    }

    /// Semantic digest of the campaign: FNV-1a over the canonical dump of
    /// `(name, seed, base, axes)`.  Execution knobs (`workers`,
    /// `cell_workers`, the base's `workers`, `tolerance`) are excluded —
    /// they change how fast the grid runs, never what it computes, and
    /// journals/reports must stay interchangeable across budget splits.
    pub fn digest(&self) -> String {
        let mut base = match self.base.to_json() {
            Json::Obj(m) => m,
            _ => Default::default(),
        };
        base.remove("workers");
        let spec = self.to_json();
        let axes =
            spec.get("axes").cloned().unwrap_or_else(|| Json::arr(Vec::new()));
        let canonical = Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("seed", self.seed.into()),
            ("base", Json::Obj(base)),
            ("axes", axes),
        ]);
        format!("{:016x}", fnv1a64(canonical.dump().as_bytes()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn tiny_spec() -> Json {
        Json::parse(
            r#"{
              "version": 1,
              "name": "t",
              "seed": 9,
              "base": {"engine": "native", "optimizer": "momentum", "lr": 0.05,
                       "clients": 8, "clusters": 2, "rounds": 2,
                       "batch_size": 4, "samples_per_client": 8,
                       "test_samples": 16, "eval_every": 1},
              "axes": [
                {"axis": "algorithm", "cells": [
                  {"cell": "seq",  "delta": {"algorithm": "edgeflow_seq"}},
                  {"cell": "hier", "delta": {"algorithm": "hierfl"}}]},
                {"axis": "codec", "cells": [
                  {"cell": "raw",   "delta": {"codec": "none"}},
                  {"cell": "top10", "delta": {"codec": "top10"}}]}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn grid_expands_row_major_with_derived_seeds() {
        let spec = CampaignSpec::from_json(&tiny_spec()).unwrap();
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 4);
        let ids: Vec<&str> = cells.iter().map(|c| c.id.as_str()).collect();
        assert_eq!(ids, ["seq+raw", "seq+top10", "hier+raw", "hier+top10"]);
        assert_eq!(cells[2].cfg.algorithm, Algorithm::HierFl);
        // base fields survive under the deltas
        assert!(cells.iter().all(|c| c.cfg.clients == 8 && c.cfg.rounds == 2));
        // seeds are derived, distinct, and stable under re-expansion
        let seeds: BTreeSet<u64> = cells.iter().map(|c| c.seed).collect();
        assert_eq!(seeds.len(), 4);
        for c in &cells {
            assert_eq!(c.seed, cell_seed(9, c.index as u64));
            assert_eq!(c.cfg.seed, c.seed);
            assert!(c.seed < (1 << 53), "seed must survive a JSON f64");
        }
        let again = spec.expand().unwrap();
        assert!(cells
            .iter()
            .zip(&again)
            .all(|(a, b)| a.id == b.id && a.seed == b.seed));
    }

    #[test]
    fn spec_round_trips_and_digest_ignores_execution_knobs() {
        let spec = CampaignSpec::from_json(&tiny_spec()).unwrap();
        let back = CampaignSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.axes.len(), spec.axes.len());
        assert_eq!(back.digest(), spec.digest());
        // workers / cell_workers / tolerance do not perturb the digest...
        let mut exec = spec.clone();
        exec.workers = 7;
        exec.cell_workers = 3;
        exec.tolerance = 0.25;
        assert_eq!(exec.digest(), spec.digest());
        // ...but a semantic change does
        let mut other = spec.clone();
        other.seed = 10;
        assert_ne!(other.digest(), spec.digest());
    }

    #[test]
    fn unknown_fields_and_empty_axes_are_typed_errors() {
        let mut v = tiny_spec();
        if let Json::Obj(m) = &mut v {
            m.insert("tolerence".into(), 0.1.into());
        }
        let err = CampaignSpec::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("tolerence"), "{err}");

        let empty = Json::parse(r#"{"name": "t", "axes": []}"#).unwrap();
        assert!(CampaignSpec::from_json(&empty).is_err());

        let empty_axis =
            Json::parse(r#"{"name": "t", "axes": [{"axis": "a", "cells": []}]}"#)
                .unwrap();
        assert!(CampaignSpec::from_json(&empty_axis).is_err());

        // a delta typo is caught at parse time, not at run time
        let typo = Json::parse(
            r#"{"name": "t", "axes": [{"axis": "a", "cells": [
                 {"cell": "x", "delta": {"algorithrm": "hierfl"}}]}]}"#,
        )
        .unwrap();
        let err = CampaignSpec::from_json(&typo).unwrap_err();
        assert!(err.to_string().contains("algorithrm"), "{err}");

        // duplicate cell names would collide in the grid id space
        let dup = Json::parse(
            r#"{"name": "t", "axes": [{"axis": "a", "cells": [
                 {"cell": "x", "delta": {}}, {"cell": "x", "delta": {}}]}]}"#,
        )
        .unwrap();
        assert!(CampaignSpec::from_json(&dup).is_err());
    }
}
