//! Campaign execution: fan the expanded cell grid over a [`WorkerPool`]
//! (the `fl::experiments` cell-pool pattern) with an append-only journal
//! so an interrupted campaign resumes where it stopped.
//!
//! The journal is JSONL: a header line binding the file to the spec's
//! semantic digest, then one checkpoint-grade [`CellResult`] record per
//! completed cell, appended (and flushed) the moment the cell finishes —
//! a kill loses at most the cells still in flight.  On the next run,
//! journaled cells are skipped and their results reused bit-exactly, so
//! the final report is byte-identical to an uninterrupted run's.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::sync::Mutex;

use crate::fl::experiments::{run_cell_traced, split_budget};
use crate::runtime::backend::backend_for;
use crate::runtime::pool::WorkerPool;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

use super::report::CellResult;
use super::spec::{CampaignCell, CampaignSpec};

/// Journal file schema version (header line `"version"`).
pub const JOURNAL_VERSION: u64 = 1;

/// Execution knobs for [`run_campaign`].
#[derive(Debug, Clone, Default)]
pub struct CampaignOptions {
    /// Artifact directory for the XLA engine (native cells ignore it).
    pub artifacts: String,
    /// Journal path; `None` runs without resumability.
    pub journal: Option<String>,
    /// Stop after this many *fresh* cells this invocation (0 = run all).
    /// The journal keeps the partial progress — the interruption story
    /// without needing an actual kill, used by tests and CI.
    pub max_cells: usize,
    /// Per-cell trace output directory ("" = tracing off): every fresh
    /// cell writes `<trace_dir>/<cell-name>.trace.jsonl` — one file per
    /// cell, so concurrently-running cells never interleave streams.
    /// Journal-skipped cells are not re-traced.
    pub trace_dir: String,
    /// Verbosity for cell traces (round | phase | full).
    pub trace_level: String,
}

/// What a [`run_campaign`] invocation accomplished.
#[derive(Debug)]
pub struct CampaignOutcome {
    /// Per-cell results in grid order; `None` where `max_cells` stopped
    /// short.
    pub results: Vec<Option<CellResult>>,
    /// Cells reused from the journal.
    pub skipped: usize,
    /// Cells trained by this invocation.
    pub executed: usize,
}

impl CampaignOutcome {
    pub fn is_complete(&self) -> bool {
        self.results.iter().all(Option::is_some)
    }

    /// All results in grid order, or `None` while the campaign is
    /// partial.
    pub fn complete_results(&self) -> Option<Vec<CellResult>> {
        if !self.is_complete() {
            return None;
        }
        Some(self.results.iter().flatten().cloned().collect())
    }
}

fn journal_header(spec: &CampaignSpec) -> Json {
    Json::obj(vec![
        ("version", JOURNAL_VERSION.into()),
        ("campaign", spec.name.as_str().into()),
        ("spec_digest", spec.digest().as_str().into()),
    ])
}

/// Load completed cells from a journal, validating the header against
/// the spec.  A truncated *final* line (the record a kill interrupted
/// mid-write) is dropped; corruption anywhere else is a typed error.
/// The `bool` is true when a torn tail was dropped — the caller must
/// then rewrite the file before appending, or the next record would
/// merge onto the partial line.
fn load_journal(
    path: &str,
    spec: &CampaignSpec,
    cells: &[CampaignCell],
) -> Result<(BTreeMap<usize, CellResult>, bool)> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((BTreeMap::new(), false))
        }
        Err(e) => return Err(e.into()),
    };
    let mut lines = text.lines().enumerate();
    let Some((_, header)) = lines.next() else {
        return Ok((BTreeMap::new(), false)); // empty file: nothing journaled yet
    };
    let h = Json::parse(header)
        .map_err(|e| Error::Config(format!("journal {path:?} header: {e}")))?;
    match h.get("version").and_then(Json::as_u64) {
        Some(JOURNAL_VERSION) => {}
        other => {
            return Err(Error::Config(format!(
                "journal {path:?} version {other:?} unsupported (this build \
                 writes {JOURNAL_VERSION})"
            )))
        }
    }
    let digest = spec.digest();
    let found = h.get("spec_digest").and_then(Json::as_str).unwrap_or("");
    if found != digest {
        return Err(Error::Config(format!(
            "journal {path:?} belongs to a different campaign (spec digest \
             {found} != {digest}) — delete it or restore the original spec"
        )));
    }
    let total_lines = text.lines().count();
    let mut done = BTreeMap::new();
    let mut torn = false;
    for (lineno, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(line).and_then(|j| CellResult::from_journal_json(&j));
        let rec = match parsed {
            Ok(r) => r,
            // The record a kill cut short: only tolerable on the last line.
            Err(e) if lineno + 1 == total_lines => {
                log::warn!(
                    "journal {path}: dropping truncated final record ({e})"
                );
                torn = true;
                continue;
            }
            Err(e) => {
                return Err(Error::Config(format!(
                    "journal {path:?} line {}: {e}",
                    lineno + 1
                )))
            }
        };
        match cells.get(rec.index) {
            Some(cell) if cell.id == rec.id => {}
            _ => {
                return Err(Error::Config(format!(
                    "journal {path:?} line {}: cell {} {:?} does not match the \
                     spec's grid",
                    lineno + 1,
                    rec.index,
                    rec.id
                )))
            }
        }
        done.insert(rec.index, rec);
    }
    Ok((done, torn))
}

/// Rewrite a journal to header + the given records (atomic tmp+rename).
/// Used after a torn tail was dropped: appending to a file whose last
/// line is partial would merge the next record onto the junk.
fn rewrite_journal(
    path: &str,
    spec: &CampaignSpec,
    done: &BTreeMap<usize, CellResult>,
) -> Result<()> {
    let mut out = format!("{}\n", journal_header(spec).dump());
    for rec in done.values() {
        out.push_str(&rec.to_journal_json().dump());
        out.push('\n');
    }
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, out)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Run a campaign's pending cells on the cell pool, journaling each
/// completion.  Already-journaled cells are skipped; their results are
/// returned alongside the fresh ones in grid order.
pub fn run_campaign(
    spec: &CampaignSpec,
    cells: &[CampaignCell],
    opts: &CampaignOptions,
) -> Result<CampaignOutcome> {
    let (pool_workers, cell_workers) = split_budget(spec.workers, spec.cell_workers);
    let done = match &opts.journal {
        Some(path) => {
            let (done, torn) = load_journal(path, spec, cells)?;
            if torn {
                rewrite_journal(path, spec, &done)?;
            }
            done
        }
        None => BTreeMap::new(),
    };
    let mut pending: Vec<&CampaignCell> =
        cells.iter().filter(|c| !done.contains_key(&c.index)).collect();
    if opts.max_cells > 0 && pending.len() > opts.max_cells {
        pending.truncate(opts.max_cells);
    }
    let journal = match &opts.journal {
        None => None,
        Some(path) => {
            let file = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)?;
            if file.metadata()?.len() == 0 {
                let mut f = file;
                writeln!(f, "{}", journal_header(spec).dump())?;
                f.flush()?;
                Some(Mutex::new(f))
            } else {
                Some(Mutex::new(file))
            }
        }
    };
    let pool = WorkerPool::new(pool_workers);
    log::info!(
        "campaign {}: {} cells ({} journaled, {} to run) on {} x {} workers",
        spec.name,
        cells.len(),
        done.len(),
        pending.len(),
        pool.workers(),
        cell_workers,
    );
    let artifacts = opts.artifacts.as_str();
    let fresh = pool.try_run(pending.len(), |i, _w| {
        let cell = pending[i];
        let mut cfg = cell.cfg.clone();
        cfg.workers = cell_workers;
        // Per-cell backends let an `engine` axis mix native and XLA cells
        // in one grid (the native backend is free to build; XLA reuses
        // its artifact cache per cell).
        let backend = backend_for(&cfg, artifacts)?;
        log::info!("campaign cell {}: {}", cell.index, cell.id);
        let report =
            run_cell_traced(&backend, cfg, &opts.trace_dir, &opts.trace_level)?;
        let result = CellResult::from_report(cell, &report);
        if let Some(j) = &journal {
            let line = result.to_journal_json().dump();
            let mut f = j
                .lock()
                .map_err(|_| Error::Config("campaign journal lock poisoned".into()))?;
            writeln!(f, "{line}")?;
            f.flush()?;
        }
        Ok(result)
    })?;
    let mut results: Vec<Option<CellResult>> = vec![None; cells.len()];
    let executed = fresh.len();
    let skipped = done.len();
    for (index, r) in done {
        results[index] = Some(r);
    }
    for r in fresh {
        results[r.index] = Some(r);
    }
    Ok(CampaignOutcome { results, skipped, executed })
}
