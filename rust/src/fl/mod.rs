//! The federated-learning coordinator — the paper's Layer-3 contribution.
//!
//! * [`aggregate`] — the weighted-averaging hot path (Eq. 3).
//! * [`scheduler`] — cluster schedules: the EdgeFLow migration orders
//!   (random / fixed-sequence) and FedAvg client sampling.
//! * [`comm`] — per-round communication patterns of every algorithm over
//!   a topology (drives Fig 4 and the in-training accounting).
//! * [`strategy`] — round planning for FedAvg / Hierarchical FL /
//!   Sequential FL / EdgeFLowRand / EdgeFLowSeq.
//! * [`runner`] — the experiment driver as a **stepwise round session**:
//!   [`Runner::step`] executes one round and returns a typed
//!   [`session::RoundOutcome`]; `run()` is a thin loop over it.
//!   [`runner::RunnerCheckpoint`] serializes the whole session for
//!   bit-identical resume.
//! * [`session`] — the session vocabulary: [`session::RoundObserver`]
//!   hooks with the [`session::RoundControl`] back-channel (early stop,
//!   adaptive deadlines), built-in progress/metrics observers, and the
//!   straggler re-inclusion pool behind `straggler_policy = defer`.
//! * [`theory`] — Theorem 1's convergence bound (Eq. 8), term by term.
//! * [`campaign`] — declarative multi-axis experiment campaigns over the
//!   [`experiments`] cell pool: resumable journaled runs, comparison
//!   reports with baseline regression checks, `BENCH_campaign.json`
//!   trajectories.

pub mod aggregate;
pub mod campaign;
pub mod comm;
pub mod compress;
pub mod experiments;
pub mod runner;
pub mod scheduler;
pub mod session;
pub mod strategy;
pub mod theory;

pub use runner::{RunReport, Runner, RunnerCheckpoint};
pub use scheduler::ClusterSchedule;
pub use session::{
    AdaptiveDeadlineObserver, LostCause, RoundControl, RoundObserver, RoundOutcome,
};
pub use strategy::{RoundPlan, Strategy};
