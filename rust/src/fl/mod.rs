//! The federated-learning coordinator — the paper's Layer-3 contribution.
//!
//! * [`aggregate`] — the weighted-averaging hot path (Eq. 3).
//! * [`scheduler`] — cluster schedules: the EdgeFLow migration orders
//!   (random / fixed-sequence) and FedAvg client sampling.
//! * [`comm`] — per-round communication patterns of every algorithm over
//!   a topology (drives Fig 4 and the in-training accounting).
//! * [`strategy`] — round planning for FedAvg / Hierarchical FL /
//!   Sequential FL / EdgeFLowRand / EdgeFLowSeq.
//! * [`runner`] — the experiment driver: train loop, aggregation,
//!   evaluation, metrics.
//! * [`theory`] — Theorem 1's convergence bound (Eq. 8), term by term.

pub mod aggregate;
pub mod comm;
pub mod compress;
pub mod experiments;
pub mod runner;
pub mod scheduler;
pub mod strategy;
pub mod theory;

pub use runner::{Runner, RunReport};
pub use scheduler::ClusterSchedule;
pub use strategy::{RoundPlan, Strategy};
