//! Experiment metrics: per-round records, curves, smoothing, exporters.

use crate::util::csv::CsvWriter;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::{f64_from_hex, f64_to_hex, u64_from_hex, u64_to_hex};

/// One communication round's record.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Active cluster (participating set) this round.
    pub cluster: usize,
    /// Training loss over the round's reduction operands, weighted by
    /// the same Eq. 3 sample counts the aggregation uses (folded
    /// deferred updates included).  NaN for a lost round.
    pub train_loss: f64,
    /// Test accuracy in [0,1]; NaN when not evaluated this round.
    pub test_accuracy: f64,
    /// Test loss; NaN when not evaluated.
    pub test_loss: f64,
    /// Byte-hops of communication attributed to this round.
    pub comm_byte_hops: u64,
    /// Wall-clock seconds spent in local training (XLA execution).
    pub train_s: f64,
    /// Wall-clock seconds spent aggregating.
    pub aggregate_s: f64,
    /// Simulated network seconds for this round's transfers.
    pub net_s: f64,
    /// Cumulative simulated wall-clock at the end of this round (the
    /// persistent DES clock) — lets Fig-3/Fig-4 curves plot against
    /// simulated time instead of round index.
    pub clock_s: f64,
    /// Clients whose simulated upload missed `deadline_s` this round;
    /// their traffic is charged but they are excluded from the Eq. 3
    /// reduction.  Empty when no deadline is set.
    pub stragglers: Vec<usize>,
    /// Clients whose *earlier-round* late updates were folded into this
    /// round's Eq. 3 reduction (straggler re-inclusion,
    /// `straggler_policy = defer`).  Empty under the drop policy.
    pub deferred: Vec<usize>,
}

impl RoundRecord {
    /// Checkpoint-grade JSON: every float travels as its bit pattern so a
    /// restored record is bit-identical (NaN losses of lost rounds
    /// included — plain JSON numbers cannot carry them at all).
    pub fn to_ckpt_json(&self) -> Json {
        Json::obj(vec![
            ("round", self.round.into()),
            // cluster may be the usize::MAX "no cluster" sentinel, which
            // does not survive a f64 JSON number exactly.
            ("cluster", u64_to_hex(self.cluster as u64).into()),
            ("train_loss", f64_to_hex(self.train_loss).into()),
            ("test_accuracy", f64_to_hex(self.test_accuracy).into()),
            ("test_loss", f64_to_hex(self.test_loss).into()),
            ("comm_byte_hops", u64_to_hex(self.comm_byte_hops).into()),
            ("train_s", f64_to_hex(self.train_s).into()),
            ("aggregate_s", f64_to_hex(self.aggregate_s).into()),
            ("net_s", f64_to_hex(self.net_s).into()),
            ("clock_s", f64_to_hex(self.clock_s).into()),
            ("stragglers", Json::arr(self.stragglers.iter().map(|&s| Json::from(s)))),
            ("deferred", Json::arr(self.deferred.iter().map(|&s| Json::from(s)))),
        ])
    }

    /// Inverse of [`RoundRecord::to_ckpt_json`].
    pub fn from_ckpt_json(j: &Json) -> Result<RoundRecord> {
        let hex_f64 = |k: &str| -> Result<f64> { f64_from_hex(j.str_field(k)?) };
        let ids = |k: &str| -> Result<Vec<usize>> {
            j.req(k)?
                .as_arr()
                .ok_or_else(|| Error::Json(format!("field {k:?} must be an array")))?
                .iter()
                .map(|v| {
                    v.as_usize().ok_or_else(|| {
                        Error::Json(format!("field {k:?} holds a non-integer"))
                    })
                })
                .collect()
        };
        Ok(RoundRecord {
            round: j.usize_field("round")?,
            cluster: u64_from_hex(j.str_field("cluster")?)? as usize,
            train_loss: hex_f64("train_loss")?,
            test_accuracy: hex_f64("test_accuracy")?,
            test_loss: hex_f64("test_loss")?,
            comm_byte_hops: u64_from_hex(j.str_field("comm_byte_hops")?)?,
            train_s: hex_f64("train_s")?,
            aggregate_s: hex_f64("aggregate_s")?,
            net_s: hex_f64("net_s")?,
            clock_s: hex_f64("clock_s")?,
            stragglers: ids("stragglers")?,
            deferred: ids("deferred")?,
        })
    }
}

/// Column order of the standard metrics CSV, one column per
/// [`RoundRecord::csv_fields`] entry.  The appending live exporter
/// ([`crate::fl::session::MetricsCsvObserver`]) rides `csv_fields`
/// too, so its file is byte-identical to the batch export
/// ([`ExperimentMetrics::to_csv`]) over the same records.
pub const METRICS_CSV_HEADER: [&str; 12] = [
    "round",
    "cluster",
    "train_loss",
    "test_accuracy",
    "test_loss",
    "comm_byte_hops",
    "train_s",
    "aggregate_s",
    "net_s",
    "clock_s",
    "stragglers",
    "deferred",
];

impl RoundRecord {
    /// This record's row of the standard metrics CSV, in
    /// [`METRICS_CSV_HEADER`] order.
    pub fn csv_fields(&self) -> Vec<String> {
        vec![
            self.round.to_string(),
            self.cluster.to_string(),
            format!("{}", self.train_loss),
            format!("{}", self.test_accuracy),
            format!("{}", self.test_loss),
            self.comm_byte_hops.to_string(),
            format!("{}", self.train_s),
            format!("{}", self.aggregate_s),
            format!("{}", self.net_s),
            format!("{}", self.clock_s),
            // semicolon-joined ids: stays a single CSV field
            self.stragglers
                .iter()
                .map(ToString::to_string)
                .collect::<Vec<_>>()
                .join(";"),
            self.deferred
                .iter()
                .map(ToString::to_string)
                .collect::<Vec<_>>()
                .join(";"),
        ]
    }
}

/// Full experiment result.
#[derive(Debug, Clone, Default)]
pub struct ExperimentMetrics {
    pub rounds: Vec<RoundRecord>,
}

impl ExperimentMetrics {
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// Final evaluated accuracy (last non-NaN), or NaN.
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .map(|r| r.test_accuracy)
            .find(|a| !a.is_nan())
            .unwrap_or(f64::NAN)
    }

    /// Final per-round training loss, skipping back over rounds that
    /// trained nothing (lost to dropout or stragglers, NaN loss) — the
    /// same spirit as [`ExperimentMetrics::final_accuracy`].  NaN only
    /// when no round ever trained.
    pub fn final_train_loss(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .map(|r| r.train_loss)
            .find(|l| l.is_finite())
            .unwrap_or(f64::NAN)
    }

    /// Best evaluated accuracy, or NaN.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_accuracy)
            .filter(|a| !a.is_nan())
            .fold(f64::NAN, |acc, a| if acc.is_nan() || a > acc { a } else { acc })
    }

    /// Total communication byte-hops.
    pub fn total_byte_hops(&self) -> u64 {
        self.rounds.iter().map(|r| r.comm_byte_hops).sum()
    }

    /// Total simulated network seconds across rounds (sum of per-round
    /// transfer makespans).
    pub fn total_net_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.net_s).sum()
    }

    /// (round, accuracy) curve of evaluated rounds.
    pub fn accuracy_curve(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter(|r| !r.test_accuracy.is_nan())
            .map(|r| (r.round, r.test_accuracy))
            .collect()
    }

    /// (round, loss) curve.
    pub fn loss_curve(&self) -> Vec<(usize, f64)> {
        self.rounds.iter().map(|r| (r.round, r.train_loss)).collect()
    }

    /// CSV export with one row per round ([`METRICS_CSV_HEADER`] order).
    pub fn to_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(&METRICS_CSV_HEADER);
        for r in &self.rounds {
            w.row(&r.csv_fields());
        }
        w
    }

    /// JSON export (summary + curves).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("final_accuracy", self.final_accuracy().into()),
            ("best_accuracy", self.best_accuracy().into()),
            ("total_byte_hops", self.total_byte_hops().into()),
            (
                "rounds",
                Json::arr(self.rounds.iter().map(|r| {
                    Json::obj(vec![
                        ("round", r.round.into()),
                        ("cluster", r.cluster.into()),
                        ("train_loss", r.train_loss.into()),
                        ("test_accuracy", r.test_accuracy.into()),
                        ("test_loss", r.test_loss.into()),
                        ("comm_byte_hops", r.comm_byte_hops.into()),
                        ("train_s", r.train_s.into()),
                        ("aggregate_s", r.aggregate_s.into()),
                        ("net_s", r.net_s.into()),
                        ("clock_s", r.clock_s.into()),
                        (
                            "stragglers",
                            Json::arr(
                                r.stragglers.iter().map(|&s| Json::from(s)),
                            ),
                        ),
                        (
                            "deferred",
                            Json::arr(
                                r.deferred.iter().map(|&s| Json::from(s)),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Sliding-window smoothing (the paper smooths Fig 3 curves this way).
/// Window is centered, clamped at the edges.
pub fn smooth(values: &[f64], window: usize) -> Vec<f64> {
    if window <= 1 || values.is_empty() {
        return values.to_vec();
    }
    let half = window / 2;
    (0..values.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(values.len());
            values[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            cluster: 0,
            train_loss: 1.0,
            test_accuracy: acc,
            test_loss: 1.0,
            comm_byte_hops: 100,
            train_s: 0.0,
            aggregate_s: 0.0,
            net_s: 0.0,
            clock_s: 0.0,
            stragglers: Vec::new(),
            deferred: Vec::new(),
        }
    }

    #[test]
    fn final_and_best_skip_nan() {
        let mut m = ExperimentMetrics::default();
        m.push(rec(0, 0.5));
        m.push(rec(1, f64::NAN));
        m.push(rec(2, 0.8));
        m.push(rec(3, f64::NAN));
        assert_eq!(m.final_accuracy(), 0.8);
        assert_eq!(m.best_accuracy(), 0.8);
        assert_eq!(m.total_byte_hops(), 400);
        assert_eq!(m.accuracy_curve(), vec![(0, 0.5), (2, 0.8)]);
    }

    #[test]
    fn empty_metrics_are_nan() {
        let m = ExperimentMetrics::default();
        assert!(m.final_accuracy().is_nan());
        assert!(m.best_accuracy().is_nan());
    }

    #[test]
    fn smoothing_averages_neighbors() {
        let s = smooth(&[0.0, 1.0, 2.0, 3.0, 4.0], 3);
        assert_eq!(s[0], 0.5); // clamped window [0,1]
        assert_eq!(s[2], 2.0);
        assert_eq!(s[4], 3.5);
        assert_eq!(smooth(&[1.0, 2.0], 1), vec![1.0, 2.0]);
    }

    #[test]
    fn csv_has_row_per_round() {
        let mut m = ExperimentMetrics::default();
        m.push(rec(0, 0.1));
        m.push(rec(1, 0.2));
        let text = String::from_utf8(m.to_csv().as_bytes().to_vec()).unwrap();
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn json_export_parses_back() {
        let mut m = ExperimentMetrics::default();
        let mut r = rec(0, 0.5);
        r.net_s = 1.25;
        r.clock_s = 3.5;
        r.stragglers = vec![4, 9];
        r.deferred = vec![1];
        m.push(r);
        let j = Json::parse(&m.to_json().dump()).unwrap();
        assert_eq!(j.f64_field("final_accuracy").unwrap(), 0.5);
        let r0 = &j.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.f64_field("net_s").unwrap(), 1.25);
        assert_eq!(r0.f64_field("clock_s").unwrap(), 3.5);
        assert_eq!(r0.get("stragglers").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(r0.get("deferred").unwrap().as_arr().unwrap().len(), 1);
        assert!((m.total_net_s() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn csv_carries_clock_and_stragglers() {
        let mut m = ExperimentMetrics::default();
        let mut r = rec(0, 0.1);
        r.clock_s = 2.0;
        r.stragglers = vec![3, 7];
        r.deferred = vec![9];
        m.push(r);
        m.push(rec(1, 0.2));
        let text = String::from_utf8(m.to_csv().as_bytes().to_vec()).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert!(
            header.ends_with("net_s,clock_s,stragglers,deferred"),
            "{header}"
        );
        let row0 = lines.next().unwrap();
        assert!(row0.ends_with(",2,3;7,9"), "{row0}");
        let row1 = lines.next().unwrap();
        assert!(row1.ends_with(",0,,"), "{row1}");
    }

    #[test]
    fn final_train_loss_skips_lost_rounds() {
        let mut m = ExperimentMetrics::default();
        assert!(m.final_train_loss().is_nan(), "empty metrics");
        let mut r0 = rec(0, 0.5);
        r0.train_loss = 0.75;
        m.push(r0);
        // Final round lost to dropout/stragglers: NaN loss must not leak
        // into the headline number.
        let mut r1 = rec(1, f64::NAN);
        r1.train_loss = f64::NAN;
        m.push(r1);
        assert_eq!(m.final_train_loss(), 0.75);
    }

    #[test]
    fn ckpt_json_roundtrips_bit_exactly() {
        let mut r = rec(3, f64::NAN);
        r.cluster = usize::MAX; // the FedAvg "no cluster" sentinel
        r.train_loss = f64::NAN; // lost round
        r.net_s = 0.1 + 0.2; // a value with no short decimal form
        r.clock_s = 1e-300;
        r.comm_byte_hops = u64::MAX;
        r.stragglers = vec![4, 9];
        r.deferred = vec![2];
        let text = r.to_ckpt_json().dump();
        let back =
            RoundRecord::from_ckpt_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.round, r.round);
        assert_eq!(back.cluster, r.cluster);
        assert_eq!(back.train_loss.to_bits(), r.train_loss.to_bits());
        assert_eq!(back.test_accuracy.to_bits(), r.test_accuracy.to_bits());
        assert_eq!(back.net_s.to_bits(), r.net_s.to_bits());
        assert_eq!(back.clock_s.to_bits(), r.clock_s.to_bits());
        assert_eq!(back.comm_byte_hops, r.comm_byte_hops);
        assert_eq!(back.stragglers, r.stragglers);
        assert_eq!(back.deferred, r.deferred);
    }
}
