//! # EdgeFLow — serverless federated learning via sequential model migration
//!
//! Reproduction of *"EdgeFLow: Serverless Federated Learning via Sequential
//! Model Migration in Edge Networks"* (Shi, Hou, Fan, Letaief; 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: cluster
//!   management, the sequential model-migration scheduler
//!   ([`fl::edgeflow`]), FedAvg / Hierarchical-FL / Sequential-FL baselines,
//!   an edge-network topology model ([`topology`]) with a discrete-event
//!   communication simulator ([`netsim`]), the aggregation hot path
//!   ([`fl::aggregate`]), metrics, CLI.
//! * **Layer 2** — the paper's six-layer CNN (and MLP variants) written in
//!   JAX (`python/compile/model.py`), AOT-lowered to HLO text once at build
//!   time (`make artifacts`).
//! * **Layer 1** — Pallas kernels (tiled matmul, conv-as-im2col, fused
//!   BN+ReLU, fused softmax-xent) under `python/compile/kernels/`.
//!
//! Training engines are pluggable ([`runtime::backend`], config
//! `engine: xla|native`): at run time the Rust binary either loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client
//! ([`runtime::executor`]) — Python never runs here — or trains with the
//! pure-Rust in-process engine ([`runtime::native`]), which needs no
//! artifacts at all.
//!
//! ## Quick start (no artifacts needed)
//!
//! ```no_run
//! use edgeflow::config::{preset, Algorithm, EngineKind};
//! use edgeflow::fl::runner::Runner;
//!
//! let mut cfg = preset("table1_fashion_iid").unwrap();
//! cfg.rounds = 10;
//! cfg.algorithm = Algorithm::EdgeFlowSeq;
//! cfg.engine = EngineKind::Native; // pure-Rust trainer
//! cfg.optimizer = "momentum".into();
//! cfg.lr = 0.01;
//! let report = Runner::new(cfg, "artifacts").unwrap().run().unwrap();
//! println!("final accuracy: {:.2}%", report.final_accuracy * 100.0);
//! ```

pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod fl;
pub mod metrics;
pub mod netsim;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod testing;
pub mod topology;
pub mod util;

pub use util::error::{Error, Result};
