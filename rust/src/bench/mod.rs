//! Timing harness for the `harness = false` bench targets (criterion is
//! not vendored in this offline image).
//!
//! Provides warmup + repeated measurement with mean / stddev / percentiles,
//! and a stable one-line report format the bench mains print:
//!
//! ```text
//! bench aggregate/100x109k      iters=50  mean=1.23 ms  p50=1.20 ms  p99=1.61 ms
//! ```

use std::time::{Duration, Instant};

use crate::util::{human_duration, mean, percentile_sorted, stddev};

/// One benchmark measurement summary.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "bench {:<36} iters={:<4} mean={:<9} p50={:<9} p99={:<9} sd={}",
            self.name,
            self.iters,
            human_duration(Duration::from_secs_f64(self.mean_s)),
            human_duration(Duration::from_secs_f64(self.p50_s)),
            human_duration(Duration::from_secs_f64(self.p99_s)),
            human_duration(Duration::from_secs_f64(self.stddev_s)),
        )
    }

    /// Throughput helper: items per second at the mean.
    pub fn per_second(&self, items: usize) -> f64 {
        items as f64 / self.mean_s
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    /// Minimum iterations (after warmup).
    pub min_iters: usize,
    /// Maximum iterations.
    pub max_iters: usize,
    /// Soft wall-clock budget per benchmark.
    pub budget: Duration,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(3),
            warmup: 3,
        }
    }
}

impl Bencher {
    /// Quick-mode bencher honoring `EDGEFLOW_BENCH_FAST=1` (CI smoke).
    pub fn from_env() -> Bencher {
        if std::env::var("EDGEFLOW_BENCH_FAST").as_deref() == Ok("1") {
            Bencher {
                min_iters: 3,
                max_iters: 10,
                budget: Duration::from_millis(300),
                warmup: 1,
            }
        } else {
            Bencher::default()
        }
    }

    /// Measure `f`, printing and returning the summary.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let started = Instant::now();
        let mut samples = Vec::new();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && started.elapsed() < self.budget)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: mean(&samples),
            stddev_s: stddev(&samples),
            p50_s: percentile_sorted(&sorted, 50.0),
            p99_s: percentile_sorted(&sorted, 99.0),
            min_s: sorted[0],
            max_s: *sorted.last().unwrap(),
        };
        println!("{}", m.report());
        m
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Shared env-var knob parsing for the bench mains (`EDGEFLOW_WORKERS`,
/// `EDGEFLOW_*_ROUNDS`, ...): integer value of `name`, or `default`.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bencher {
            min_iters: 5,
            max_iters: 8,
            budget: Duration::from_millis(50),
            warmup: 1,
        };
        let mut n = 0u64;
        let m = b.bench("noop", || {
            n = black_box(n + 1);
        });
        assert!(m.iters >= 5 && m.iters <= 8);
        assert!(m.min_s <= m.p50_s && m.p50_s <= m.max_s);
    }

    #[test]
    fn per_second_scales() {
        let m = Measurement {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            stddev_s: 0.0,
            p50_s: 0.5,
            p99_s: 0.5,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert_eq!(m.per_second(100), 200.0);
    }
}
