//! `edgeflow` — the Layer-3 coordinator CLI.
//!
//! Subcommands map to the paper's experiments (see DESIGN.md §5):
//! `train` runs one experiment, `table1` / `fig3` / `comm-sim` regenerate
//! the paper's table and figures, `inspect` prints partitions/topologies/
//! manifest, `theory` evaluates Theorem 1.

use std::process::ExitCode;
use std::sync::Arc;

use edgeflow::cli::{
    apply_overrides, cell_workers_flag, flag, flag_def, switch, trace_flag,
    trace_level_flag, workers_flag, Args, Cli, CommandSpec,
};
use edgeflow::config::{
    preset, Algorithm, DatasetKind, Distribution, EngineKind, ExperimentConfig,
    TopologyKind, PRESETS,
};
use edgeflow::data::partition::build_federation;
use edgeflow::fl::campaign::{
    append_bench, parse_baseline, regressions, render_report, run_campaign,
    winners, BaselineCell, CampaignOptions, CampaignSpec, CellResult,
};
use edgeflow::fl::experiments::{
    fig3a, fig3b, fig4, split_budget, table1, SuiteOptions,
};
use edgeflow::fl::runner::{
    find_latest_checkpoint, prune_checkpoints, round_stamped_path, Runner,
    RunnerCheckpoint,
};
use edgeflow::fl::session::{AdaptiveDeadlineObserver, MetricsCsvObserver, PlateauStopObserver};
use edgeflow::fl::theory::{bound, k_scan, TheoryParams};
use edgeflow::metrics::smooth;
use edgeflow::runtime::backend::{backend_for, backend_for_kind, TrainBackend};
use edgeflow::runtime::manifest::Manifest;
use edgeflow::topology::builder::{build as build_topo, TopologyParams};
use edgeflow::topology::route::RouteTable;
use edgeflow::util::error::{Error, Result};
use edgeflow::util::json::Json;
use edgeflow::util::table::{Align, Table};

fn cli() -> Cli {
    let common_train = || {
        vec![
            flag_def("artifacts", "artifact directory", "artifacts"),
            flag("preset", "named preset (see `presets`)"),
            flag("config", "JSON config file"),
            flag(
                "engine",
                "xla|native: AOT XLA artifacts, or the pure-Rust in-process \
                 trainer (no artifacts; *_linear/*_mlp/*_cnn_slim_fast \
                 models, sgd|momentum|adam)",
            ),
            flag(
                "algorithm",
                "fedavg|hierfl|seqfl|edgeflow_rand|edgeflow_seq|edgeflow_hop|edgeflow_latency",
            ),
            flag("dropout", "per-round client dropout probability [0,1]"),
            flag(
                "deadline-s",
                "round deadline in simulated network seconds (0 = none); \
                 late uploads are excluded from aggregation",
            ),
            flag(
                "adaptive-deadline",
                "adaptive round deadlines: slack factor over an EWMA of \
                 per-round simulated network time (0 = off); overrides \
                 --deadline-s once warm.  Observer state is process-local: \
                 a resumed run re-warms the estimator instead of replaying \
                 it, so --resume is bit-identical only for runs without \
                 this flag",
            ),
            flag(
                "adaptive-warmup",
                "rounds observed before the adaptive deadline applies \
                 (default 3)",
            ),
            switch(
                "adaptive-per-cluster",
                "track one deadline EWMA per planned cluster instead of a \
                 single global estimate (pairs with --adaptive-deadline; \
                 clusters fall back to the global EWMA until their own \
                 estimate is warm)",
            ),
            flag(
                "plateau-rounds",
                "stop early after N consecutive evaluated rounds without \
                 test-loss improvement (0 = off); the checkpointed round \
                 cursor still resumes bit-identically",
            ),
            flag(
                "plateau-min-delta",
                "loss improvement below this counts as no improvement for \
                 --plateau-rounds (default 0)",
            ),
            flag(
                "straggler-policy",
                "drop|defer: discard a straggler's late update, or fold it \
                 into the next round's reduction (straggler re-inclusion)",
            ),
            flag(
                "codec",
                "transfer codec for wire-size accounting: none|int8|top<pct> \
                 (compressed byte-hops/transfer-times in every RoundRecord)",
            ),
            flag(
                "checkpoint-every",
                "write a session checkpoint every N rounds (0 = off)",
            ),
            flag(
                "checkpoint",
                "checkpoint file path (default: <name>.ckpt.json)",
            ),
            flag(
                "checkpoint-keep",
                "rotate round-stamped checkpoints, keeping the N newest \
                 (0 = single file overwritten in place)",
            ),
            flag(
                "resume",
                "resume from a checkpoint file (bit-identical continuation; \
                 other config flags are ignored)",
            ),
            flag(
                "resume-latest",
                "resume from the newest *.ckpt.json in a directory \
                 (pairs with --checkpoint-keep rotation)",
            ),
            flag("dataset", "synth_fashion|synth_cifar"),
            flag("dist", "iid|niid_a|niid_b|noniid<pct>"),
            flag("model", "artifact model variant"),
            flag("rounds", "communication rounds T"),
            flag("clients", "total client count N"),
            flag("clusters", "cluster count M"),
            flag("k", "local steps K"),
            flag("batch", "training minibatch size B"),
            flag("lr", "learning rate"),
            flag("optimizer", "sgd|momentum|adam (either engine)"),
            flag("seed", "master seed"),
            flag("samples", "samples per client"),
            flag("test-samples", "held-out test set size"),
            flag("eval-every", "evaluation period in rounds"),
            flag("topology", "simple|breadth_parallel|depth_linear|hybrid"),
            workers_flag(),
            trace_flag(),
            trace_level_flag(),
            flag("out", "write metrics CSV here"),
            flag("out-json", "write metrics JSON here"),
            flag(
                "live-csv",
                "rewrite a metrics CSV here after every round (live export \
                 that survives a crash)",
            ),
            switch("verbose", "debug logging"),
        ]
    };
    Cli {
        bin: "edgeflow",
        about: "EdgeFLow: serverless federated learning via sequential model \
                migration (paper reproduction)",
        commands: vec![
            CommandSpec {
                name: "train",
                about: "run one federated-learning experiment",
                flags: common_train(),
                positional: vec![],
            },
            CommandSpec {
                name: "table1",
                about: "regenerate Table I (accuracy across methods/configs)",
                flags: vec![
                    flag_def("artifacts", "artifact directory", "artifacts"),
                    flag_def("engine", "xla|native training engine", "xla"),
                    flag("optimizer", "optimizer override (sgd|momentum|adam)"),
                    flag("batch", "minibatch size override"),
                    flag("lr", "learning-rate override"),
                    flag_def("rounds", "rounds per cell", "60"),
                    flag_def("samples", "samples per client", "120"),
                    flag("seed", "master seed"),
                    workers_flag(),
                    cell_workers_flag(),
                    flag(
                        "trace-dir",
                        "write one dual-clock trace JSONL per cell into this \
                         directory",
                    ),
                    trace_level_flag(),
                    switch("fast", "fashion cells only"),
                    flag("out", "write cell results CSV here"),
                    switch("verbose", "debug logging"),
                ],
                positional: vec![],
            },
            CommandSpec {
                name: "fig3",
                about: "regenerate Fig 3 (cluster-size and local-epoch sweeps)",
                flags: vec![
                    flag_def("artifacts", "artifact directory", "artifacts"),
                    flag_def("engine", "xla|native training engine", "xla"),
                    flag("optimizer", "optimizer override (sgd|momentum|adam)"),
                    flag("batch", "minibatch size override"),
                    flag("lr", "learning-rate override"),
                    flag_def("rounds", "rounds per run", "60"),
                    flag_def("samples", "samples per client", "120"),
                    flag_def("part", "a|b|both", "both"),
                    flag_def("nms", "cluster sizes for part a", "5,10,20,50"),
                    flag_def("ks", "local steps for part b", "1,2,5,10"),
                    flag_def("window", "smoothing window", "5"),
                    flag("seed", "master seed"),
                    workers_flag(),
                    cell_workers_flag(),
                    flag(
                        "trace-dir",
                        "write one dual-clock trace JSONL per run into this \
                         directory",
                    ),
                    trace_level_flag(),
                    flag("out", "write curves CSV here"),
                    switch("verbose", "debug logging"),
                ],
                positional: vec![],
            },
            CommandSpec {
                name: "comm-sim",
                about: "regenerate Fig 4 (communication load across topologies)",
                flags: vec![
                    flag_def("artifacts", "artifact directory (for param counts)", "artifacts"),
                    flag_def(
                        "model",
                        "model variant for the parameter count (the Fig-4 \
                         study sizes transfers on params only; `train` \
                         charges the full optimizer-bearing state)",
                        "fashion_mlp",
                    ),
                    flag(
                        "param-count",
                        "parameter count override (skips the artifact manifest \
                         — lets the pure-coordination study run without \
                         artifacts, e.g. in CI)",
                    ),
                    flag_def("rounds", "rounds to average over", "100"),
                    flag_def("clusters", "cluster count M", "10"),
                    flag_def("cluster-size", "clients per cluster N_m", "10"),
                    flag("seed", "master seed"),
                    workers_flag(),
                    switch("latency", "print DES latency column"),
                    flag_def("codec", "transfer codec: none|int8|top<pct>", "none"),
                    flag("out", "write results CSV here"),
                    flag("out-json", "write results JSON here"),
                    switch("verbose", "debug logging"),
                ],
                positional: vec![],
            },
            CommandSpec {
                name: "theory",
                about: "evaluate Theorem 1's bound (Eq. 8) and its K-scan",
                flags: vec![
                    flag_def("l", "smoothness constant L", "1.0"),
                    flag_def("g2", "gradient bound G^2", "1.0"),
                    flag_def("sigma2", "gradient variance sigma^2", "1.0"),
                    flag_def("gap", "F(theta0) - F*", "1.0"),
                    flag_def("eta", "learning rate", "0.01"),
                    flag_def("k", "local steps K", "5"),
                    flag_def("t", "rounds T", "100"),
                    flag_def("lambda2", "heterogeneity bound", "0.1"),
                    flag_def("nm", "cluster size N_m", "10"),
                    flag_def("kmax", "K-scan upper bound", "20"),
                ],
                positional: vec![],
            },
            CommandSpec {
                name: "inspect",
                about: "print partitions (Fig 2), topology routes, or the manifest",
                flags: vec![
                    flag_def("artifacts", "artifact directory", "artifacts"),
                    switch("partitions", "per-client class histograms"),
                    switch("topology", "nodes, links and BS->cloud hops"),
                    switch("manifest", "artifact manifest summary"),
                    flag_def("dist", "distribution for --partitions", "niid_a"),
                    flag_def("clients", "client count", "100"),
                    flag_def("clusters", "cluster count", "10"),
                    flag("seed", "master seed"),
                ],
                positional: vec![],
            },
            CommandSpec {
                name: "campaign",
                about: "declarative multi-axis experiment campaigns \
                        (validate the grid, run it resumably, compare reports)",
                flags: vec![
                    flag_def("artifacts", "artifact directory (XLA cells)", "artifacts"),
                    flag("out", "report path (default <campaign>_report.json)"),
                    flag(
                        "journal",
                        "resume journal path (default <campaign>.journal.jsonl); \
                         completed cells are skipped on re-run",
                    ),
                    switch("no-journal", "run without the resume journal"),
                    flag(
                        "baseline",
                        "older report to compare against; regressions beyond \
                         the tolerance fail the command",
                    ),
                    flag(
                        "tolerance",
                        "relative regression tolerance for --baseline \
                         (overrides the spec's; 0 = only bit-identical or \
                         better passes)",
                    ),
                    flag_def(
                        "bench",
                        "trajectory file to append headline results to",
                        "BENCH_campaign.json",
                    ),
                    switch("no-bench", "skip the trajectory append"),
                    flag(
                        "max-cells",
                        "stop after N fresh cells this invocation (0 = all); \
                         the journal keeps the partial progress",
                    ),
                    workers_flag(),
                    cell_workers_flag(),
                    flag(
                        "trace-dir",
                        "write one dual-clock trace JSONL per fresh cell into \
                         this directory (journal-skipped cells are not \
                         re-traced)",
                    ),
                    trace_level_flag(),
                    switch("verbose", "debug logging"),
                ],
                positional: vec![
                    ("action", "run | validate | report"),
                    (
                        "file",
                        "campaign spec JSON (run|validate) or an existing \
                         report JSON (report)",
                    ),
                ],
            },
            CommandSpec {
                name: "trace",
                about: "summarize a dual-clock trace or export it for \
                        Perfetto/chrome://tracing (see `train --trace`)",
                flags: vec![
                    flag(
                        "chrome",
                        "write a Chrome trace-event JSON here (export action)",
                    ),
                    switch("verbose", "debug logging"),
                ],
                positional: vec![
                    ("action", "summarize | export"),
                    ("file", "trace JSONL file (written by --trace/--trace-dir)"),
                ],
            },
            CommandSpec {
                name: "presets",
                about: "list named experiment presets",
                flags: vec![],
                positional: vec![],
            },
        ],
    }
}

fn suite_options(a: &Args) -> Result<SuiteOptions> {
    let mut o = SuiteOptions::default();
    if let Some(v) = a.get_usize("rounds")? {
        o.rounds = v;
    }
    if let Some(v) = a.get_usize("samples")? {
        o.samples_per_client = v;
    }
    if let Some(v) = a.get_u64("seed")? {
        o.seed = v;
    }
    if let Some(v) = a.get_usize("workers")? {
        o.workers = v;
    }
    if let Some(v) = a.get_usize("cell-workers")? {
        o.cell_workers = v;
    }
    if let Some(s) = a.get("engine") {
        o.engine = EngineKind::parse(s)?;
    }
    if let Some(s) = a.get("optimizer") {
        o.optimizer = Some(s.to_string());
    }
    if let Some(v) = a.get_usize("batch")? {
        o.batch_size = Some(v);
    }
    if let Some(v) = a.get_f64("lr")? {
        o.lr = v;
    }
    if let Some(s) = a.get("trace-dir") {
        o.trace_dir = s.to_string();
    }
    if let Some(s) = a.get("trace-level") {
        o.trace_level = s.to_string();
    }
    Ok(o)
}

/// Build the training backend a suite subcommand selects (`--engine`).
fn suite_backend(a: &Args) -> Result<Arc<dyn TrainBackend>> {
    let kind = EngineKind::parse(a.get("engine").unwrap_or("xla"))?;
    backend_for_kind(kind, a.get("artifacts").unwrap())
}

fn cmd_train(a: &Args) -> Result<()> {
    let artifacts = a.get("artifacts").unwrap();
    // Validate the adaptive-deadline flag before the (possibly
    // expensive) runner construction: 0 disables; anything else must be
    // a positive finite factor (the observer constructor asserts, so
    // reject junk as a typed usage error here).
    let adaptive_slack = a.get_f64("adaptive-deadline")?.unwrap_or(0.0);
    if !(adaptive_slack.is_finite() && adaptive_slack >= 0.0) {
        return Err(Error::Usage(format!(
            "--adaptive-deadline expects a finite slack factor >= 0, \
             got {adaptive_slack}"
        )));
    }
    // --resume takes a file; --resume-latest scans a directory for the
    // newest checkpoint (the partner of --checkpoint-keep rotation).
    let resume_path = match (a.get("resume"), a.get("resume-latest")) {
        (Some(p), None) => Some(p.to_string()),
        (None, Some(dir)) => Some(find_latest_checkpoint(dir)?),
        (None, None) => None,
        (Some(_), Some(_)) => {
            return Err(Error::Usage(
                "pass either --resume or --resume-latest, not both".into(),
            ))
        }
    };
    let mut runner = if let Some(path) = resume_path {
        // A resumed session must replay bit-identically, so the config
        // comes from the checkpoint; overriding flags are ignored.  The
        // checkpoint also names the engine that trained it.
        let ck = RunnerCheckpoint::load(&path)?;
        log::info!(
            "resuming {:?} at round {} from {path} (engine {})",
            ck.cfg.name,
            ck.cursor,
            ck.cfg.engine.name()
        );
        let backend = backend_for(&ck.cfg, artifacts)?;
        Runner::resume(backend, &ck)?
    } else {
        let base = if let Some(p) = a.get("preset") {
            preset(p)?
        } else if let Some(path) = a.get("config") {
            ExperimentConfig::load(path)?
        } else {
            ExperimentConfig::default()
        };
        let cfg = apply_overrides(base, a)?;
        log::info!("config: {}", cfg.to_json().dump());
        Runner::new(cfg, artifacts)?
    };
    if let Some(path) = a.get("live-csv") {
        runner.add_observer(Box::new(MetricsCsvObserver::new(path)));
    }
    if adaptive_slack > 0.0 {
        let warmup = a.get_usize("adaptive-warmup")?.unwrap_or(3);
        let mut obs = AdaptiveDeadlineObserver::with_params(adaptive_slack, 0.3, warmup)
            .with_tracer(runner.tracer().clone());
        if a.has("adaptive-per-cluster") {
            obs = obs.per_cluster();
        }
        runner.add_observer(Box::new(obs));
    }
    if runner.cfg.plateau_rounds > 0 {
        let obs = PlateauStopObserver::new(
            runner.cfg.plateau_rounds,
            runner.cfg.plateau_min_delta,
        )
        .with_tracer(runner.tracer().clone());
        runner.add_observer(Box::new(obs));
    }
    // Drive the stepwise session: one step per round, with periodic
    // checkpoints when requested.  With --checkpoint-keep the files are
    // round-stamped and rotated; without it one file is overwritten
    // (atomically) in place.
    let ckpt_every = a.get_usize("checkpoint-every")?.unwrap_or(0);
    let ckpt_keep = a.get_usize("checkpoint-keep")?.unwrap_or(0);
    let ckpt_path = a
        .get("checkpoint")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}.ckpt.json", runner.cfg.name));
    while !runner.is_done() {
        runner.step()?;
        if ckpt_every > 0 && runner.round() % ckpt_every == 0 {
            let path = if ckpt_keep > 0 {
                round_stamped_path(&ckpt_path, runner.round())
            } else {
                ckpt_path.clone()
            };
            runner.checkpoint()?.save(&path)?;
            log::info!("checkpoint at round {} -> {path}", runner.round());
            for gone in prune_checkpoints(&ckpt_path, ckpt_keep)? {
                log::debug!("pruned old checkpoint {gone}");
            }
        }
    }
    let report = runner.report();
    println!(
        "\n[{}] {} rounds: final acc {:.2}%  best {:.2}%  loss {:.4}  comm {:.3e} byte-hops",
        report.algorithm,
        report.rounds,
        report.final_accuracy * 100.0,
        report.best_accuracy * 100.0,
        report.final_loss,
        report.total_byte_hops as f64,
    );
    for (phase, secs) in &report.phase_seconds {
        println!("  {phase:>10}: {secs:.2}s");
    }
    if let Some(path) = a.get("out") {
        report.metrics.to_csv().save(path)?;
        println!("wrote {path}");
    }
    if let Some(path) = a.get("out-json") {
        std::fs::write(path, report.metrics.to_json().pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_table1(a: &Args) -> Result<()> {
    let backend = suite_backend(a)?;
    let o = suite_options(a)?;
    let (table, cells) = table1(&backend, &o, a.has("fast"))?;
    println!("{}", table.render());
    if let Some(path) = a.get("out") {
        let mut csv = edgeflow::util::csv::CsvWriter::new(&[
            "dataset", "distribution", "algorithm", "accuracy", "byte_hops",
        ]);
        for c in &cells {
            csv.row(&[
                c.dataset.name().to_string(),
                c.distribution.name(),
                c.algorithm.name().to_string(),
                format!("{}", c.accuracy),
                c.byte_hops.to_string(),
            ]);
        }
        csv.save(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_fig3(a: &Args) -> Result<()> {
    let backend = suite_backend(a)?;
    let o = suite_options(a)?;
    let part = a.get("part").unwrap_or("both").to_string();
    let window = a.get_usize("window")?.unwrap_or(5);
    let mut csv = edgeflow::util::csv::CsvWriter::new(&[
        "part", "series", "round", "accuracy", "smoothed",
    ]);
    let mut emit = |part: &str, series: String, rep: &edgeflow::fl::runner::RunReport| {
        let curve = rep.metrics.accuracy_curve();
        let vals: Vec<f64> = curve.iter().map(|&(_, a)| a).collect();
        let sm = smooth(&vals, window);
        println!(
            "  {series}: final {:.2}%  best {:.2}%",
            rep.final_accuracy * 100.0,
            rep.best_accuracy * 100.0
        );
        for ((round, acc), s) in curve.iter().zip(sm) {
            csv.row(&[
                part.to_string(),
                series.clone(),
                round.to_string(),
                format!("{acc}"),
                format!("{s}"),
            ]);
        }
    };
    if part == "a" || part == "both" {
        let nms: Vec<usize> = a
            .get_list("nms")
            .iter()
            .map(|s| s.parse().map_err(|_| Error::Usage(format!("bad N_m {s}"))))
            .collect::<Result<_>>()?;
        println!("Fig 3(a): accuracy vs rounds for cluster sizes {nms:?}");
        for (n_m, rep) in fig3a(&backend, &o, &nms)? {
            emit("a", format!("Nm={n_m}"), &rep);
        }
    }
    if part == "b" || part == "both" {
        let ks: Vec<usize> = a
            .get_list("ks")
            .iter()
            .map(|s| s.parse().map_err(|_| Error::Usage(format!("bad K {s}"))))
            .collect::<Result<_>>()?;
        println!("Fig 3(b): accuracy vs rounds for local epochs {ks:?}");
        for (k, rep) in fig3b(&backend, &o, &ks)? {
            emit("b", format!("K={k}"), &rep);
        }
    }
    if let Some(path) = a.get("out") {
        csv.save(path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_comm_sim(a: &Args) -> Result<()> {
    let model = a.get("model").unwrap();
    // Fig 4 is pure coordination: with an explicit --param-count it
    // needs no artifacts at all (the manifest only supplies this one
    // number).  Deliberately a **params-only** wire contract — the
    // paper's Fig-4 communication unit is the parameter count, and no
    // optimizer is involved here.  `train`'s runner accounting instead
    // charges the full migrating state (params + optimizer regions), so
    // its absolute byte-hops exceed this study's for momentum (2x) and
    // adam (~3x); cross-algorithm ratios match either way.
    let raw_param_count = match a.get_usize("param-count")? {
        Some(n) => n,
        None => Manifest::load(a.get("artifacts").unwrap())?
            .variant(model)?
            .param_count(),
    };
    // Compression codecs shrink every model transfer; express the codec's
    // wire size as an equivalent f32 parameter count so the topology math
    // is unchanged (ratios between algorithms are codec-invariant, the
    // absolute loads scale by Codec::ratio).
    let codec = edgeflow::fl::compress::Codec::parse(a.get("codec").unwrap())?;
    let param_count =
        (codec.wire_bytes(raw_param_count) as usize).div_ceil(4);
    if codec != edgeflow::fl::compress::Codec::None {
        println!(
            "codec {}: {} -> {} wire bytes per transfer ({:.1}% of raw)\n",
            codec.name(),
            edgeflow::util::human_bytes((raw_param_count * 4) as u64),
            edgeflow::util::human_bytes(codec.wire_bytes(raw_param_count)),
            codec.ratio(raw_param_count) * 100.0
        );
    }
    let rounds = a.get_usize("rounds")?.unwrap_or(100);
    let clusters = a.get_usize("clusters")?.unwrap_or(10);
    let csize = a.get_usize("cluster-size")?.unwrap_or(10);
    let seed = a.get_u64("seed")?.unwrap_or(0);
    let algs = [
        Algorithm::FedAvg,
        Algorithm::HierFl,
        Algorithm::SeqFl,
        Algorithm::EdgeFlowRand,
        Algorithm::EdgeFlowSeq,
        Algorithm::EdgeFlowHop,
        Algorithm::EdgeFlowLatency,
    ];
    println!(
        "model {model}: {param_count} parameters ({} per transfer)\n",
        edgeflow::util::human_bytes((param_count * 4) as u64)
    );
    let workers = a.get_usize("workers")?.unwrap_or(1);
    let (table, results) =
        fig4(param_count, clusters, csize, rounds, &algs, seed, workers)?;
    println!("{}", table.render());
    if a.has("latency") {
        let mut t = Table::new(&["Topology", "Algorithm", "mean transfer latency (s)"])
            .align(0, Align::Left)
            .align(1, Align::Left);
        for r in &results {
            t.row(&[
                r.topology.name().to_string(),
                r.algorithm.name().to_string(),
                format!("{:.4}", r.round_latency_s),
            ]);
        }
        println!("{}", t.render());
    }
    if let Some(path) = a.get("out") {
        let mut csv = edgeflow::util::csv::CsvWriter::new(&[
            "topology",
            "algorithm",
            "byte_hops_per_round",
            "vs_fedavg",
            "latency_s",
            "participants_per_round",
            "byte_hops_per_participant",
        ]);
        for r in &results {
            csv.row(&[
                r.topology.name().to_string(),
                r.algorithm.name().to_string(),
                format!("{}", r.byte_hops_per_round),
                format!("{}", r.vs_fedavg),
                format!("{}", r.round_latency_s),
                format!("{}", r.participants_per_round),
                format!("{}", r.byte_hops_per_participant()),
            ]);
        }
        csv.save(path)?;
        println!("wrote {path}");
    }
    if let Some(path) = a.get("out-json") {
        let j = edgeflow::util::json::Json::arr(results.iter().map(|r| {
            edgeflow::util::json::Json::obj(vec![
                ("topology", r.topology.name().into()),
                ("algorithm", r.algorithm.name().into()),
                ("byte_hops_per_round", r.byte_hops_per_round.into()),
                ("vs_fedavg", r.vs_fedavg.into()),
                ("latency_s", r.round_latency_s.into()),
                ("participants_per_round", r.participants_per_round.into()),
                (
                    "byte_hops_per_participant",
                    r.byte_hops_per_participant().into(),
                ),
            ])
        }));
        std::fs::write(path, j.pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_theory(a: &Args) -> Result<()> {
    let p = TheoryParams {
        l: a.get_f64("l")?.unwrap(),
        g2: a.get_f64("g2")?.unwrap(),
        sigma2: a.get_f64("sigma2")?.unwrap(),
        init_gap: a.get_f64("gap")?.unwrap(),
        eta: a.get_f64("eta")?.unwrap(),
        k: a.get_usize("k")?.unwrap(),
        t: a.get_usize("t")?.unwrap(),
        lambda2: vec![a.get_f64("lambda2")?.unwrap()],
        n_m: vec![a.get_usize("nm")?.unwrap()],
    };
    let b = bound(&p);
    println!("Theorem 1 bound (Eq. 8) at K={} eta={} T={}:", p.k, p.eta, p.t);
    println!("  init term          4(F0-F*)/(K eta T) = {:.6}", b.init);
    println!("  heterogeneity      (2/T) sum lambda^2  = {:.6}", b.heterogeneity);
    println!("  gradient variance  (2/T) sum L.eta.s2/Nm = {:.6}", b.variance);
    println!("  client drift       4L^2K^2eta^2G^2/3   = {:.6}", b.drift);
    println!("  total                                  = {:.6}", b.total());
    let kmax = a.get_usize("kmax")?.unwrap();
    println!("\nK-scan (non-monotonicity behind Fig 3b):");
    let scan = k_scan(&p, kmax);
    let best = scan
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .copied();
    for (k, total) in &scan {
        let marker = if Some((*k, *total)) == best { "  <-- min" } else { "" };
        println!("  K={k:<3} bound={total:.6}{marker}");
    }
    Ok(())
}

fn cmd_inspect(a: &Args) -> Result<()> {
    if a.has("manifest") {
        let m = Manifest::load(a.get("artifacts").unwrap())?;
        let mut t = Table::new(&["variant", "arch", "image", "params", "opts", "K values"])
            .align(0, Align::Left)
            .align(1, Align::Left);
        for (name, v) in &m.variants {
            t.row(&[
                name.clone(),
                v.arch.clone(),
                format!("{:?}", v.image),
                v.param_count().to_string(),
                v.optimizers.join(","),
                format!("{:?}", v.k_values),
            ]);
        }
        println!("{}", t.render());
    }
    if a.has("partitions") {
        let dist = Distribution::parse(a.get("dist").unwrap())?;
        let clients = a.get_usize("clients")?.unwrap_or(100);
        let clusters = a.get_usize("clusters")?.unwrap_or(10);
        let seed = a.get_u64("seed")?.unwrap_or(0);
        let fed = build_federation(
            DatasetKind::SynthFashion,
            &dist,
            clients,
            clusters,
            120,
            10,
            seed,
        )?;
        println!(
            "Fig 2 — per-client class histograms, {} over {clients} clients:",
            dist.name()
        );
        for c in fed.clients.iter() {
            let hist = c
                .quotas
                .iter()
                .map(|&n| format!("{n:>3}"))
                .collect::<Vec<_>>()
                .join(" ");
            println!(
                "  client {:>3} cluster {:>2} [{}] ({})",
                c.id,
                c.cluster,
                hist,
                c.distribution.name()
            );
        }
    }
    if a.has("topology") {
        for kind in TopologyKind::ALL {
            let topo = build_topo(&TopologyParams::new(kind, 10, 10))?;
            let rt = RouteTable::hops(&topo);
            let cloud = topo.cloud()?;
            let bs = topo.base_stations();
            let hops: Vec<String> = bs
                .iter()
                .map(|&b| rt.dist(b, cloud).map_or("-".into(), |h| h.to_string()))
                .collect();
            let migr: Vec<String> = (0..bs.len())
                .map(|i| {
                    let j = (i + 1) % bs.len();
                    rt.dist(bs[i], bs[j]).map_or("-".into(), |h| h.to_string())
                })
                .collect();
            println!(
                "{:<18} nodes={:<4} links={:<4} BS->cloud hops=[{}] BS->next hops=[{}]",
                kind.name(),
                topo.node_count(),
                topo.link_count(),
                hops.join(","),
                migr.join(",")
            );
        }
    }
    if !a.has("manifest") && !a.has("partitions") && !a.has("topology") {
        return Err(Error::Usage(
            "pass at least one of --manifest, --partitions, --topology".into(),
        ));
    }
    Ok(())
}

/// Load a campaign spec and fold the execution-knob CLI overrides onto
/// it.  Only the knobs the digest ignores are overridable — the sweep
/// itself always comes from the file.
fn campaign_spec(a: &Args, path: &str) -> Result<CampaignSpec> {
    let mut spec = CampaignSpec::load(path)?;
    if let Some(v) = a.get_usize("workers")? {
        spec.workers = v;
    }
    if let Some(v) = a.get_usize("cell-workers")? {
        spec.cell_workers = v;
    }
    if let Some(v) = campaign_tolerance(a)? {
        spec.tolerance = v;
    }
    Ok(spec)
}

fn campaign_tolerance(a: &Args) -> Result<Option<f64>> {
    match a.get_f64("tolerance")? {
        None => Ok(None),
        Some(v) if v.is_finite() && v >= 0.0 => Ok(Some(v)),
        Some(v) => Err(Error::Usage(format!(
            "--tolerance expects a finite number >= 0, got {v}"
        ))),
    }
}

fn print_winners(w: &Json) {
    println!("winners:");
    if let Some(tables) = w.as_obj() {
        for (metric, v) in tables {
            match v {
                Json::Null => println!("  {metric:<20} -"),
                v => println!(
                    "  {metric:<20} {}  ({})",
                    v.get("cell").and_then(Json::as_str).unwrap_or("?"),
                    v.get("value").map(|x| x.dump()).unwrap_or_default()
                ),
            }
        }
    }
}

/// `campaign validate`: expand the grid and print it without training —
/// the dry run that catches spec typos (typed errors, not panics).
fn campaign_validate(a: &Args, path: &str) -> Result<()> {
    let spec = campaign_spec(a, path)?;
    let cells = spec.expand()?;
    let (pool, per_cell) = split_budget(spec.workers, spec.cell_workers);
    println!(
        "campaign {:?}: {} axes, {} cells, spec digest {}",
        spec.name,
        spec.axes.len(),
        cells.len(),
        spec.digest()
    );
    println!(
        "budget: {} cell-pool slots x {} round workers per cell",
        pool, per_cell
    );
    let mut t = Table::new(&["#", "cell", "seed", "delta"])
        .align(1, Align::Left)
        .align(3, Align::Left);
    for c in &cells {
        t.row(&[
            c.index.to_string(),
            c.id.clone(),
            c.seed.to_string(),
            c.delta.dump(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `campaign run`: execute the grid (resuming from the journal), write
/// the comparison report, append the trajectory, check the baseline.
fn campaign_run(a: &Args, path: &str) -> Result<()> {
    let spec = campaign_spec(a, path)?;
    let cells = spec.expand()?;
    let journal = if a.has("no-journal") {
        None
    } else {
        Some(
            a.get("journal")
                .map(str::to_string)
                .unwrap_or_else(|| format!("{}.journal.jsonl", spec.name)),
        )
    };
    let opts = CampaignOptions {
        artifacts: a.get("artifacts").unwrap().to_string(),
        journal,
        max_cells: a.get_usize("max-cells")?.unwrap_or(0),
        trace_dir: a.get("trace-dir").unwrap_or("").to_string(),
        trace_level: a.get("trace-level").unwrap_or("full").to_string(),
    };
    let outcome = run_campaign(&spec, &cells, &opts)?;
    println!(
        "campaign {}: {} cells — {} from the journal, {} run now",
        spec.name,
        cells.len(),
        outcome.skipped,
        outcome.executed
    );
    let Some(results) = outcome.complete_results() else {
        let pending = outcome.results.iter().filter(|r| r.is_none()).count();
        println!("{pending} cell(s) pending — re-run to continue from the journal");
        return Ok(());
    };
    let out = a
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}_report.json", spec.name));
    std::fs::write(&out, render_report(&spec, &results))?;
    println!("wrote {out}");
    let mut t = Table::new(&[
        "cell", "final acc", "loss", "wire bytes", "clock_s", "rounds",
    ])
    .align(0, Align::Left);
    for c in &results {
        t.row(&[
            c.id.clone(),
            format!("{:.2}%", c.final_accuracy * 100.0),
            format!("{:.4}", c.final_loss),
            c.wire_bytes.to_string(),
            format!("{:.3}", c.clock_s),
            c.rounds.to_string(),
        ]);
    }
    println!("{}", t.render());
    print_winners(&winners(&results));
    if !a.has("no-bench") {
        let bench = a.get("bench").unwrap();
        append_bench(bench, &spec, &results)?;
        println!("appended trajectory run -> {bench}");
    }
    if let Some(bpath) = a.get("baseline") {
        let old = parse_baseline(&std::fs::read_to_string(bpath)?)?;
        let new: Vec<BaselineCell> =
            results.iter().map(BaselineCell::from_result).collect();
        let regs = regressions(&new, &old, spec.tolerance);
        if !regs.is_empty() {
            for r in &regs {
                eprintln!("REGRESSION: {r}");
            }
            return Err(Error::Config(format!(
                "{} regression(s) vs baseline {bpath} (tolerance {})",
                regs.len(),
                spec.tolerance
            )));
        }
        println!("baseline {bpath}: clean at tolerance {}", spec.tolerance);
    }
    Ok(())
}

/// `campaign report`: print an existing report, optionally comparing it
/// against a baseline report (regressions fail the command).
fn campaign_report(a: &Args, path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let cells = parse_baseline(&text)?;
    let j = Json::parse(&text)?;
    println!(
        "campaign {:?} report ({} cells, spec digest {})",
        j.get("campaign").and_then(Json::as_str).unwrap_or("?"),
        cells.len(),
        j.get("spec_digest").and_then(Json::as_str).unwrap_or("?"),
    );
    let mut t = Table::new(&["cell", "final acc", "loss", "wire bytes", "clock_s"])
        .align(0, Align::Left);
    for c in &cells {
        t.row(&[
            c.id.clone(),
            format!("{:.2}%", c.final_accuracy * 100.0),
            format!("{:.4}", c.final_loss),
            c.wire_bytes.to_string(),
            format!("{:.3}", c.clock_s),
        ]);
    }
    println!("{}", t.render());
    if let Some(w) = j.get("winners") {
        print_winners(w);
    }
    if let Some(bpath) = a.get("baseline") {
        let old = parse_baseline(&std::fs::read_to_string(bpath)?)?;
        let tol = campaign_tolerance(a)?.unwrap_or(0.0);
        let regs = regressions(&cells, &old, tol);
        if !regs.is_empty() {
            for r in &regs {
                eprintln!("REGRESSION: {r}");
            }
            return Err(Error::Config(format!(
                "{} regression(s) vs baseline {bpath} (tolerance {tol})",
                regs.len()
            )));
        }
        println!("baseline {bpath}: clean at tolerance {tol}");
    }
    Ok(())
}

fn cmd_campaign(a: &Args) -> Result<()> {
    let action = a.positional.first().map(String::as_str).ok_or_else(|| {
        Error::Usage("campaign needs an action: run | validate | report".into())
    })?;
    let file = a.positional.get(1).map(String::as_str).ok_or_else(|| {
        Error::Usage(format!("campaign {action} needs a file argument"))
    })?;
    match action {
        "validate" => campaign_validate(a, file),
        "run" => campaign_run(a, file),
        "report" => campaign_report(a, file),
        other => Err(Error::Usage(format!(
            "unknown campaign action {other:?} (expected run | validate | report)"
        ))),
    }
}

/// `trace summarize`: per-(category, name) and per-link rollups of a
/// JSONL trace — every line is schema-validated on the way through, so
/// this doubles as a trace linter.
fn trace_summarize(file: &str) -> Result<()> {
    let s = edgeflow::obs::summary::summarize(file)?;
    match &s.header {
        Some(h) => println!(
            "trace {file}: run {:?} level {} — {} events",
            h.get("run").and_then(Json::as_str).unwrap_or("?"),
            h.get("level").and_then(Json::as_str).unwrap_or("?"),
            s.events
        ),
        None => println!("trace {file}: {} events (no header)", s.events),
    }
    let mut t = Table::new(&["category", "name", "count", "wall_s", "sim_s", "bytes"])
        .align(0, Align::Left)
        .align(1, Align::Left);
    for ((cat, name), r) in &s.by_kind {
        t.row(&[
            cat.clone(),
            name.clone(),
            r.count.to_string(),
            format!("{:.3}", r.wall_s),
            format!("{:.3}", r.sim_s),
            r.bytes.to_string(),
        ]);
    }
    println!("{}", t.render());
    if !s.by_lane.is_empty() {
        let mut t = Table::new(&["link lane", "transfers", "sim_s", "bytes"])
            .align(0, Align::Left);
        for (lane, r) in &s.by_lane {
            t.row(&[
                lane.clone(),
                r.count.to_string(),
                format!("{:.3}", r.sim_s),
                r.bytes.to_string(),
            ]);
        }
        println!("{}", t.render());
    }
    if let Some(reg) = s.metrics.as_ref().and_then(|m| m.get("registry")) {
        println!("final metrics: {}", reg.dump());
    }
    Ok(())
}

fn cmd_trace(a: &Args) -> Result<()> {
    let action = a.positional.first().map(String::as_str).ok_or_else(|| {
        Error::Usage("trace needs an action: summarize | export".into())
    })?;
    let file = a.positional.get(1).map(String::as_str).ok_or_else(|| {
        Error::Usage(format!("trace {action} needs a trace file argument"))
    })?;
    match action {
        "summarize" => trace_summarize(file),
        "export" => {
            let out = a.get("chrome").ok_or_else(|| {
                Error::Usage("trace export needs --chrome <out.json>".into())
            })?;
            let n = edgeflow::obs::chrome::export_chrome(file, out)?;
            println!("wrote {n} Chrome trace events -> {out}");
            println!(
                "open in Perfetto (https://ui.perfetto.dev) or chrome://tracing"
            );
            Ok(())
        }
        other => Err(Error::Usage(format!(
            "unknown trace action {other:?} (expected summarize | export)"
        ))),
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let c = cli();
    let a = c.parse(&argv)?;
    edgeflow::util::logging::init(a.has("verbose"));
    match a.command.as_str() {
        "train" => cmd_train(&a),
        "table1" => cmd_table1(&a),
        "fig3" => cmd_fig3(&a),
        "comm-sim" => cmd_comm_sim(&a),
        "theory" => cmd_theory(&a),
        "inspect" => cmd_inspect(&a),
        "campaign" => cmd_campaign(&a),
        "trace" => cmd_trace(&a),
        "presets" => {
            for p in PRESETS {
                let cfg = preset(p)?;
                println!("{p:<24} {}", cfg.to_json().dump());
            }
            Ok(())
        }
        other => Err(Error::Usage(format!("unhandled command {other}"))),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(Error::Usage(msg)) => {
            eprintln!("{msg}");
            ExitCode::from(2)
        }
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
