//! Discrete-event network simulator.
//!
//! Extends the hop-count accounting of [`crate::topology`] with *time*:
//! transfers move store-and-forward along their route, each link is a FIFO
//! server with finite bandwidth and fixed propagation latency, and
//! contention shows up as queueing delay.  Used for the latency extension
//! of the Fig 4 study (`edgeflow comm-sim --latency`) and for the netsim
//! property tests.

pub mod sim;

pub use sim::{NetSim, NetSimState, TransferOutcome};
