//! Store-and-forward FIFO discrete-event simulation.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::obs::{TraceLevel, Tracer};
use crate::topology::graph::{LinkId, NodeId, Topology};
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};

/// Completed transfer timing.
#[derive(Debug, Clone)]
pub struct TransferOutcome {
    pub id: usize,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    pub submitted_s: f64,
    pub delivered_s: f64,
    /// Total time spent waiting behind other transfers.
    pub queue_wait_s: f64,
    pub hops: usize,
}

impl TransferOutcome {
    pub fn latency_s(&self) -> f64 {
        self.delivered_s - self.submitted_s
    }
}

#[derive(Debug, Clone)]
struct Pending {
    id: usize,
    src: NodeId,
    dst: NodeId,
    bytes: u64,
    submitted_s: f64,
    path: Vec<LinkId>,
    next_hop: usize,
    queue_wait_s: f64,
}

/// Heap event: a transfer becomes ready to enter its next hop at `time`.
#[derive(Debug, Clone, PartialEq)]
struct Event {
    time: f64,
    seq: usize, // FIFO tie-break
    pending_idx: usize,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp: a poisoned (NaN) event time must order, not panic —
        // link parameters are validated at `Topology::add_link`, but the
        // heap stays safe even against hand-built topologies.
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

/// The simulator.  Deterministic: FIFO per link, ties broken by
/// submission order.
///
/// A `NetSim` owns (a shared handle to) its topology and is
/// **persistent**: state (`link_free_s`, the clock) carries across
/// [`NetSim::run`] calls, so the simulated clock accumulates round after
/// round, and a caller that keeps traffic in flight across submissions
/// sees congestion compound instead of an idle network.  (A caller that
/// drains every round gets idle links back at each boundary — the clock
/// is then what persists.)  [`NetSim::reset`] restores round-zero
/// semantics; [`Clone`] supports cheap what-if probes (e.g. the
/// latency-aware scheduler's candidate transfers).
#[derive(Clone)]
pub struct NetSim {
    /// Shared so probe clones don't deep-copy the graph.
    topo: std::sync::Arc<Topology>,
    /// Next time each link is free (links are half-duplex single-servers).
    link_free_s: Vec<f64>,
    /// Accumulated busy seconds per link (for utilization reports).
    link_busy_s: Vec<f64>,
    /// In-flight transfers only: [`NetSim::run`] compacts delivered ones
    /// away (ids stay globally unique via `id_base`), so a long-lived
    /// persistent sim stays O(round), not O(history).
    pending: Vec<Pending>,
    events: BinaryHeap<Reverse<Event>>,
    seq: usize,
    clock_s: f64,
    /// Transfer ids below this belong to already-compacted runs.
    id_base: usize,
}

impl NetSim {
    pub fn new(topo: &Topology) -> NetSim {
        NetSim {
            topo: std::sync::Arc::new(topo.clone()),
            link_free_s: vec![0.0; topo.link_count()],
            link_busy_s: vec![0.0; topo.link_count()],
            pending: Vec::new(),
            events: BinaryHeap::new(),
            seq: 0,
            clock_s: 0.0,
            id_base: 0,
        }
    }

    /// Drop all traffic history and return to an idle network at clock 0
    /// — the pre-persistence escape hatch for per-round-makespan use.
    pub fn reset(&mut self) {
        for v in &mut self.link_free_s {
            *v = 0.0;
        }
        for v in &mut self.link_busy_s {
            *v = 0.0;
        }
        self.pending.clear();
        self.events.clear();
        self.seq = 0;
        self.clock_s = 0.0;
        self.id_base = 0;
    }

    /// Queue a transfer for delivery; routed on `routes` (the DES
    /// contract is time-weighted routing — [`RouteTable::latency`], or
    /// [`RouteTable::transfer_time`] when the payload size is known —
    /// unless a test deliberately rides hop-shortest paths) at
    /// submission time.  Once [`NetSim::run`] has
    /// drained earlier traffic, `at_s` must not precede [`NetSim::now_s`]
    /// (the clock is monotone).
    pub fn submit(
        &mut self,
        routes: &RouteTable,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        at_s: f64,
    ) -> Result<usize> {
        let path = routes
            .path(src, dst)
            .ok_or_else(|| Error::Topology(format!("no route {src:?} -> {dst:?}")))?;
        let idx = self.pending.len();
        let id = self.id_base + idx;
        self.pending.push(Pending {
            id,
            src,
            dst,
            bytes,
            submitted_s: at_s,
            path,
            next_hop: 0,
            queue_wait_s: 0.0,
        });
        self.events.push(Reverse(Event { time: at_s, seq: self.seq, pending_idx: idx }));
        self.seq += 1;
        Ok(id)
    }

    /// Run until all submitted transfers deliver; returns outcomes in
    /// completion order.  The simulation clock is monotone.
    pub fn run(&mut self) -> Vec<TransferOutcome> {
        self.run_traced(&Tracer::off())
    }

    /// [`NetSim::run`] with link-occupancy tracing: at `full` trace
    /// level every hop emits one sim-clock span on its link's lane
    /// (`linkN`, window = transmission start → end), so the Chrome
    /// export shows the per-link schedule the FIFO simulation actually
    /// produced.  Event processing is identical to the untraced run.
    pub fn run_traced(&mut self, tracer: &Tracer) -> Vec<TransferOutcome> {
        let trace_links = tracer.enabled(TraceLevel::Full);
        let mut done = Vec::new();
        while let Some(Reverse(ev)) = self.events.pop() {
            debug_assert!(ev.time >= self.clock_s - 1e-12, "clock went backwards");
            self.clock_s = self.clock_s.max(ev.time);
            let p = &mut self.pending[ev.pending_idx];
            if p.next_hop >= p.path.len() {
                // Delivered (zero-hop transfers deliver instantly).
                done.push(TransferOutcome {
                    id: p.id,
                    src: p.src,
                    dst: p.dst,
                    bytes: p.bytes,
                    submitted_s: p.submitted_s,
                    delivered_s: ev.time,
                    queue_wait_s: p.queue_wait_s,
                    hops: p.path.len(),
                });
                continue;
            }
            let l = p.path[p.next_hop];
            let link = self.topo.link(l);
            let start = ev.time.max(self.link_free_s[l.0]);
            p.queue_wait_s += start - ev.time;
            let tx_s = if p.bytes == 0 {
                0.0
            } else {
                (p.bytes as f64 * 8.0) / (link.bandwidth_mbps * 1e6)
            };
            let free_at = start + tx_s;
            self.link_free_s[l.0] = free_at;
            self.link_busy_s[l.0] += tx_s;
            let arrive = free_at + link.latency_ms / 1e3;
            if trace_links {
                tracer.span_at(
                    TraceLevel::Full,
                    "link",
                    "tx",
                    &format!("link{}", l.0),
                    tracer.rel_now_ns(),
                    0,
                    Some((start, tx_s)),
                    vec![
                        ("transfer", p.id.into()),
                        ("bytes", p.bytes.into()),
                        ("hop", p.next_hop.into()),
                        ("queue_s", crate::util::json::Json::Num(start - ev.time)),
                    ],
                );
            }
            p.next_hop += 1;
            self.events.push(Reverse(Event {
                time: arrive,
                seq: self.seq,
                pending_idx: ev.pending_idx,
            }));
            self.seq += 1;
        }
        // Everything delivered (the loop drains the heap): compact the
        // bookkeeping so a persistent sim doesn't accumulate history.
        self.id_base += self.pending.len();
        self.pending.clear();
        done.sort_by(|a, b| a.delivered_s.total_cmp(&b.delivered_s));
        done
    }

    /// Link utilization over `[0, horizon_s]`.
    pub fn utilization(&self, l: LinkId, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return 0.0;
        }
        (self.link_busy_s[l.0] / horizon_s).min(1.0)
    }

    /// Current simulation clock.
    pub fn now_s(&self) -> f64 {
        self.clock_s
    }

    /// Snapshot the carried state for a checkpoint.  Only a *drained* sim
    /// can snapshot — in-flight transfers live in the event heap and are
    /// deliberately not serialized (the runner's rounds are synchronous
    /// barriers, so at every round boundary the heap is empty).
    pub fn state(&self) -> Result<NetSimState> {
        if !self.pending.is_empty() || !self.events.is_empty() {
            return Err(Error::Data(format!(
                "cannot checkpoint a NetSim with {} in-flight transfers — \
                 run() to drain first",
                self.pending.len()
            )));
        }
        Ok(NetSimState {
            link_free_s: self.link_free_s.clone(),
            link_busy_s: self.link_busy_s.clone(),
            clock_s: self.clock_s,
            seq: self.seq,
            id_base: self.id_base,
        })
    }

    /// Restore a snapshot taken by [`NetSim::state`] onto a sim built
    /// over the same topology.  The continuation — clocks, FIFO
    /// tie-breaks, transfer ids — is bit-identical to the uninterrupted
    /// sim's.
    pub fn restore(&mut self, st: &NetSimState) -> Result<()> {
        if st.link_free_s.len() != self.link_free_s.len()
            || st.link_busy_s.len() != self.link_busy_s.len()
        {
            return Err(Error::Data(format!(
                "NetSim snapshot has {} links, topology has {}",
                st.link_free_s.len(),
                self.link_free_s.len()
            )));
        }
        self.link_free_s.clone_from(&st.link_free_s);
        self.link_busy_s.clone_from(&st.link_busy_s);
        self.pending.clear();
        self.events.clear();
        self.clock_s = st.clock_s;
        self.seq = st.seq;
        self.id_base = st.id_base;
        Ok(())
    }
}

/// Serializable carried state of a drained [`NetSim`] (checkpoint/resume):
/// per-link free/busy times, the monotone clock, the FIFO tie-break
/// counter and the transfer-id base.
#[derive(Debug, Clone, PartialEq)]
pub struct NetSimState {
    pub link_free_s: Vec<f64>,
    pub link_busy_s: Vec<f64>,
    pub clock_s: f64,
    pub seq: usize,
    pub id_base: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::topology::builder::{build, TopologyParams};
    use crate::topology::graph::NodeKind;

    fn two_node() -> Topology {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        t.add_link(a, b, 8.0, 100.0); // 8 Mbps, 100 ms
        t
    }

    #[test]
    fn single_transfer_timing() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        // 1 MB over 8 Mbps = 1 s + 0.1 s latency
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out.len(), 1);
        assert!((out[0].latency_s() - 1.1).abs() < 1e-9, "{}", out[0].latency_s());
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn fifo_queueing_delay() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out.len(), 2);
        // Second transfer waits 1 s for the link.
        assert!((out[1].queue_wait_s - 1.0).abs() < 1e-9);
        assert!((out[1].delivered_s - 2.1).abs() < 1e-9);
    }

    #[test]
    fn zero_byte_zero_latency_is_instant() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        t.add_link(a, b, 1.0, 0.0);
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, a, b, 0, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].latency_s(), 0.0);
    }

    #[test]
    fn self_transfer_delivers_immediately() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(0), 123, 5.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].delivered_s, 5.0);
        assert_eq!(out[0].hops, 0);
    }

    #[test]
    fn multihop_store_and_forward() {
        let p = TopologyParams::new(TopologyKind::DepthLinear, 3, 1);
        let t = build(&p).unwrap();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        let bs0 = t.edge_bs(0).unwrap();
        let cloud = t.cloud().unwrap();
        sim.submit(&rt, bs0, cloud, 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].hops, 3); // bs0-bs1-bs2-cloud
        // 2 edge hops @1 Gbps + 1 backbone @10 Gbps + latencies
        let tx = 2.0 * 8e6 / 1e9 + 8e6 / 1e10;
        let lat = (2.0 * 1.0 + 5.0) / 1e3;
        assert!((out[0].latency_s() - (tx + lat)).abs() < 1e-9);
    }

    #[test]
    fn utilization_reported() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        sim.run();
        assert!((sim.utilization(LinkId(0), 2.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn state_persists_across_runs() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let first = sim.run();
        assert!((sim.now_s() - 1.1).abs() < 1e-9);
        // Same transfer submitted at the carried-forward clock: delivery
        // time stacks on the first round instead of restarting at zero.
        let at = sim.now_s();
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, at).unwrap();
        let second = sim.run();
        assert!((second[0].delivered_s - (first[0].delivered_s + 1.1)).abs() < 1e-9);
        assert_eq!(second[0].queue_wait_s, 0.0, "link freed before resubmit");
    }

    #[test]
    fn congestion_compounds_across_undrained_rounds() {
        // Two "rounds" submitted into one persistent sim without draining
        // in between: the second queues behind the first instead of
        // seeing an idle network.
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.5).unwrap();
        let out = sim.run();
        assert!((out[1].queue_wait_s - 0.5).abs() < 1e-9, "{}", out[1].queue_wait_s);
        assert!((out[1].delivered_s - 2.1).abs() < 1e-9);
    }

    #[test]
    fn reset_restores_idle_network() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let fresh = sim.run()[0].latency_s();
        sim.reset();
        assert_eq!(sim.now_s(), 0.0);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].latency_s(), fresh);
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn cloned_probe_leaves_original_untouched() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        sim.run();
        let clock = sim.now_s();
        let mut probe = sim.clone();
        probe.submit(&rt, NodeId(0), NodeId(1), 1_000_000, probe.now_s()).unwrap();
        probe.run();
        assert!(probe.now_s() > clock);
        assert_eq!(sim.now_s(), clock, "probe must not advance the original");
    }

    #[test]
    fn transfer_ids_stay_unique_across_compacted_runs() {
        // run() compacts delivered bookkeeping; ids must keep advancing so
        // a persistent caller can never confuse two rounds' transfers.
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        let a = sim.submit(&rt, NodeId(0), NodeId(1), 10, 0.0).unwrap();
        sim.run();
        let at = sim.now_s();
        let b = sim.submit(&rt, NodeId(0), NodeId(1), 10, at).unwrap();
        let out = sim.run();
        assert_ne!(a, b);
        assert_eq!(out[0].id, b);
        sim.reset();
        assert_eq!(sim.submit(&rt, NodeId(0), NodeId(1), 10, 0.0).unwrap(), 0);
    }

    #[test]
    fn snapshot_restore_continues_bit_identically() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        // Reference: one uninterrupted sim over two rounds of traffic.
        let mut whole = NetSim::new(&t);
        whole.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        whole.run();
        let at = whole.now_s();
        whole.submit(&rt, NodeId(0), NodeId(1), 700_000, at).unwrap();
        let ref_out = whole.run();

        // Same first round, then checkpoint + restore into a fresh sim.
        let mut first = NetSim::new(&t);
        first.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        first.run();
        let snap = first.state().unwrap();
        let mut resumed = NetSim::new(&t);
        resumed.restore(&snap).unwrap();
        assert_eq!(resumed.now_s().to_bits(), first.now_s().to_bits());
        let at = resumed.now_s();
        let id = resumed.submit(&rt, NodeId(0), NodeId(1), 700_000, at).unwrap();
        let out = resumed.run();
        assert_eq!(id, ref_out[0].id, "transfer ids must continue");
        assert_eq!(
            out[0].delivered_s.to_bits(),
            ref_out[0].delivered_s.to_bits()
        );
        assert_eq!(
            out[0].queue_wait_s.to_bits(),
            ref_out[0].queue_wait_s.to_bits()
        );
    }

    #[test]
    fn snapshot_refuses_inflight_transfers() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000, 0.0).unwrap();
        assert!(sim.state().is_err(), "undrained sim must not checkpoint");
        sim.run();
        assert!(sim.state().is_ok());
        // Restore onto a mismatched topology is a typed error.
        let mut bigger = Topology::new();
        let a = bigger.add_node(NodeKind::Router);
        let b = bigger.add_node(NodeKind::Router);
        let c = bigger.add_node(NodeKind::Router);
        bigger.add_link(a, b, 1.0, 1.0);
        bigger.add_link(b, c, 1.0, 1.0);
        let mut other = NetSim::new(&bigger);
        assert!(other.restore(&sim.state().unwrap()).is_err());
    }

    #[test]
    fn traced_run_emits_link_spans_and_matches_untraced_timing() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut plain = NetSim::new(&t);
        plain.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        plain.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let expect = plain.run();

        let sink = std::sync::Arc::new(crate::obs::test_sink::MemSink::default());
        let tracer = crate::obs::Tracer::with_sink(
            Box::new(sink.clone()),
            TraceLevel::Full,
            "netsim-test",
        );
        let mut traced = NetSim::new(&t);
        traced.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        traced.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let out = traced.run_traced(&tracer);
        for (a, b) in out.iter().zip(&expect) {
            assert_eq!(a.delivered_s.to_bits(), b.delivered_s.to_bits());
            assert_eq!(a.queue_wait_s.to_bits(), b.queue_wait_s.to_bits());
        }

        let lines = sink.lines.lock().unwrap();
        let spans: Vec<_> = lines
            .iter()
            .filter(|l| l.str_field("ev").unwrap() == "span")
            .collect();
        assert_eq!(spans.len(), 2, "one hop per transfer");
        assert_eq!(spans[0].str_field("lane").unwrap(), "link0");
        assert_eq!(spans[0].str_field("cat").unwrap(), "link");
        // First tx occupies [0, 1); second queues behind it at [1, 2).
        assert_eq!(spans[0].f64_field("sim_s").unwrap(), 0.0);
        assert!((spans[0].f64_field("sim_dur_s").unwrap() - 1.0).abs() < 1e-9);
        assert!((spans[1].f64_field("sim_s").unwrap() - 1.0).abs() < 1e-9);
        assert!((spans[1].req("attrs").unwrap().f64_field("queue_s").unwrap() - 1.0).abs() < 1e-9);
        // An off tracer emits nothing and is the plain run.
        let mut silent = NetSim::new(&t);
        silent.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let o = silent.run_traced(&crate::obs::Tracer::off());
        assert_eq!(o.len(), 1);
    }

    #[test]
    fn nan_event_times_order_without_panicking() {
        // Event ordering is total: a poisoned time must not abort the heap.
        let a = Event { time: f64::NAN, seq: 0, pending_idx: 0 };
        let b = Event { time: 1.0, seq: 1, pending_idx: 1 };
        let _ = a.cmp(&b);
        let _ = b.cmp(&a);
        assert_eq!(a.cmp(&a), std::cmp::Ordering::Equal);
    }

    #[test]
    fn clock_monotone_under_many_random_transfers() {
        let p = TopologyParams::new(TopologyKind::Hybrid, 8, 2);
        let t = build(&p).unwrap();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        let mut rng = crate::rng::Rng::new(5);
        let nodes = t.clients();
        for i in 0..200 {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            sim.submit(&rt, a, b, rng.below(100_000) as u64, i as f64 * 0.001)
                .unwrap();
        }
        let out = sim.run();
        assert_eq!(out.len(), 200);
        for o in &out {
            assert!(o.delivered_s >= o.submitted_s);
        }
    }
}
