//! Store-and-forward FIFO discrete-event simulation.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::topology::graph::{LinkId, NodeId, Topology};
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};

/// Completed transfer timing.
#[derive(Debug, Clone)]
pub struct TransferOutcome {
    pub id: usize,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    pub submitted_s: f64,
    pub delivered_s: f64,
    /// Total time spent waiting behind other transfers.
    pub queue_wait_s: f64,
    pub hops: usize,
}

impl TransferOutcome {
    pub fn latency_s(&self) -> f64 {
        self.delivered_s - self.submitted_s
    }
}

#[derive(Debug)]
struct Pending {
    id: usize,
    src: NodeId,
    dst: NodeId,
    bytes: u64,
    submitted_s: f64,
    path: Vec<LinkId>,
    next_hop: usize,
    queue_wait_s: f64,
}

/// Heap event: a transfer becomes ready to enter its next hop at `time`.
#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    seq: usize, // FIFO tie-break
    pending_idx: usize,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

/// The simulator.  Deterministic: FIFO per link, ties broken by
/// submission order.
pub struct NetSim<'a> {
    topo: &'a Topology,
    /// Next time each link is free (links are half-duplex single-servers).
    link_free_s: Vec<f64>,
    /// Accumulated busy seconds per link (for utilization reports).
    link_busy_s: Vec<f64>,
    pending: Vec<Pending>,
    events: BinaryHeap<Reverse<Event>>,
    seq: usize,
    clock_s: f64,
}

impl<'a> NetSim<'a> {
    pub fn new(topo: &'a Topology) -> NetSim<'a> {
        NetSim {
            topo,
            link_free_s: vec![0.0; topo.link_count()],
            link_busy_s: vec![0.0; topo.link_count()],
            pending: Vec::new(),
            events: BinaryHeap::new(),
            seq: 0,
            clock_s: 0.0,
        }
    }

    /// Queue a transfer for delivery; routed on the latency-weighted
    /// shortest path at submission time.
    pub fn submit(
        &mut self,
        routes: &RouteTable,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        at_s: f64,
    ) -> Result<usize> {
        let path = routes
            .path(src, dst)
            .ok_or_else(|| Error::Topology(format!("no route {src:?} -> {dst:?}")))?;
        let id = self.pending.len();
        self.pending.push(Pending {
            id,
            src,
            dst,
            bytes,
            submitted_s: at_s,
            path,
            next_hop: 0,
            queue_wait_s: 0.0,
        });
        self.events.push(Reverse(Event { time: at_s, seq: self.seq, pending_idx: id }));
        self.seq += 1;
        Ok(id)
    }

    /// Run until all submitted transfers deliver; returns outcomes in
    /// completion order.  The simulation clock is monotone.
    pub fn run(&mut self) -> Vec<TransferOutcome> {
        let mut done = Vec::new();
        while let Some(Reverse(ev)) = self.events.pop() {
            debug_assert!(ev.time >= self.clock_s - 1e-12, "clock went backwards");
            self.clock_s = self.clock_s.max(ev.time);
            let p = &mut self.pending[ev.pending_idx];
            if p.next_hop >= p.path.len() {
                // Delivered (zero-hop transfers deliver instantly).
                done.push(TransferOutcome {
                    id: p.id,
                    src: p.src,
                    dst: p.dst,
                    bytes: p.bytes,
                    submitted_s: p.submitted_s,
                    delivered_s: ev.time,
                    queue_wait_s: p.queue_wait_s,
                    hops: p.path.len(),
                });
                continue;
            }
            let l = p.path[p.next_hop];
            let link = self.topo.link(l);
            let start = ev.time.max(self.link_free_s[l.0]);
            p.queue_wait_s += start - ev.time;
            let tx_s = if p.bytes == 0 {
                0.0
            } else {
                (p.bytes as f64 * 8.0) / (link.bandwidth_mbps * 1e6)
            };
            let free_at = start + tx_s;
            self.link_free_s[l.0] = free_at;
            self.link_busy_s[l.0] += tx_s;
            let arrive = free_at + link.latency_ms / 1e3;
            p.next_hop += 1;
            self.events.push(Reverse(Event {
                time: arrive,
                seq: self.seq,
                pending_idx: ev.pending_idx,
            }));
            self.seq += 1;
        }
        done.sort_by(|a, b| a.delivered_s.partial_cmp(&b.delivered_s).unwrap());
        done
    }

    /// Link utilization over `[0, horizon_s]`.
    pub fn utilization(&self, l: LinkId, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return 0.0;
        }
        (self.link_busy_s[l.0] / horizon_s).min(1.0)
    }

    /// Current simulation clock.
    pub fn now_s(&self) -> f64 {
        self.clock_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::topology::builder::{build, TopologyParams};
    use crate::topology::graph::NodeKind;

    fn two_node() -> Topology {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        t.add_link(a, b, 8.0, 100.0); // 8 Mbps, 100 ms
        t
    }

    #[test]
    fn single_transfer_timing() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        // 1 MB over 8 Mbps = 1 s + 0.1 s latency
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out.len(), 1);
        assert!((out[0].latency_s() - 1.1).abs() < 1e-9, "{}", out[0].latency_s());
        assert_eq!(out[0].queue_wait_s, 0.0);
    }

    #[test]
    fn fifo_queueing_delay() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out.len(), 2);
        // Second transfer waits 1 s for the link.
        assert!((out[1].queue_wait_s - 1.0).abs() < 1e-9);
        assert!((out[1].delivered_s - 2.1).abs() < 1e-9);
    }

    #[test]
    fn zero_byte_zero_latency_is_instant() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        t.add_link(a, b, 1.0, 0.0);
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, a, b, 0, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].latency_s(), 0.0);
    }

    #[test]
    fn self_transfer_delivers_immediately() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(0), 123, 5.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].delivered_s, 5.0);
        assert_eq!(out[0].hops, 0);
    }

    #[test]
    fn multihop_store_and_forward() {
        let p = TopologyParams::new(TopologyKind::DepthLinear, 3, 1);
        let t = build(&p).unwrap();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        let bs0 = t.edge_bs(0).unwrap();
        let cloud = t.cloud().unwrap();
        sim.submit(&rt, bs0, cloud, 1_000_000, 0.0).unwrap();
        let out = sim.run();
        assert_eq!(out[0].hops, 3); // bs0-bs1-bs2-cloud
        // 2 edge hops @1 Gbps + 1 backbone @10 Gbps + latencies
        let tx = 2.0 * 8e6 / 1e9 + 8e6 / 1e10;
        let lat = (2.0 * 1.0 + 5.0) / 1e3;
        assert!((out[0].latency_s() - (tx + lat)).abs() < 1e-9);
    }

    #[test]
    fn utilization_reported() {
        let t = two_node();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        sim.submit(&rt, NodeId(0), NodeId(1), 1_000_000, 0.0).unwrap();
        sim.run();
        assert!((sim.utilization(LinkId(0), 2.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clock_monotone_under_many_random_transfers() {
        let p = TopologyParams::new(TopologyKind::Hybrid, 8, 2);
        let t = build(&p).unwrap();
        let rt = RouteTable::latency(&t);
        let mut sim = NetSim::new(&t);
        let mut rng = crate::rng::Rng::new(5);
        let nodes = t.clients();
        for i in 0..200 {
            let a = nodes[rng.below(nodes.len())];
            let b = nodes[rng.below(nodes.len())];
            sim.submit(&rt, a, b, rng.below(100_000) as u64, i as f64 * 0.001)
                .unwrap();
        }
        let out = sim.run();
        assert_eq!(out.len(), 200);
        for o in &out {
            assert!(o.delivered_s >= o.submitted_s);
        }
    }
}
