//! Seeded property-testing: random case generation with failure-seed
//! reporting, plus greedy input shrinking for integer vectors.
//!
//! A deliberate, small subset of proptest (which is not vendored in this
//! offline image): `forall` runs a property over N generated cases; on
//! failure it reports the case index and the reproduction seed so the
//! exact case replays with `EDGEFLOW_PROP_SEED`.

use crate::rng::Rng;

/// Case generator handle passed to properties.
pub struct Gen {
    rng: Rng,
    /// Current size hint — grows over the run so later cases are larger.
    pub size: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// Bernoulli.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of ints with length in `[0, size]`.
    pub fn vec_int(&mut self, lo: usize, hi: usize) -> Vec<usize> {
        let n = self.rng.below(self.size + 1);
        (0..n).map(|_| self.int(lo, hi)).collect()
    }

    /// Choose an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Sub-RNG for bulk data generation inside a property.
    pub fn rng(&mut self) -> Rng {
        self.rng.fork(0xfeed)
    }
}

/// Run `prop` over `cases` generated cases.  Panics (with seed info) on the
/// first failing case.  Set `EDGEFLOW_PROP_SEED` to replay a single seed,
/// `EDGEFLOW_PROP_CASES` to override the case count.
pub fn forall<F: FnMut(&mut Gen) -> Result<(), String>>(
    name: &str,
    cases: usize,
    mut prop: F,
) {
    let cases = std::env::var("EDGEFLOW_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    let fixed_seed: Option<u64> = std::env::var("EDGEFLOW_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok());

    let base = 0x00edf10u64;
    for case in 0..cases {
        let seed = fixed_seed.unwrap_or(base.wrapping_add(case as u64 * 0x9E37));
        let mut g = Gen { rng: Rng::new(seed), size: 4 + case * 97 / cases.max(1) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case}/{cases}: {msg}\n\
                 reproduce with EDGEFLOW_PROP_SEED={seed}"
            );
        }
        if fixed_seed.is_some() {
            break;
        }
    }
}

/// Greedy shrink: repeatedly try halving elements / dropping chunks while
/// the failure predicate still holds.  Returns the smallest failing input
/// found.  (Used by tests that want a readable counterexample.)
pub fn shrink_vec<F: Fn(&[usize]) -> bool>(mut xs: Vec<usize>, still_fails: F) -> Vec<usize> {
    // Drop chunks.
    let mut chunk = xs.len() / 2;
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= xs.len() {
            let mut cand = xs.clone();
            cand.drain(i..i + chunk);
            if still_fails(&cand) {
                xs = cand;
            } else {
                i += chunk;
            }
        }
        chunk /= 2;
    }
    // Shrink elements toward zero: halving first, then decrement-by-one to
    // land on the exact boundary value.
    loop {
        let mut changed = false;
        for i in 0..xs.len() {
            while xs[i] > 0 {
                let orig = xs[i];
                let mut cand = xs.clone();
                cand[i] = orig / 2;
                if still_fails(&cand) {
                    xs = cand;
                    changed = true;
                } else {
                    break;
                }
            }
            while xs[i] > 0 {
                let mut cand = xs.clone();
                cand[i] -= 1;
                if still_fails(&cand) {
                    xs = cand;
                    changed = true;
                } else {
                    break;
                }
            }
        }
        if !changed {
            break;
        }
    }
    xs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("sum-commutes", 50, |g| {
            let a = g.int(0, 1000);
            let b = g.int(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math is broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with EDGEFLOW_PROP_SEED=")]
    fn forall_reports_seed_on_failure() {
        forall("always-fails", 3, |_g| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_minimal_vector() {
        // predicate: fails whenever the vec contains an element >= 10
        let start = vec![3, 50, 7, 12, 900];
        let min = shrink_vec(start, |xs| xs.iter().any(|&x| x >= 10));
        assert_eq!(min, vec![10]);
    }

    #[test]
    fn gen_vec_respects_bounds() {
        forall("vec-bounds", 30, |g| {
            let v = g.vec_int(5, 9);
            if v.iter().all(|&x| (5..=9).contains(&x)) {
                Ok(())
            } else {
                Err(format!("out of bounds: {v:?}"))
            }
        });
    }
}
