//! In-tree property-testing harness (no proptest in this offline image).

pub mod prop;

pub use prop::{forall, Gen};
