//! Hand-rolled CLI argument parser (clap is not vendored in this image).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean flags,
//! repeated flags, positional arguments, and generated help text.

use std::collections::BTreeMap;

use crate::config::{
    Algorithm, DatasetKind, Distribution, EngineKind, ExperimentConfig,
    StragglerPolicy, TopologyKind,
};
use crate::fl::compress::Codec;
use crate::util::error::{Error, Result};

/// Declarative flag spec for help rendering + validation.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean flags take no value.
    pub boolean: bool,
    pub default: Option<&'static str>,
}

/// One subcommand.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
    pub positional: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments for a matched subcommand.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Last occurrence of a string flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences (for repeatable flags).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .map_err(|_| Error::Usage(format!("--{name} expects an integer, got {v:?}")))
            })
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.get(name)
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|_| Error::Usage(format!("--{name} expects an integer, got {v:?}")))
            })
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| Error::Usage(format!("--{name} expects a number, got {v:?}")))
            })
            .transpose()
    }

    /// Comma-separated list flag (`--ks 1,2,5`).
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
            .unwrap_or_default()
    }
}

/// The application-level CLI: a set of subcommands.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// Parse argv (without the binary name).  Returns parsed args or a
    /// rendered help/usage error.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            return Err(Error::Usage(self.help()));
        }
        let cmd_name = argv[0].as_str();
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(Error::Usage(self.help()));
        }
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                Error::Usage(format!(
                    "unknown command {cmd_name:?}\n\n{}",
                    self.help()
                ))
            })?;

        let mut values: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let tok = argv[i].as_str();
            if tok == "--help" || tok == "-h" {
                return Err(Error::Usage(self.command_help(spec)));
            }
            if let Some(flag) = tok.strip_prefix("--") {
                let (name, inline) = match flag.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (flag, None),
                };
                let fs = spec.flags.iter().find(|f| f.name == name).ok_or_else(|| {
                    Error::Usage(format!(
                        "unknown flag --{name} for {cmd_name}\n\n{}",
                        self.command_help(spec)
                    ))
                })?;
                let val = if fs.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .ok_or_else(|| Error::Usage(format!("--{name} expects a value")))?
                        .clone()
                };
                values.entry(name.to_string()).or_default().push(val);
            } else {
                positional.push(tok.to_string());
            }
            i += 1;
        }
        if positional.len() > spec.positional.len() {
            return Err(Error::Usage(format!(
                "too many positional arguments for {cmd_name}\n\n{}",
                self.command_help(spec)
            )));
        }
        // Fill declared defaults.
        for f in &spec.flags {
            if let Some(d) = f.default {
                values.entry(f.name.to_string()).or_insert_with(|| vec![d.to_string()]);
            }
        }
        Ok(Args { command: cmd_name.to_string(), values, positional })
    }

    /// Top-level help text.
    pub fn help(&self) -> String {
        let mut s = format!("{}\n\nUSAGE: {} <command> [flags]\n\nCOMMANDS:\n", self.about, self.bin);
        let w = self.commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
        for c in &self.commands {
            s.push_str(&format!("  {:w$}  {}\n", c.name, c.about, w = w));
        }
        s.push_str(&format!("\nRun `{} <command> --help` for command flags.\n", self.bin));
        s
    }

    /// Per-command help text.
    pub fn command_help(&self, spec: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n", self.bin, spec.name, spec.about);
        if !spec.positional.is_empty() {
            s.push_str("\nARGS:\n");
            for (n, h) in &spec.positional {
                s.push_str(&format!("  <{n}>  {h}\n"));
            }
        }
        if !spec.flags.is_empty() {
            s.push_str("\nFLAGS:\n");
            let w = spec.flags.iter().map(|f| f.name.len()).max().unwrap_or(0);
            for f in &spec.flags {
                let d = f
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  --{:w$}  {}{}\n", f.name, f.help, d, w = w));
            }
        }
        s
    }
}

/// Shorthand for building flag specs.
pub fn flag(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, boolean: false, default: None }
}

/// Flag with a default value.
pub fn flag_def(name: &'static str, help: &'static str, default: &'static str) -> FlagSpec {
    FlagSpec { name, help, boolean: false, default: Some(default) }
}

/// Boolean flag.
pub fn switch(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, boolean: true, default: None }
}

/// The shared `--workers` flag: thread count for the parallel round loop
/// and experiment-cell fan-out.  Reports are bit-identical at any value
/// (fixed-order reduction); the knob only buys wall-clock time.
/// Deliberately no declared default: absent must stay distinguishable
/// from explicit so a `workers` value in a config file / preset is not
/// silently clobbered (see `apply_overrides`).
pub fn workers_flag() -> FlagSpec {
    flag(
        "workers",
        "worker threads for local updates / experiment cells (0 = all cores, default 1)",
    )
}

/// The shared `--cell-workers` flag: threads *inside* each experiment
/// cell (the per-cell round loop).  Together with `--workers` this forms
/// the nested-parallelism core budget: the cell pool gets
/// `workers / cell-workers` slots (see `fl::experiments::split_budget`).
/// Reports stay bit-identical across any split; the knobs only trade
/// cell-level against round-level parallelism.  No declared default for
/// the same reason as [`workers_flag`]: a campaign spec's own
/// `cell_workers` must not be silently clobbered.
pub fn cell_workers_flag() -> FlagSpec {
    flag(
        "cell-workers",
        "worker threads inside each experiment cell (cell pool gets workers/cell-workers, default 1)",
    )
}

/// The shared `--trace` flag: structured JSONL trace output path (see
/// `crate::obs`).  No declared default so a `trace` value in a config
/// file stays distinguishable from flag absence.
pub fn trace_flag() -> FlagSpec {
    flag("trace", "write a structured dual-clock trace to this JSONL path")
}

/// The shared `--trace-level` flag, companion to [`trace_flag`].
pub fn trace_level_flag() -> FlagSpec {
    flag(
        "trace-level",
        "trace verbosity: off | round | phase | full (default full)",
    )
}

/// Apply the experiment-shaping CLI flags onto a base config (preset,
/// file, or default) and validate the result.  This is the CLI arm of
/// the config surface: every [`ExperimentConfig`] field is expected to
/// have an override here (the `config-surface-parity` lint rule checks
/// exactly that), and flag absence must stay distinguishable from an
/// explicit value so file/preset settings are never silently clobbered.
pub fn apply_overrides(mut cfg: ExperimentConfig, a: &Args) -> Result<ExperimentConfig> {
    if let Some(s) = a.get("engine") {
        cfg.engine = EngineKind::parse(s)?;
    }
    if let Some(s) = a.get("codec") {
        cfg.codec = Codec::parse(s)?;
    }
    if let Some(s) = a.get("algorithm") {
        cfg.algorithm = Algorithm::parse(s)?;
    }
    if let Some(s) = a.get("dataset") {
        cfg.dataset = DatasetKind::parse(s)?;
        // keep the model consistent unless explicitly overridden
        if a.get("model").is_none() {
            cfg.model = match cfg.dataset {
                DatasetKind::SynthFashion => "fashion_mlp".into(),
                DatasetKind::SynthCifar => "cifar_mlp".into(),
            };
        }
    }
    if let Some(s) = a.get("dist") {
        cfg.distribution = Distribution::parse(s)?;
    }
    if let Some(s) = a.get("model") {
        cfg.model = s.to_string();
    }
    if let Some(s) = a.get("topology") {
        cfg.topology = TopologyKind::parse(s)?;
    }
    if let Some(v) = a.get_usize("rounds")? {
        cfg.rounds = v;
    }
    if let Some(v) = a.get_usize("clients")? {
        cfg.clients = v;
    }
    if let Some(v) = a.get_usize("clusters")? {
        cfg.clusters = v;
    }
    if let Some(v) = a.get_usize("k")? {
        cfg.local_steps = v;
    }
    if let Some(v) = a.get_usize("batch")? {
        cfg.batch_size = v;
    }
    if let Some(v) = a.get_f64("lr")? {
        cfg.lr = v;
    }
    if let Some(s) = a.get("optimizer") {
        cfg.optimizer = s.to_string();
    }
    if let Some(v) = a.get_u64("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = a.get_usize("samples")? {
        cfg.samples_per_client = v;
    }
    if let Some(v) = a.get_usize("test-samples")? {
        cfg.test_samples = v;
    }
    if let Some(v) = a.get_usize("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = a.get_f64("dropout")? {
        cfg.dropout = v;
    }
    if let Some(v) = a.get_f64("deadline-s")? {
        cfg.deadline_s = v;
    }
    if let Some(s) = a.get("straggler-policy") {
        cfg.straggler_policy = StragglerPolicy::parse(s)?;
    }
    if let Some(v) = a.get_usize("plateau-rounds")? {
        cfg.plateau_rounds = v;
    }
    if let Some(v) = a.get_f64("plateau-min-delta")? {
        cfg.plateau_min_delta = v;
    }
    if let Some(v) = a.get_usize("workers")? {
        cfg.workers = v;
    }
    if let Some(s) = a.get("trace") {
        cfg.trace = s.to_string();
    }
    if let Some(s) = a.get("trace-level") {
        cfg.trace_level = s.to_string();
    }
    cfg.validate()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "edgeflow",
            about: "test",
            commands: vec![CommandSpec {
                name: "train",
                about: "train a model",
                flags: vec![
                    flag("rounds", "number of rounds"),
                    flag_def("lr", "learning rate", "0.001"),
                    switch("verbose", "debug logging"),
                ],
                positional: vec![("config", "config file")],
            }],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = cli()
            .parse(&argv(&["train", "--rounds", "10", "cfg.json", "--verbose"]))
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get_usize("rounds").unwrap(), Some(10));
        assert_eq!(a.get("lr"), Some("0.001")); // default applied
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["cfg.json"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = cli().parse(&argv(&["train", "--rounds=7"])).unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), Some(7));
    }

    #[test]
    fn rejects_unknown() {
        assert!(cli().parse(&argv(&["trainx"])).is_err());
        assert!(cli().parse(&argv(&["train", "--bogus", "1"])).is_err());
        assert!(cli().parse(&argv(&["train", "--rounds"])).is_err());
        assert!(cli().parse(&argv(&["train", "a", "b"])).is_err());
    }

    #[test]
    fn type_errors_are_reported() {
        let a = cli().parse(&argv(&["train", "--rounds", "x"])).unwrap();
        assert!(a.get_usize("rounds").is_err());
        let a = cli().parse(&argv(&["train", "--lr", "fast"])).unwrap();
        assert!(a.get_f64("lr").is_err());
    }

    #[test]
    fn help_lists_commands_and_flags() {
        let c = cli();
        let h = c.help();
        assert!(h.contains("train"));
        let err = c.parse(&argv(&["train", "--help"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--rounds"));
        assert!(msg.contains("default: 0.001"));
    }

    #[test]
    fn overrides_map_flags_onto_config() {
        let c = Cli {
            bin: "x",
            about: "t",
            commands: vec![CommandSpec {
                name: "train",
                about: "t",
                flags: vec![
                    flag("rounds", "rounds"),
                    flag("k", "local steps"),
                    flag("plateau-rounds", "early-stop patience"),
                    flag("plateau-min-delta", "early-stop tolerance"),
                ],
                positional: vec![],
            }],
        };
        let a = c
            .parse(&argv(&[
                "train",
                "--rounds",
                "7",
                "--k",
                "3",
                "--plateau-rounds",
                "4",
                "--plateau-min-delta",
                "0.5",
            ]))
            .unwrap();
        let cfg = apply_overrides(ExperimentConfig::default(), &a).unwrap();
        assert_eq!(cfg.rounds, 7);
        assert_eq!(cfg.local_steps, 3);
        assert_eq!(cfg.plateau_rounds, 4);
        assert!((cfg.plateau_min_delta - 0.5).abs() < 1e-12);
    }

    #[test]
    fn list_flag_splits() {
        let c = Cli {
            bin: "x",
            about: "t",
            commands: vec![CommandSpec {
                name: "sweep",
                about: "s",
                flags: vec![flag("ks", "k values")],
                positional: vec![],
            }],
        };
        let a = c.parse(&argv(&["sweep", "--ks", "1, 2,5"])).unwrap();
        assert_eq!(a.get_list("ks"), vec!["1", "2", "5"]);
    }
}
