//! Typed experiment configuration with validation, JSON round-trip, and
//! presets for every experiment in the paper's evaluation section.

use crate::fl::compress::Codec;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::{f64_from_hex, f64_to_hex};

/// Which execution engine runs local updates and evaluation (see
/// [`crate::runtime::backend`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT XLA/PJRT executables from `make artifacts`.
    Xla,
    /// Pure-Rust in-process trainer ([`crate::runtime::native`]) — no
    /// artifacts, runs anywhere; supports the `*_linear`/`*_mlp`/
    /// `*_cnn_slim_fast` variants with `sgd`/`momentum`/`adam` on
    /// blocked-GEMM batch kernels.
    Native,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
        }
    }

    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "native" => Ok(EngineKind::Native),
            other => Err(Error::Config(format!(
                "unknown engine {other:?} (xla|native)"
            ))),
        }
    }
}

/// Which FL algorithm coordinates the round loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Classical FedAvg: random client sample, cloud aggregation.
    FedAvg,
    /// Hierarchical FL: per-cluster edge aggregation + cloud aggregation.
    HierFl,
    /// Fully-sequential FL: one client at a time, P2P migration.
    SeqFl,
    /// EdgeFLow with random next-cluster selection.
    EdgeFlowRand,
    /// EdgeFLow with fixed cyclic cluster sequence.
    EdgeFlowSeq,
    /// EdgeFLow with a hop-aware migration circuit (greedy nearest-BS tour
    /// — the paper's "wireless-aware scheduling" future-work direction).
    EdgeFlowHop,
    /// EdgeFLow with latency-aware migration: the next cluster is the one
    /// with the smallest *simulated* BS->BS transfer time on the current
    /// network state (probed on the persistent DES), ties broken by the
    /// hop-aware tour.
    EdgeFlowLatency,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedAvg => "fedavg",
            Algorithm::HierFl => "hierfl",
            Algorithm::SeqFl => "seqfl",
            Algorithm::EdgeFlowRand => "edgeflow_rand",
            Algorithm::EdgeFlowSeq => "edgeflow_seq",
            Algorithm::EdgeFlowHop => "edgeflow_hop",
            Algorithm::EdgeFlowLatency => "edgeflow_latency",
        }
    }

    pub fn parse(s: &str) -> Result<Algorithm> {
        match s {
            "fedavg" => Ok(Algorithm::FedAvg),
            "hierfl" => Ok(Algorithm::HierFl),
            "seqfl" => Ok(Algorithm::SeqFl),
            "edgeflow_rand" | "edgeflowrand" => Ok(Algorithm::EdgeFlowRand),
            "edgeflow_seq" | "edgeflowseq" => Ok(Algorithm::EdgeFlowSeq),
            "edgeflow_hop" | "edgeflowhop" => Ok(Algorithm::EdgeFlowHop),
            "edgeflow_latency" | "edgeflowlatency" => {
                Ok(Algorithm::EdgeFlowLatency)
            }
            other => Err(Error::Config(format!("unknown algorithm {other:?}"))),
        }
    }

    pub const ALL: [Algorithm; 7] = [
        Algorithm::FedAvg,
        Algorithm::HierFl,
        Algorithm::SeqFl,
        Algorithm::EdgeFlowRand,
        Algorithm::EdgeFlowSeq,
        Algorithm::EdgeFlowHop,
        Algorithm::EdgeFlowLatency,
    ];
}

/// What happens to a client whose simulated upload misses the round
/// deadline (`deadline_s`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerPolicy {
    /// The late update is discarded: the straggler is excluded from the
    /// round's Eq. 3 reduction and its work is lost (PR 2 behavior).
    Drop,
    /// Straggler re-inclusion: the late update is held in session state
    /// and folded, with its Eq. 3 sample weight, into the next round's
    /// reduction instead of being discarded.
    Defer,
}

impl StragglerPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            StragglerPolicy::Drop => "drop",
            StragglerPolicy::Defer => "defer",
        }
    }

    pub fn parse(s: &str) -> Result<StragglerPolicy> {
        match s {
            "drop" => Ok(StragglerPolicy::Drop),
            "defer" => Ok(StragglerPolicy::Defer),
            other => Err(Error::Config(format!(
                "unknown straggler policy {other:?} (drop|defer)"
            ))),
        }
    }
}

/// Client data distribution (paper §IV.A, Fig 2).
#[derive(Debug, Clone, PartialEq)]
pub enum Distribution {
    /// Uniform class mix on every client.
    Iid,
    /// `x%`-non-IID: 1–2 major classes hold `x%` of each client's samples.
    /// Serialized as whole percents (`noniid95`) — fractions round to 1%.
    NonIid { major_fraction: f64 },
    /// Paper preset "NIID A": 10 IID + 20 @95% + 70 @98%.
    NiidA,
    /// Paper preset "NIID B": 10 IID + 90 @100%.
    NiidB,
}

impl Distribution {
    pub fn name(&self) -> String {
        match self {
            Distribution::Iid => "iid".into(),
            Distribution::NonIid { major_fraction } => {
                format!("noniid{:.0}", major_fraction * 100.0)
            }
            Distribution::NiidA => "niid_a".into(),
            Distribution::NiidB => "niid_b".into(),
        }
    }

    pub fn parse(s: &str) -> Result<Distribution> {
        match s {
            "iid" => Ok(Distribution::Iid),
            "niid_a" | "niida" => Ok(Distribution::NiidA),
            "niid_b" | "niidb" => Ok(Distribution::NiidB),
            other => {
                if let Some(pct) = other.strip_prefix("noniid") {
                    let p: f64 = pct.parse().map_err(|_| {
                        Error::Config(format!("bad distribution {other:?}"))
                    })?;
                    if !(0.0..=100.0).contains(&p) {
                        return Err(Error::Config(format!(
                            "non-IID fraction {p} outside [0, 100]"
                        )));
                    }
                    Ok(Distribution::NonIid { major_fraction: p / 100.0 })
                } else {
                    Err(Error::Config(format!("unknown distribution {other:?}")))
                }
            }
        }
    }
}

/// Synthetic dataset family (stands in for FashionMNIST / CIFAR-10; see
/// DESIGN.md §3 for the substitution rationale).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28x28x1, 10 procedurally-generated "apparel-like" classes.
    SynthFashion,
    /// 32x32x3, 10 classes with higher intra-class variance.
    SynthCifar,
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::SynthFashion => "synth_fashion",
            DatasetKind::SynthCifar => "synth_cifar",
        }
    }

    pub fn parse(s: &str) -> Result<DatasetKind> {
        match s {
            "synth_fashion" | "fashion" => Ok(DatasetKind::SynthFashion),
            "synth_cifar" | "cifar" => Ok(DatasetKind::SynthCifar),
            other => Err(Error::Config(format!("unknown dataset {other:?}"))),
        }
    }

    /// (H, W, C)
    pub fn image(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::SynthFashion => (28, 28, 1),
            DatasetKind::SynthCifar => (32, 32, 3),
        }
    }

    pub fn classes(&self) -> usize {
        10
    }
}

/// Edge network shape for the communication study (paper Fig 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// local — edge — cloud (one hop from BS to cloud).
    Simple,
    /// Many base stations fanning into one aggregation router before cloud.
    BreadthParallel,
    /// Base stations chained in a line; the cloud hangs off the far end.
    DepthLinear,
    /// Mixed breadth/depth tree.
    Hybrid,
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Simple => "simple",
            TopologyKind::BreadthParallel => "breadth_parallel",
            TopologyKind::DepthLinear => "depth_linear",
            TopologyKind::Hybrid => "hybrid",
        }
    }

    pub fn parse(s: &str) -> Result<TopologyKind> {
        match s {
            "simple" => Ok(TopologyKind::Simple),
            "breadth_parallel" | "breadth" => Ok(TopologyKind::BreadthParallel),
            "depth_linear" | "depth" => Ok(TopologyKind::DepthLinear),
            "hybrid" => Ok(TopologyKind::Hybrid),
            other => Err(Error::Config(format!("unknown topology {other:?}"))),
        }
    }

    pub const ALL: [TopologyKind; 4] = [
        TopologyKind::Simple,
        TopologyKind::BreadthParallel,
        TopologyKind::DepthLinear,
        TopologyKind::Hybrid,
    ];
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Experiment label used in output paths.
    // lint:allow(config-surface-parity): the run label comes from the preset
    // name or the config file itself; a CLI flag would let two otherwise
    // identical runs collide in the output directory, so none is offered.
    pub name: String,
    pub algorithm: Algorithm,
    pub dataset: DatasetKind,
    pub distribution: Distribution,
    pub topology: TopologyKind,
    /// Total clients N (paper: 100).
    pub clients: usize,
    /// Clusters M; cluster size is `clients / clusters` (paper: N_m = 10).
    pub clusters: usize,
    /// Local steps K per round (paper: 5).
    pub local_steps: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Training minibatch size (paper: 64; must match the artifact).
    pub batch_size: usize,
    /// Learning rate.
    pub lr: f64,
    /// "sgd" | "adam" (paper experiments: Adam).
    pub optimizer: String,
    /// Artifact model variant (see artifacts/manifest.json).
    pub model: String,
    /// Samples per client (train split).
    pub samples_per_client: usize,
    /// Held-out test set size.
    pub test_samples: usize,
    /// Evaluate every this many rounds (0 = only final).
    pub eval_every: usize,
    /// Master seed.
    pub seed: u64,
    /// Worker threads for the round loop: client local updates fan out
    /// across this many threads and the aggregation tree reduces in a
    /// fixed order, so reports are bit-identical at any setting.
    /// `0` = one worker per available core; `1` = sequential (default).
    pub workers: usize,
    /// Failure injection: probability a selected client drops out of a
    /// round before uploading (straggler/radio-loss model).  The round
    /// aggregates over the survivors; a fully-dropped round keeps the
    /// model unchanged.
    pub dropout: f64,
    /// Round deadline in *simulated* network seconds (0 = no deadline).
    /// A client whose upload the DES delivers later than this after the
    /// round opens is a straggler: its traffic still counts, but it is
    /// excluded from the round's Eq. 3 reduction and recorded in
    /// `RoundRecord::stragglers`.
    pub deadline_s: f64,
    /// What to do with a straggler's late update: `drop` discards it
    /// (default), `defer` folds it into the next round's reduction with
    /// its Eq. 3 weight (`RoundRecord::deferred` records the fold).
    pub straggler_policy: StragglerPolicy,
    /// Execution engine: `xla` (AOT artifacts) or `native` (pure-Rust
    /// in-process trainer, no artifacts).
    pub engine: EngineKind,
    /// Model-transfer codec for the wire-size accounting: every
    /// migration/upload/downlink is charged the codec's wire size of
    /// the full migrating state (params ++ BN ++ optimizer regions —
    /// `codec.wire_bytes(layout.total)`) instead of raw f32 bytes, and
    /// the DES sizes its transfers the same way.  Accounting only — the
    /// payload itself stays lossless.
    pub codec: Codec,
    /// Early stopping: end the run after this many consecutive *evaluated*
    /// rounds without test-loss improvement (0 = never stop early).  The
    /// stop lands through `RoundControl::request_stop`, so the checkpoint
    /// cursor still resumes bit-identically.
    pub plateau_rounds: usize,
    /// A loss improvement smaller than this counts as "no improvement"
    /// for `plateau_rounds` (default 0 = any decrease resets the counter).
    pub plateau_min_delta: f64,
    /// Structured trace output path (JSONL, see [`crate::obs`]).  Empty
    /// string disables tracing entirely — the runner then carries a
    /// no-op [`crate::obs::Tracer`] and every report stays byte-identical
    /// to an untraced run.
    pub trace: String,
    /// Trace verbosity when `trace` is set: `round` (round spans and
    /// control events), `phase` (adds per-phase spans), or `full` (adds
    /// per-client and per-transfer spans).  Ignored when `trace` is empty.
    pub trace_level: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            algorithm: Algorithm::EdgeFlowSeq,
            dataset: DatasetKind::SynthFashion,
            distribution: Distribution::Iid,
            topology: TopologyKind::Simple,
            clients: 100,
            clusters: 10,
            local_steps: 5,
            rounds: 50,
            batch_size: 64,
            lr: 1e-3,
            optimizer: "adam".into(),
            model: "fashion_mlp".into(),
            samples_per_client: 120,
            test_samples: 1000,
            eval_every: 5,
            seed: 0,
            workers: 1,
            dropout: 0.0,
            deadline_s: 0.0,
            straggler_policy: StragglerPolicy::Drop,
            engine: EngineKind::Xla,
            codec: Codec::None,
            plateau_rounds: 0,
            plateau_min_delta: 0.0,
            trace: String::new(),
            trace_level: "full".into(),
        }
    }
}

impl ExperimentConfig {
    /// Clients per cluster, `N_m` in the paper.
    pub fn cluster_size(&self) -> usize {
        self.clients / self.clusters
    }

    /// Validate invariants; returns self for chaining.
    pub fn validate(self) -> Result<ExperimentConfig> {
        if self.clients == 0 || self.clusters == 0 {
            return Err(Error::Config("clients/clusters must be positive".into()));
        }
        if self.clients % self.clusters != 0 {
            return Err(Error::Config(format!(
                "clients ({}) must divide evenly into clusters ({})",
                self.clients, self.clusters
            )));
        }
        if self.local_steps == 0 || self.rounds == 0 {
            return Err(Error::Config("local_steps/rounds must be positive".into()));
        }
        if self.batch_size == 0 {
            return Err(Error::Config("batch_size must be positive".into()));
        }
        if !(self.lr > 0.0) {
            return Err(Error::Config(format!("lr must be positive, got {}", self.lr)));
        }
        if self.optimizer != "sgd"
            && self.optimizer != "adam"
            && self.optimizer != "momentum"
        {
            return Err(Error::Config(format!(
                "optimizer must be sgd|momentum|adam, got {:?}",
                self.optimizer
            )));
        }
        if !(0.0..=1.0).contains(&self.dropout) {
            return Err(Error::Config(format!(
                "dropout must be in [0, 1], got {}",
                self.dropout
            )));
        }
        if !self.deadline_s.is_finite() || self.deadline_s < 0.0 {
            return Err(Error::Config(format!(
                "deadline_s must be finite and >= 0 (0 disables), got {}",
                self.deadline_s
            )));
        }
        if !self.plateau_min_delta.is_finite() || self.plateau_min_delta < 0.0 {
            return Err(Error::Config(format!(
                "plateau_min_delta must be finite and >= 0, got {}",
                self.plateau_min_delta
            )));
        }
        // `off` is accepted for symmetry with `--trace-level`; an empty
        // `trace` path is the canonical way to disable tracing.
        crate::obs::TraceLevel::parse(&self.trace_level)?;
        if self.samples_per_client < self.batch_size {
            return Err(Error::Config(format!(
                "samples_per_client ({}) < batch_size ({}) — a client cannot \
                 fill a single minibatch",
                self.samples_per_client, self.batch_size
            )));
        }
        Ok(self)
    }

    // ------------------------------------------------------------- JSON I/O

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", self.name.as_str().into()),
            ("algorithm", self.algorithm.name().into()),
            ("dataset", self.dataset.name().into()),
            ("distribution", self.distribution.name().as_str().into()),
            ("topology", self.topology.name().into()),
            ("clients", self.clients.into()),
            ("clusters", self.clusters.into()),
            ("local_steps", self.local_steps.into()),
            ("rounds", self.rounds.into()),
            ("batch_size", self.batch_size.into()),
            ("lr", self.lr.into()),
            ("optimizer", self.optimizer.as_str().into()),
            ("model", self.model.as_str().into()),
            ("samples_per_client", self.samples_per_client.into()),
            ("test_samples", self.test_samples.into()),
            ("eval_every", self.eval_every.into()),
            ("seed", self.seed.into()),
            ("workers", self.workers.into()),
            ("dropout", self.dropout.into()),
            ("deadline_s", self.deadline_s.into()),
            ("straggler_policy", self.straggler_policy.name().into()),
            ("engine", self.engine.name().into()),
            ("codec", self.codec.name().as_str().into()),
            ("plateau_rounds", self.plateau_rounds.into()),
            ("plateau_min_delta", self.plateau_min_delta.into()),
            ("trace", self.trace.as_str().into()),
            ("trace_level", self.trace_level.as_str().into()),
        ];
        // The decimal percent inside "codec" is the human-readable form;
        // a top-k fraction also travels as exact bits so a checkpoint's
        // embedded config restores bit-identically even for fractions
        // whose percent form is lossy (e.g. 1/3).
        if let Codec::TopK { keep_fraction } = self.codec {
            pairs.push(("codec_keep_hex", f64_to_hex(keep_fraction).as_str().into()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let get_usize = |k: &str, dflt: usize| -> Result<usize> {
            match v.get(k) {
                None => Ok(dflt),
                Some(x) => x.as_usize().ok_or_else(|| {
                    Error::Config(format!("field {k:?} must be an integer"))
                }),
            }
        };
        let cfg = ExperimentConfig {
            name: v.get("name").and_then(Json::as_str).unwrap_or(&d.name).to_string(),
            algorithm: match v.get("algorithm").and_then(Json::as_str) {
                Some(s) => Algorithm::parse(s)?,
                None => d.algorithm,
            },
            dataset: match v.get("dataset").and_then(Json::as_str) {
                Some(s) => DatasetKind::parse(s)?,
                None => d.dataset,
            },
            distribution: match v.get("distribution").and_then(Json::as_str) {
                Some(s) => Distribution::parse(s)?,
                None => d.distribution,
            },
            topology: match v.get("topology").and_then(Json::as_str) {
                Some(s) => TopologyKind::parse(s)?,
                None => d.topology,
            },
            clients: get_usize("clients", d.clients)?,
            clusters: get_usize("clusters", d.clusters)?,
            local_steps: get_usize("local_steps", d.local_steps)?,
            rounds: get_usize("rounds", d.rounds)?,
            batch_size: get_usize("batch_size", d.batch_size)?,
            lr: v.get("lr").and_then(Json::as_f64).unwrap_or(d.lr),
            optimizer: v
                .get("optimizer")
                .and_then(Json::as_str)
                .unwrap_or(&d.optimizer)
                .to_string(),
            model: v.get("model").and_then(Json::as_str).unwrap_or(&d.model).to_string(),
            samples_per_client: get_usize("samples_per_client", d.samples_per_client)?,
            test_samples: get_usize("test_samples", d.test_samples)?,
            eval_every: get_usize("eval_every", d.eval_every)?,
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(d.seed),
            // Legacy configs carried a boolean `parallel_clients`; map
            // `true` to "all cores" when no explicit count is given.
            workers: match v.get("workers") {
                Some(_) => get_usize("workers", d.workers)?,
                None => match v.get("parallel_clients").and_then(Json::as_bool) {
                    Some(true) => 0,
                    _ => d.workers,
                },
            },
            dropout: v.get("dropout").and_then(Json::as_f64).unwrap_or(d.dropout),
            deadline_s: v
                .get("deadline_s")
                .and_then(Json::as_f64)
                .unwrap_or(d.deadline_s),
            straggler_policy: match v.get("straggler_policy").and_then(Json::as_str)
            {
                Some(s) => StragglerPolicy::parse(s)?,
                None => d.straggler_policy,
            },
            engine: match v.get("engine").and_then(Json::as_str) {
                Some(s) => EngineKind::parse(s)?,
                None => d.engine,
            },
            codec: {
                let codec = match v.get("codec").and_then(Json::as_str) {
                    Some(s) => Codec::parse(s)?,
                    None => d.codec,
                };
                match (codec, v.get("codec_keep_hex").and_then(Json::as_str)) {
                    (Codec::TopK { .. }, Some(hex)) => {
                        let keep_fraction = f64_from_hex(hex)?;
                        if !(0.0 < keep_fraction && keep_fraction <= 1.0) {
                            return Err(Error::Config(format!(
                                "codec_keep_hex decodes to {keep_fraction}, \
                                 outside (0, 1]"
                            )));
                        }
                        Codec::TopK { keep_fraction }
                    }
                    (c, _) => c,
                }
            },
            plateau_rounds: get_usize("plateau_rounds", d.plateau_rounds)?,
            plateau_min_delta: v
                .get("plateau_min_delta")
                .and_then(Json::as_f64)
                .unwrap_or(d.plateau_min_delta),
            trace: v.get("trace").and_then(Json::as_str).unwrap_or(&d.trace).to_string(),
            trace_level: v
                .get("trace_level")
                .and_then(Json::as_str)
                .unwrap_or(&d.trace_level)
                .to_string(),
        };
        cfg.validate()
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Every JSON key [`ExperimentConfig::from_json`] accepts: the 27 field
/// keys plus the `codec_keep_hex` bit-exact side channel and the legacy
/// `parallel_clients` alias.  `from_json` itself ignores unknown keys
/// (old checkpoints may carry retired fields); surfaces that take a
/// config *delta* — where a typo would silently no-op — validate against
/// this list instead (see [`apply_json_delta`]).
pub const CONFIG_JSON_KEYS: [&str; 29] = [
    "name",
    "algorithm",
    "dataset",
    "distribution",
    "topology",
    "clients",
    "clusters",
    "local_steps",
    "rounds",
    "batch_size",
    "lr",
    "optimizer",
    "model",
    "samples_per_client",
    "test_samples",
    "eval_every",
    "seed",
    "workers",
    "dropout",
    "deadline_s",
    "straggler_policy",
    "engine",
    "codec",
    "codec_keep_hex",
    "plateau_rounds",
    "plateau_min_delta",
    "trace",
    "trace_level",
    "parallel_clients",
];

/// Apply a JSON config *delta* onto a base config: the delta's entries
/// overwrite the base's serialized form and the merged object re-parses
/// through [`ExperimentConfig::from_json`], so a delta accepts exactly
/// the file parser's vocabulary and runs the same validation.  Unlike
/// whole-file parsing, unknown delta keys are typed errors — a sweep
/// axis that misspells a knob must not silently test the base config.
pub fn apply_json_delta(
    base: &ExperimentConfig,
    delta: &Json,
) -> Result<ExperimentConfig> {
    let entries = match delta {
        Json::Obj(m) => m,
        other => {
            return Err(Error::Config(format!(
                "config delta must be a JSON object, got {}",
                other.dump()
            )))
        }
    };
    let mut merged = match base.to_json() {
        Json::Obj(m) => m,
        _ => return Err(Error::Config("config did not serialize to an object".into())),
    };
    // A delta that re-picks the codec by name must not inherit the base's
    // bit-exact keep-fraction side channel (stale hex would override the
    // freshly named fraction in from_json).
    if entries.contains_key("codec") && !entries.contains_key("codec_keep_hex") {
        merged.remove("codec_keep_hex");
    }
    for (k, v) in entries {
        if !CONFIG_JSON_KEYS.contains(&k.as_str()) {
            return Err(Error::Config(format!(
                "unknown config field {k:?} in delta (known fields: {})",
                CONFIG_JSON_KEYS.join(", ")
            )));
        }
        merged.insert(k.clone(), v.clone());
    }
    ExperimentConfig::from_json(&Json::Obj(merged))
}

/// Named presets matching the paper's experiments (CPU-scaled rounds).
pub fn preset(name: &str) -> Result<ExperimentConfig> {
    let base = ExperimentConfig::default();
    let cfg = match name {
        // Table I cells (paper: N=100, M=10, K=5, B=64, Adam)
        "table1_fashion_iid" => ExperimentConfig {
            name: name.into(),
            dataset: DatasetKind::SynthFashion,
            distribution: Distribution::Iid,
            model: "fashion_mlp".into(),
            ..base
        },
        "table1_fashion_niid_a" => ExperimentConfig {
            name: name.into(),
            dataset: DatasetKind::SynthFashion,
            distribution: Distribution::NiidA,
            model: "fashion_mlp".into(),
            ..base
        },
        "table1_cifar_iid" => ExperimentConfig {
            name: name.into(),
            dataset: DatasetKind::SynthCifar,
            distribution: Distribution::Iid,
            model: "cifar_mlp".into(),
            ..base
        },
        "table1_cifar_niid_a" => ExperimentConfig {
            name: name.into(),
            dataset: DatasetKind::SynthCifar,
            distribution: Distribution::NiidA,
            model: "cifar_mlp".into(),
            ..base
        },
        "table1_cifar_niid_b" => ExperimentConfig {
            name: name.into(),
            dataset: DatasetKind::SynthCifar,
            distribution: Distribution::NiidB,
            model: "cifar_mlp".into(),
            ..base
        },
        // Fig 3 base: CIFAR NIID B
        "fig3_base" => ExperimentConfig {
            name: name.into(),
            algorithm: Algorithm::EdgeFlowSeq,
            dataset: DatasetKind::SynthCifar,
            distribution: Distribution::NiidB,
            model: "cifar_mlp".into(),
            ..base
        },
        // Fig 4: communication study (model irrelevant; uses param counts)
        "fig4_comm" => ExperimentConfig {
            name: name.into(),
            rounds: 100,
            ..base
        },
        // Paper-faithful 6-layer CNN run (im2col conv lowering — the fast
        // CPU variant; see EXPERIMENTS.md §Perf).
        "e2e_cnn" => ExperimentConfig {
            name: name.into(),
            dataset: DatasetKind::SynthFashion,
            distribution: Distribution::NiidA,
            model: "fashion_cnn_slim_fast".into(),
            rounds: 20,
            eval_every: 2,
            ..base
        },
        other => {
            return Err(Error::Config(format!(
                "unknown preset {other:?} (see `edgeflow presets`)"
            )))
        }
    };
    cfg.validate()
}

/// All preset names, for CLI listing.
pub const PRESETS: [&str; 8] = [
    "table1_fashion_iid",
    "table1_fashion_niid_a",
    "table1_cifar_iid",
    "table1_cifar_niid_a",
    "table1_cifar_niid_b",
    "fig3_base",
    "fig4_comm",
    "e2e_cnn",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = preset("table1_cifar_niid_b").unwrap();
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.algorithm, cfg.algorithm);
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.distribution, cfg.distribution);
        assert_eq!(back.clients, cfg.clients);
        assert_eq!(back.lr, cfg.lr);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = ExperimentConfig::default();
        c.clusters = 7; // 100 % 7 != 0
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.optimizer = "rmsprop".into();
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.samples_per_client = 10; // < batch
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default();
        c.lr = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn all_presets_parse() {
        for p in PRESETS {
            preset(p).unwrap_or_else(|e| panic!("preset {p}: {e}"));
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn workers_roundtrip_and_legacy_alias() {
        let cfg = ExperimentConfig { workers: 4, ..ExperimentConfig::default() };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.workers, 4);
        // Legacy boolean maps true -> all cores (0), false/absent -> 1.
        let legacy = Json::parse(r#"{"parallel_clients": true}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&legacy).unwrap().workers, 0);
        let legacy = Json::parse(r#"{"parallel_clients": false}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&legacy).unwrap().workers, 1);
    }

    #[test]
    fn deadline_roundtrips_and_validates() {
        let cfg =
            ExperimentConfig { deadline_s: 2.5, ..ExperimentConfig::default() };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.deadline_s, 2.5);
        // absent field keeps the no-deadline default
        let none = Json::parse("{}").unwrap();
        assert_eq!(ExperimentConfig::from_json(&none).unwrap().deadline_s, 0.0);
        let mut c = ExperimentConfig::default();
        c.deadline_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.deadline_s = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn straggler_policy_parses_and_roundtrips() {
        assert_eq!(StragglerPolicy::parse("drop").unwrap(), StragglerPolicy::Drop);
        assert_eq!(
            StragglerPolicy::parse("defer").unwrap(),
            StragglerPolicy::Defer
        );
        assert!(StragglerPolicy::parse("hold").is_err());
        let cfg = ExperimentConfig {
            straggler_policy: StragglerPolicy::Defer,
            ..ExperimentConfig::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.straggler_policy, StragglerPolicy::Defer);
        // absent field keeps the drop default
        let none = Json::parse("{}").unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&none).unwrap().straggler_policy,
            StragglerPolicy::Drop
        );
    }

    #[test]
    fn engine_and_codec_roundtrip() {
        let cfg = ExperimentConfig {
            engine: EngineKind::Native,
            codec: Codec::QuantizeInt8,
            optimizer: "momentum".into(),
            model: "fashion_mlp".into(),
            ..ExperimentConfig::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.engine, EngineKind::Native);
        assert_eq!(back.codec, Codec::QuantizeInt8);
        assert_eq!(back.optimizer, "momentum");
        // absent fields keep the XLA / uncompressed defaults
        let none = Json::parse("{}").unwrap();
        let d = ExperimentConfig::from_json(&none).unwrap();
        assert_eq!(d.engine, EngineKind::Xla);
        assert_eq!(d.codec, Codec::None);
        // top-k codec names survive the round-trip too
        let cfg = ExperimentConfig {
            codec: Codec::TopK { keep_fraction: 0.1 },
            ..ExperimentConfig::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.codec, Codec::TopK { keep_fraction: 0.1 });
        // ... bit-exactly, even for fractions whose decimal percent form
        // is lossy (the codec_keep_hex side channel): resume must not
        // perturb wire accounting by 1 ulp of keep_fraction.
        let kf = 1.0 / 3.0;
        let cfg = ExperimentConfig {
            codec: Codec::TopK { keep_fraction: kf },
            ..ExperimentConfig::default()
        };
        match ExperimentConfig::from_json(&cfg.to_json()).unwrap().codec {
            Codec::TopK { keep_fraction } => {
                assert_eq!(keep_fraction.to_bits(), kf.to_bits())
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        // a corrupt hex value is a typed error, not a silent fallback
        let bad = Json::parse(
            r#"{"codec": "top10", "codec_keep_hex": "7ff8000000000000"}"#,
        )
        .unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err(), "NaN keep fraction");
        assert!(EngineKind::parse("tpu").is_err());
        assert_eq!(EngineKind::parse("native").unwrap(), EngineKind::Native);
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
    }

    #[test]
    fn delta_merge_overrides_and_rejects_unknown_keys() {
        let base = ExperimentConfig::default();
        let delta =
            Json::parse(r#"{"algorithm": "hierfl", "rounds": 3}"#).unwrap();
        let cfg = apply_json_delta(&base, &delta).unwrap();
        assert_eq!(cfg.algorithm, Algorithm::HierFl);
        assert_eq!(cfg.rounds, 3);
        // untouched fields keep the base's values
        assert_eq!(cfg.clients, base.clients);
        assert_eq!(cfg.lr, base.lr);
        // unknown keys are typed errors, not silent no-ops
        let typo = Json::parse(r#"{"algorithrm": "hierfl"}"#).unwrap();
        let err = apply_json_delta(&base, &typo).unwrap_err();
        assert!(err.to_string().contains("algorithrm"), "{err}");
        // a non-object delta is rejected too
        assert!(apply_json_delta(&base, &Json::parse("[1]").unwrap()).is_err());
        // merged config still runs full validation
        let bad = Json::parse(r#"{"clusters": 7}"#).unwrap();
        assert!(apply_json_delta(&base, &bad).is_err(), "100 % 7 != 0");
    }

    #[test]
    fn delta_codec_rename_drops_stale_keep_hex() {
        // Base serializes a TopK keep-fraction side channel; a delta that
        // re-picks the codec by name must not inherit those stale bits.
        let base = ExperimentConfig {
            codec: Codec::TopK { keep_fraction: 1.0 / 3.0 },
            ..ExperimentConfig::default()
        };
        let delta = Json::parse(r#"{"codec": "top10"}"#).unwrap();
        let cfg = apply_json_delta(&base, &delta).unwrap();
        assert_eq!(cfg.codec, Codec::TopK { keep_fraction: 0.1 });
        // ... while an untouched codec still round-trips bit-exactly
        let same = apply_json_delta(&base, &Json::parse("{}").unwrap()).unwrap();
        match same.codec {
            Codec::TopK { keep_fraction } => {
                assert_eq!(keep_fraction.to_bits(), (1.0f64 / 3.0).to_bits())
            }
            other => panic!("expected TopK, got {other:?}"),
        }
    }

    #[test]
    fn config_json_keys_cover_the_roundtrip_surface() {
        // Every key to_json emits must be in the whitelist — otherwise a
        // delta could not override a field the file format round-trips.
        let cfg = ExperimentConfig {
            codec: Codec::TopK { keep_fraction: 0.1 },
            ..ExperimentConfig::default()
        };
        match cfg.to_json() {
            Json::Obj(m) => {
                for k in m.keys() {
                    assert!(
                        CONFIG_JSON_KEYS.contains(&k.as_str()),
                        "to_json key {k:?} missing from CONFIG_JSON_KEYS"
                    );
                }
            }
            other => panic!("expected object, got {other:?}"),
        }
    }

    #[test]
    fn trace_fields_roundtrip_and_validate() {
        let cfg = ExperimentConfig {
            trace: "out/run.trace.jsonl".into(),
            trace_level: "phase".into(),
            ..ExperimentConfig::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.trace, "out/run.trace.jsonl");
        assert_eq!(back.trace_level, "phase");
        // absent fields keep tracing off at the default verbosity
        let none = Json::parse("{}").unwrap();
        let d = ExperimentConfig::from_json(&none).unwrap();
        assert_eq!(d.trace, "");
        assert_eq!(d.trace_level, "full");
        // a bogus level is a typed error
        let mut c = ExperimentConfig::default();
        c.trace_level = "verbose".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn distribution_parsing() {
        assert_eq!(Distribution::parse("iid").unwrap(), Distribution::Iid);
        assert_eq!(
            Distribution::parse("noniid95").unwrap(),
            Distribution::NonIid { major_fraction: 0.95 }
        );
        assert!(Distribution::parse("noniid150").is_err());
        assert!(Distribution::parse("bogus").is_err());
    }

    #[test]
    fn algorithm_and_topology_parse_all() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        for t in TopologyKind::ALL {
            assert_eq!(TopologyKind::parse(t.name()).unwrap(), t);
        }
    }
}
