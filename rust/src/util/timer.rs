//! Scoped wall-clock timing.

use std::time::{Duration, Instant};

/// Accumulating timer with named laps — used by the runner to attribute
/// round time to train / aggregate / eval / comm phases.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
    laps: Vec<(String, Duration)>,
    last: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Timer {
        // lint:allow(transitive-wall-clock): phase timing is wall-clock
        // observability by design and never feeds simulated time or
        // report bits; NetSim owns the simulated clock.
        let now = Instant::now();
        Timer { start: now, laps: Vec::new(), last: now }
    }

    /// Record time since the previous lap (or start) under `name`.
    pub fn lap(&mut self, name: &str) -> Duration {
        // lint:allow(transitive-wall-clock): same observability-only
        // policy as `new` — lap times decorate logs and traces, never
        // the deterministic outputs.
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        if let Some((_, acc)) = self.laps.iter_mut().find(|(n, _)| n == name) {
            *acc += d;
        } else {
            self.laps.push((name.to_string(), d));
        }
        d
    }

    /// Total elapsed since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    /// Accumulated duration for a named lap.
    pub fn get(&self, name: &str) -> Duration {
        self.laps
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    /// `(name, seconds)` pairs in first-seen order.
    pub fn laps(&self) -> Vec<(String, f64)> {
        self.laps.iter().map(|(n, d)| (n.clone(), d.as_secs_f64())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut t = Timer::new();
        std::thread::sleep(Duration::from_millis(2));
        t.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        t.lap("a");
        assert!(t.get("a") >= Duration::from_millis(4));
        assert_eq!(t.get("missing"), Duration::ZERO);
        assert_eq!(t.laps().len(), 1);
    }
}
