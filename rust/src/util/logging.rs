//! Stderr logger wired to the `log` facade.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; `verbose` enables debug level.
/// Honors `EDGEFLOW_LOG` (error|warn|info|debug|trace) when set.
pub fn init(verbose: bool) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("EDGEFLOW_LOG").ok().as_deref() {
        Some("error") => LevelFilter::Error,
        Some("warn") => LevelFilter::Warn,
        Some("info") => LevelFilter::Info,
        Some("debug") => LevelFilter::Debug,
        Some("trace") => LevelFilter::Trace,
        _ if verbose => LevelFilter::Debug,
        _ => LevelFilter::Info,
    };
    let _ = log::set_boxed_logger(Box::new(StderrLogger { start: Instant::now() }));
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init(false);
        super::init(true); // must not panic on double install
    }
}
