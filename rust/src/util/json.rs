//! Minimal, dependency-free JSON: full parser + writer.
//!
//! Used for the AOT `manifest.json`, experiment configs, and metrics
//! output.  Implements RFC 8259 minus some exotica we never produce
//! (surrogate-pair escapes are handled; duplicate keys take last-wins).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::error::{Error, Result};

/// A JSON value.  Objects use `BTreeMap` for deterministic ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- parse

    /// Parse a JSON document (must consume the full input).
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    /// Object field lookup; `None` for missing key or non-object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field lookup that errors with context on absence.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            // lint:allow(float-ordering): exact integer-representability
            // check — fract() is 0.0 precisely when f is an integer, no
            // tolerance wanted.
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed string field.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field {key:?} is not a string")))
    }

    /// Typed usize field.
    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("field {key:?} is not an integer")))
    }

    /// Typed f64 field.
    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("field {key:?} is not a number")))
    }

    // -------------------------------------------------------------- writers

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // lint:allow(float-ordering): exact integer-
                // representability check mirroring as_u64 — decides
                // integer vs decimal rendering, no tolerance wanted.
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.s[..self.i.min(self.s.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line} col {col}"))
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            // The writer renders a NaN f64 as the bare token `NaN`
            // (unevaluated metrics rounds carry NaN on purpose), so the
            // parser accepts it back — our emit/parse pair stays closed
            // even though RFC 8259 has no NaN literal.
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let cp = 0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(cp)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(hi)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced i past the escape
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let rest = &self.s[self.i..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&rest[..len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.s.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let txt = std::str::from_utf8(&self.s[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_field("c").unwrap(), "x");
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-3,"o":{"k":false}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.25).dump(), "5.25");
    }

    #[test]
    fn typed_field_errors() {
        let v = Json::parse(r#"{"a": "x"}"#).unwrap();
        assert!(v.usize_field("a").is_err());
        assert!(v.str_field("missing").is_err());
        assert_eq!(v.str_field("a").unwrap(), "x");
    }

    #[test]
    fn nan_round_trips_through_own_writer() {
        // The writer emits NaN as a bare token; the parser must take it
        // back so NaN-bearing metrics exports stay self-consistent.
        assert_eq!(Json::Num(f64::NAN).dump(), "NaN");
        let v = Json::parse("{\"a\": NaN}").unwrap();
        assert!(v.get("a").unwrap().as_f64().unwrap().is_nan());
        // NaN is a number, not an integer.
        assert!(v.get("a").unwrap().as_u64().is_none());
        // Near-miss literals still fail cleanly.
        assert!(Json::parse("Na").is_err());
        assert!(Json::parse("NaNaN").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo wörld ❤\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ❤");
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
