//! ASCII table pretty-printer, used to regenerate the paper's tables.

/// Column alignment.
#[derive(Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    aligns: Vec<Align>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            aligns: vec![Align::Right; header.len()],
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title(mut self, t: &str) -> Table {
        self.title = Some(t.to_string());
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len(), "table row width mismatch");
        self.rows.push(fields.to_vec());
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, f) in row.iter().enumerate() {
                widths[i] = widths[i].max(f.chars().count());
            }
        }
        let sep = |l: char, m: char, r: char| {
            let mut s = String::new();
            s.push(l);
            for (i, w) in widths.iter().enumerate() {
                s.push_str(&"─".repeat(w + 2));
                s.push(if i + 1 == ncols { r } else { m });
            }
            s.push('\n');
            s
        };
        let fmt_row = |fields: &[String]| {
            let mut s = String::from("│");
            for (i, f) in fields.iter().enumerate() {
                let pad = widths[i] - f.chars().count();
                match self.aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(f);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(f);
                        s.push(' ');
                    }
                }
                s.push('│');
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep('┌', '┬', '┐'));
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep('├', '┼', '┤'));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep('└', '┴', '┘'));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "acc"]).align(0, Align::Left);
        t.row(&["FedAvg".into(), "90.60".into()]);
        t.row(&["EdgeFLowSeq".into(), "90.53".into()]);
        let s = t.render();
        assert!(s.contains("FedAvg"));
        assert!(s.contains("90.53"));
        // all lines the same display width
        let lens: Vec<usize> =
            s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
