//! Tiny CSV writer for metrics output (RFC 4180 quoting).

use std::io::Write;
use std::path::Path;

use super::error::Result;

/// Buffered CSV writer.
pub struct CsvWriter {
    cols: usize,
    out: Vec<u8>,
}

impl CsvWriter {
    /// Start a document with a header row.
    pub fn new(header: &[&str]) -> CsvWriter {
        let mut w = CsvWriter { cols: header.len(), out: Vec::new() };
        w.push_row(header.iter().map(|s| s.to_string()));
        w
    }

    fn push_row<I: IntoIterator<Item = String>>(&mut self, row: I) {
        let fields: Vec<String> = row.into_iter().collect();
        debug_assert_eq!(fields.len(), self.cols, "csv row width mismatch");
        self.out.extend_from_slice(&Self::encode_row(&fields));
    }

    /// Append one row of stringified fields.
    pub fn row(&mut self, fields: &[String]) {
        self.push_row(fields.iter().cloned());
    }

    /// Convenience: append a row of f64s with compact formatting.
    pub fn row_f64(&mut self, fields: &[f64]) {
        self.push_row(fields.iter().map(|v| format!("{v}")));
    }

    /// Serialized document.
    pub fn as_bytes(&self) -> &[u8] {
        &self.out
    }

    /// Serialize a single row (no header) — the one implementation of
    /// field quoting and line ending, behind both the document form
    /// ([`CsvWriter::row`]) and incremental appends to a file whose
    /// header an earlier [`CsvWriter::save`] wrote.
    pub fn encode_row(fields: &[String]) -> Vec<u8> {
        let mut out = Vec::new();
        for (i, field) in fields.iter().enumerate() {
            if i > 0 {
                out.push(b',');
            }
            out.extend_from_slice(escape(field).as_bytes());
        }
        out.extend_from_slice(b"\r\n");
        out
    }

    /// Write to a file.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.out)?;
        Ok(())
    }
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut w = CsvWriter::new(&["round", "loss"]);
        w.row(&["1".into(), "2.5".into()]);
        let text = String::from_utf8(w.as_bytes().to_vec()).unwrap();
        assert_eq!(text, "round,loss\r\n1,2.5\r\n");
    }

    #[test]
    fn encode_row_matches_document_form() {
        // Header + encode_row appends must equal the batch document.
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "x,y".into()]);
        w.row(&["2".into(), "plain".into()]);
        let mut appended = CsvWriter::new(&["a", "b"]).as_bytes().to_vec();
        appended.extend(CsvWriter::encode_row(&["1".into(), "x,y".into()]));
        appended.extend(CsvWriter::encode_row(&["2".into(), "plain".into()]));
        assert_eq!(appended, w.as_bytes());
    }

    #[test]
    fn quotes_special_fields() {
        assert_eq!(escape("a,b"), "\"a,b\"");
        assert_eq!(escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(escape("plain"), "plain");
    }
}
