//! Crate-wide error type.

use thiserror::Error;

/// All errors surfaced by the edgeflow library.
#[derive(Error, Debug)]
pub enum Error {
    /// I/O failure (artifact loading, metrics output, ...).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON syntax or type mismatch while parsing manifests/configs.
    #[error("json error: {0}")]
    Json(String),

    /// Configuration validation failure.
    #[error("config error: {0}")]
    Config(String),

    /// PJRT / XLA runtime failure.
    #[error("xla error: {0}")]
    Xla(String),

    /// Artifact manifest inconsistency (missing file, shape mismatch...).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Topology / routing failure (disconnected node, bad id, ...).
    #[error("topology error: {0}")]
    Topology(String),

    /// Dataset / partitioning failure.
    #[error("data error: {0}")]
    Data(String),

    /// CLI usage error.
    #[error("usage error: {0}")]
    Usage(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
