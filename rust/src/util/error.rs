//! Crate-wide error type (hand-rolled Display/Error impls — proc-macro
//! derive crates are not vendored in this offline image).

use std::fmt;

/// All errors surfaced by the edgeflow library.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (artifact loading, metrics output, ...).
    Io(std::io::Error),

    /// JSON syntax or type mismatch while parsing manifests/configs.
    Json(String),

    /// Configuration validation failure.
    Config(String),

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// Artifact manifest inconsistency (missing file, shape mismatch...).
    Artifact(String),

    /// Topology / routing failure (disconnected node, bad id, ...).
    Topology(String),

    /// Dataset / partitioning failure.
    Data(String),

    /// CLI usage error.
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Topology(m) => write!(f, "topology error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(Error::Usage("u".into()).to_string(), "usage error: u");
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "gone").into();
        assert!(io.to_string().starts_with("io error: "));
        assert!(std::error::Error::source(&io).is_some());
    }
}
