//! Small shared substrates: errors, JSON, CSV, tables, logging, timing.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the serialization / formatting layers a
//! production framework would normally pull from crates.io live here.

pub mod csv;
pub mod error;
pub mod json;
pub mod logging;
pub mod table;
pub mod timer;

pub use error::{Error, Result};
pub use json::Json;
pub use timer::Timer;

/// Human-readable byte count (`12.3 MiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Human-readable duration (`1.23 s`, `45 ms`, `12 µs`).
pub fn human_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.2} s", d.as_secs_f64())
    } else if ns >= 1_000_000 {
        format!("{:.1} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation (0.0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by nearest-rank on a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 5.0);
    }
}
