//! Small shared substrates: errors, JSON, CSV, tables, logging, timing.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the serialization / formatting layers a
//! production framework would normally pull from crates.io live here.

pub mod csv;
pub mod error;
pub mod json;
pub mod logging;
pub mod table;
pub mod timer;

pub use error::{Error, Result};
pub use json::Json;
pub use timer::Timer;

/// Human-readable byte count (`12.3 MiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Human-readable duration (`1.23 s`, `45 ms`, `12 µs`).
pub fn human_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.2} s", d.as_secs_f64())
    } else if ns >= 1_000_000 {
        format!("{:.1} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Lowercase hex encoding (checkpoint blobs).
pub fn bytes_to_hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(DIGITS[(b >> 4) as usize] as char);
        s.push(DIGITS[(b & 0xf) as usize] as char);
    }
    s
}

/// Inverse of [`bytes_to_hex`].
pub fn bytes_from_hex(s: &str) -> Result<Vec<u8>> {
    if !s.is_ascii() {
        return Err(Error::Json("hex string holds non-ASCII bytes".into()));
    }
    if s.len() % 2 != 0 {
        return Err(Error::Json(format!("odd hex length {}", s.len())));
    }
    (0..s.len() / 2)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|_| Error::Json(format!("bad hex byte at {}", 2 * i)))
        })
        .collect()
}

/// Bit-exact f64 serialization for checkpoints: JSON numbers cannot carry
/// NaN and a decimal round-trip is one rounding bug away from breaking
/// the resume-is-bit-identical contract, so checkpoint floats travel as
/// the 16-hex-digit bit pattern instead.
pub fn f64_to_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Inverse of [`f64_to_hex`].
pub fn f64_from_hex(s: &str) -> Result<f64> {
    u64_from_hex(s).map(f64::from_bits)
}

/// u64 as hex (values above 2^53 would lose precision as JSON numbers).
pub fn u64_to_hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`u64_to_hex`].
pub fn u64_from_hex(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16)
        .map_err(|_| Error::Json(format!("bad u64 hex {s:?}")))
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation (0.0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by nearest-rank on a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hex_roundtrips_bytes_and_bits() {
        let blob = vec![0u8, 1, 0xab, 0xff, 0x10];
        assert_eq!(bytes_from_hex(&bytes_to_hex(&blob)).unwrap(), blob);
        assert!(bytes_from_hex("abc").is_err());
        assert!(bytes_from_hex("zz").is_err());
        for v in [0.0, -0.0, 1.5, f64::NAN, f64::INFINITY, -1e-300] {
            let back = f64_from_hex(&f64_to_hex(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
        for v in [0u64, 1, u64::MAX, 1 << 60] {
            assert_eq!(u64_from_hex(&u64_to_hex(v)).unwrap(), v);
        }
        assert!(u64_from_hex("not hex").is_err());
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 5.0);
    }
}
