//! Structured observability: dual-clock tracing and deterministic
//! metrics.
//!
//! A trace is a stream of events, each carrying *two* time axes:
//!
//! * the **wall clock** — nanoseconds since the tracer's epoch
//!   (`wall_ns`, `wall_dur_ns`), read only through
//!   [`wallclock`] (the one obs module the `wall-clock-in-sim` lint
//!   allowlists);
//! * the **simulated clock** — NetSim seconds (`sim_s`, `sim_dur_s`),
//!   present on events that live inside the simulation (DES
//!   transfers, link occupancy, round windows).
//!
//! Events are emitted through a pluggable [`TraceSink`].  The default
//! is no sink at all — a disabled [`Tracer`] is a `None` and every
//! instrumentation call returns immediately — and the shipping sink is
//! [`JsonlSink`]: schema-versioned JSONL, one event per line, written
//! through a buffered stream so a trace never holds the run in RAM.
//! `trace export --chrome` ([`chrome`]) converts a JSONL trace to the
//! Chrome trace-event format for Perfetto; `trace summarize`
//! ([`summary`]) rolls it up per phase and per link.
//!
//! **Determinism contract.**  The *logical* content of a trace —
//! event kinds, categories, names, attributes and every sim-clock
//! field — is bit-identical at any `--workers` count; only wall-clock
//! fields and worker-lane assignment are physical.  The
//! [`metrics::MetricsRegistry`] is deterministic outright.

pub mod chrome;
pub mod metrics;
pub mod summary;
pub mod wallclock;

pub use metrics::MetricsRegistry;
pub use wallclock::{PhaseTimer, WallMark};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

use wallclock::WallEpoch;

/// Trace schema version: the `"v"` field on every emitted line.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// How much detail a trace records.  Levels nest: each one includes
/// everything below it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// No events.
    Off,
    /// Round spans, checkpoint/cell spans, control events, metrics.
    Round,
    /// Plus per-phase spans (plan / comm / train / aggregate / eval).
    Phase,
    /// Plus per-client local-update spans and per-transfer DES spans.
    Full,
}

impl TraceLevel {
    pub fn parse(s: &str) -> Result<TraceLevel> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "round" => Ok(TraceLevel::Round),
            "phase" => Ok(TraceLevel::Phase),
            "full" => Ok(TraceLevel::Full),
            other => Err(Error::Config(format!(
                "unknown trace level {other:?} (use off | round | phase | full)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Round => "round",
            TraceLevel::Phase => "phase",
            TraceLevel::Full => "full",
        }
    }
}

/// Where emitted events go.  Implementations must be thread-safe:
/// worker-lane spans are emitted from the main thread in job order,
/// but campaign cells emit concurrently.
pub trait TraceSink: Send + Sync {
    /// Write one event line.  Sinks swallow I/O errors after logging
    /// them once — tracing must never abort a training run.
    fn emit(&self, line: &Json);
    fn flush(&self);
}

/// The shipping sink: one compact JSON object per line, streamed
/// through a buffer (flushed on [`Tracer::flush`] and drop).
pub struct JsonlSink {
    w: Mutex<std::io::BufWriter<std::fs::File>>,
    path: String,
    failed: AtomicBool,
}

impl JsonlSink {
    pub fn create(path: &str) -> Result<JsonlSink> {
        let f = std::fs::File::create(path)?;
        Ok(JsonlSink {
            w: Mutex::new(std::io::BufWriter::new(f)),
            path: path.to_string(),
            failed: AtomicBool::new(false),
        })
    }
}

impl TraceSink for JsonlSink {
    fn emit(&self, line: &Json) {
        use std::io::Write as _;
        if self.failed.load(Ordering::Relaxed) {
            return;
        }
        if let Ok(mut w) = self.w.lock() {
            if let Err(e) = writeln!(w, "{}", line.dump()) {
                log::warn!("trace sink {}: write failed ({e}); tracing disabled", self.path);
                self.failed.store(true, Ordering::Relaxed);
            }
        }
    }

    fn flush(&self) {
        use std::io::Write as _;
        if let Ok(mut w) = self.w.lock() {
            if let Err(e) = w.flush() {
                log::warn!("trace sink {}: flush failed ({e})", self.path);
            }
        }
    }
}

struct Inner {
    level: TraceLevel,
    epoch: WallEpoch,
    sink: Box<dyn TraceSink>,
}

/// Cheap-clone tracing handle.  A disabled tracer carries no
/// allocation and every method on it is a branch on `None` — the
/// instrumented hot paths pay nothing when tracing is off.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<Inner>>,
}

impl Tracer {
    /// The disabled tracer.
    pub fn off() -> Tracer {
        Tracer { inner: None }
    }

    /// A tracer writing JSONL to `path` at `level`; emits the header
    /// line immediately.  `level == Off` yields the disabled tracer
    /// (no file is created).
    pub fn jsonl(path: &str, level: TraceLevel, run: &str) -> Result<Tracer> {
        if level == TraceLevel::Off {
            return Ok(Tracer::off());
        }
        let sink = JsonlSink::create(path)?;
        Ok(Tracer::with_sink(Box::new(sink), level, run))
    }

    /// A tracer over any sink (tests use an in-memory sink).  Emits
    /// the header line.
    pub fn with_sink(sink: Box<dyn TraceSink>, level: TraceLevel, run: &str) -> Tracer {
        let t = Tracer {
            inner: Some(Arc::new(Inner { level, epoch: WallEpoch::now(), sink })),
        };
        if let Some(inner) = &t.inner {
            inner.sink.emit(&Json::obj(vec![
                ("v", TRACE_SCHEMA_VERSION.into()),
                ("ev", "header".into()),
                ("format", "edgeflow-trace".into()),
                ("level", level.as_str().into()),
                ("run", run.into()),
            ]));
        }
        t
    }

    /// Build a tracer from config fields: empty `path` (or level
    /// `off`) disables.
    pub fn from_config(path: &str, level: &str, run: &str) -> Result<Tracer> {
        if path.is_empty() {
            return Ok(Tracer::off());
        }
        Tracer::jsonl(path, TraceLevel::parse(level)?, run)
    }

    /// Whether events at `level` are recorded.
    pub fn enabled(&self, level: TraceLevel) -> bool {
        match &self.inner {
            Some(i) => level != TraceLevel::Off && level <= i.level,
            None => false,
        }
    }

    pub fn level(&self) -> TraceLevel {
        self.inner.as_ref().map(|i| i.level).unwrap_or(TraceLevel::Off)
    }

    /// Take a wall mark if events at `level` are recorded (so the
    /// clock is never read for spans that would be dropped).
    pub fn mark_if(&self, level: TraceLevel) -> Option<WallMark> {
        if self.enabled(level) {
            Some(WallMark::now())
        } else {
            None
        }
    }

    /// Wall offset of "now" in trace time (0 when disabled).
    pub fn rel_now_ns(&self) -> u64 {
        match &self.inner {
            Some(i) => i.epoch.rel_ns(WallMark::now()),
            None => 0,
        }
    }

    /// Emit a span opened at `start` and closing now.  `sim` is the
    /// optional simulated-clock window `(start_s, dur_s)`.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &self,
        level: TraceLevel,
        cat: &str,
        name: &str,
        lane: &str,
        start: Option<WallMark>,
        sim: Option<(f64, f64)>,
        attrs: Vec<(&str, Json)>,
    ) {
        let Some(inner) = &self.inner else { return };
        if !self.enabled(level) {
            return;
        }
        let (wall_ns, wall_dur_ns) = match start {
            Some(m) => inner.epoch.span_ns(m),
            None => (inner.epoch.rel_ns(WallMark::now()), 0),
        };
        self.emit_span(cat, name, lane, wall_ns, wall_dur_ns, sim, attrs);
    }

    /// Emit a span with explicit wall-clock placement (the phase
    /// timer's tiled lanes; DES spans whose wall time is just the
    /// emission point).
    #[allow(clippy::too_many_arguments)]
    pub fn span_at(
        &self,
        level: TraceLevel,
        cat: &str,
        name: &str,
        lane: &str,
        wall_ns: u64,
        wall_dur_ns: u64,
        sim: Option<(f64, f64)>,
        attrs: Vec<(&str, Json)>,
    ) {
        if !self.enabled(level) {
            return;
        }
        self.emit_span(cat, name, lane, wall_ns, wall_dur_ns, sim, attrs);
    }

    #[allow(clippy::too_many_arguments)]
    fn emit_span(
        &self,
        cat: &str,
        name: &str,
        lane: &str,
        wall_ns: u64,
        wall_dur_ns: u64,
        sim: Option<(f64, f64)>,
        attrs: Vec<(&str, Json)>,
    ) {
        let Some(inner) = &self.inner else { return };
        let mut pairs = vec![
            ("v", TRACE_SCHEMA_VERSION.into()),
            ("ev", "span".into()),
            ("cat", cat.into()),
            ("name", name.into()),
            ("lane", lane.into()),
            ("wall_ns", wall_ns.into()),
            ("wall_dur_ns", wall_dur_ns.into()),
        ];
        if let Some((s, d)) = sim {
            pairs.push(("sim_s", Json::Num(s)));
            pairs.push(("sim_dur_s", Json::Num(d)));
        }
        pairs.push(("attrs", Json::obj(attrs)));
        inner.sink.emit(&Json::obj(pairs));
    }

    /// Emit a point event (no duration).
    pub fn instant(
        &self,
        level: TraceLevel,
        cat: &str,
        name: &str,
        lane: &str,
        sim_s: Option<f64>,
        attrs: Vec<(&str, Json)>,
    ) {
        let Some(inner) = &self.inner else { return };
        if !self.enabled(level) {
            return;
        }
        let mut pairs = vec![
            ("v", TRACE_SCHEMA_VERSION.into()),
            ("ev", "instant".into()),
            ("cat", cat.into()),
            ("name", name.into()),
            ("lane", lane.into()),
            ("wall_ns", inner.epoch.rel_ns(WallMark::now()).into()),
        ];
        if let Some(s) = sim_s {
            pairs.push(("sim_s", Json::Num(s)));
        }
        pairs.push(("attrs", Json::obj(attrs)));
        inner.sink.emit(&Json::obj(pairs));
    }

    /// Emit the registry snapshot as one `metrics` event.
    pub fn metrics(&self, reg: &MetricsRegistry) {
        let Some(inner) = &self.inner else { return };
        inner.sink.emit(&Json::obj(vec![
            ("v", TRACE_SCHEMA_VERSION.into()),
            ("ev", "metrics".into()),
            ("registry", reg.to_json()),
        ]));
    }

    pub fn flush(&self) {
        if let Some(inner) = &self.inner {
            inner.sink.flush();
        }
    }
}

/// Validate one parsed trace line against schema v1.  Used by `trace
/// summarize` (every line is validated as it streams past) and the
/// schema tests.
pub fn validate_event(j: &Json) -> Result<()> {
    let bad = |m: String| Err(Error::Json(m));
    match j.get("v").and_then(Json::as_u64) {
        Some(TRACE_SCHEMA_VERSION) => {}
        other => return bad(format!("trace event version {other:?} != {TRACE_SCHEMA_VERSION}")),
    }
    let ev = j.str_field("ev")?;
    match ev {
        "header" => {
            if j.str_field("format")? != "edgeflow-trace" {
                return bad("header format is not edgeflow-trace".into());
            }
            TraceLevel::parse(j.str_field("level")?)?;
            j.str_field("run")?;
        }
        "span" | "instant" => {
            j.str_field("cat")?;
            j.str_field("name")?;
            j.str_field("lane")?;
            j.req("wall_ns")?
                .as_u64()
                .ok_or_else(|| Error::Json("wall_ns is not an integer".into()))?;
            if ev == "span" {
                j.req("wall_dur_ns")?
                    .as_u64()
                    .ok_or_else(|| Error::Json("wall_dur_ns is not an integer".into()))?;
            }
            // The sim clock is optional, but a span carrying one half
            // of the window must carry the other.
            let has_sim = j.get("sim_s").is_some();
            let has_sim_dur = j.get("sim_dur_s").is_some();
            if j.get("sim_s").map(|v| v.as_f64().is_none()).unwrap_or(false) {
                return bad("sim_s is not a number".into());
            }
            if j.get("sim_dur_s").map(|v| v.as_f64().is_none()).unwrap_or(false) {
                return bad("sim_dur_s is not a number".into());
            }
            if ev == "span" && has_sim != has_sim_dur {
                return bad("span carries sim_s xor sim_dur_s".into());
            }
            if ev == "instant" && has_sim_dur {
                return bad("instant events carry no sim_dur_s".into());
            }
            if j.req("attrs")?.as_obj().is_none() {
                return bad("attrs is not an object".into());
            }
        }
        "metrics" => {
            let reg = j.req("registry")?;
            for part in ["counters", "gauges", "histograms"] {
                if reg.req(part)?.as_obj().is_none() {
                    return bad(format!("metrics registry {part} is not an object"));
                }
            }
        }
        other => return bad(format!("unknown trace event kind {other:?}")),
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod test_sink {
    use super::*;

    /// In-memory sink for unit tests.
    #[derive(Default)]
    pub struct MemSink {
        pub lines: Mutex<Vec<Json>>,
    }

    impl TraceSink for Arc<MemSink> {
        fn emit(&self, line: &Json) {
            self.lines.lock().unwrap().push(line.clone());
        }
        fn flush(&self) {}
    }
}

#[cfg(test)]
mod tests {
    use super::test_sink::MemSink;
    use super::*;

    fn mem_tracer(level: TraceLevel) -> (Tracer, Arc<MemSink>) {
        let sink = Arc::new(MemSink::default());
        let t = Tracer::with_sink(Box::new(sink.clone()), level, "test");
        (t, sink)
    }

    #[test]
    fn levels_nest_and_parse() {
        assert!(TraceLevel::Round < TraceLevel::Phase);
        assert!(TraceLevel::Phase < TraceLevel::Full);
        for s in ["off", "round", "phase", "full"] {
            assert_eq!(TraceLevel::parse(s).unwrap().as_str(), s);
        }
        assert!(TraceLevel::parse("verbose").is_err());
    }

    #[test]
    fn disabled_tracer_emits_nothing_and_costs_no_marks() {
        let t = Tracer::off();
        assert!(!t.enabled(TraceLevel::Round));
        assert_eq!(t.level(), TraceLevel::Off);
        assert!(t.mark_if(TraceLevel::Full).is_none());
        t.span(TraceLevel::Round, "round", "round", "main", None, None, vec![]);
        t.instant(TraceLevel::Round, "c", "n", "main", None, vec![]);
        t.flush();
    }

    #[test]
    fn level_gating_drops_finer_events() {
        let (t, sink) = mem_tracer(TraceLevel::Phase);
        assert!(t.enabled(TraceLevel::Round));
        assert!(t.enabled(TraceLevel::Phase));
        assert!(!t.enabled(TraceLevel::Full));
        t.span(TraceLevel::Round, "round", "round", "main", None, None, vec![]);
        t.span(TraceLevel::Full, "client", "local_update", "worker0", None, None, vec![]);
        let lines = sink.lines.lock().unwrap();
        // header + the round span; the Full-level span was dropped.
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].str_field("ev").unwrap(), "header");
        assert_eq!(lines[1].str_field("cat").unwrap(), "round");
    }

    #[test]
    fn emitted_events_validate() {
        let (t, sink) = mem_tracer(TraceLevel::Full);
        let m = t.mark_if(TraceLevel::Full);
        t.span(
            TraceLevel::Full,
            "net",
            "upload",
            "route:1->2",
            m,
            Some((3.5, 0.25)),
            vec![("bytes", 100usize.into())],
        );
        t.instant(TraceLevel::Round, "control", "deadline.set", "main", Some(1.0), vec![]);
        let mut reg = MetricsRegistry::new();
        reg.inc("rounds", 2);
        t.metrics(&reg);
        let lines = sink.lines.lock().unwrap();
        assert_eq!(lines.len(), 4);
        for l in lines.iter() {
            validate_event(l).unwrap_or_else(|e| panic!("{e}: {}", l.dump()));
        }
    }

    #[test]
    fn validation_rejects_malformed_events() {
        let bad = [
            r#"{"ev":"span"}"#,
            r#"{"v":1,"ev":"mystery"}"#,
            r#"{"v":2,"ev":"instant"}"#,
            r#"{"v":1,"ev":"span","cat":"c","name":"n","lane":"l","wall_ns":0,"wall_dur_ns":0,"sim_s":1.0,"attrs":{}}"#,
            r#"{"v":1,"ev":"span","cat":"c","name":"n","lane":"l","wall_ns":0,"attrs":{}}"#,
            r#"{"v":1,"ev":"instant","cat":"c","name":"n","lane":"l","wall_ns":0,"attrs":[]}"#,
        ];
        for src in bad {
            let j = Json::parse(src).unwrap();
            assert!(validate_event(&j).is_err(), "{src}");
        }
    }

    #[test]
    fn jsonl_sink_writes_one_line_per_event() {
        let path = std::env::temp_dir().join("edgeflow_obs_jsonl_sink_test.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        let t = Tracer::jsonl(&path_s, TraceLevel::Full, "demo").unwrap();
        t.span(TraceLevel::Round, "round", "round", "main", None, None, vec![("round", 0usize.into())]);
        t.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in &lines {
            validate_event(&Json::parse(l).unwrap()).unwrap();
        }
        assert!(lines[0].contains("\"run\":\"demo\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn off_level_jsonl_creates_no_file() {
        let path = std::env::temp_dir().join("edgeflow_obs_no_file_test.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let t = Tracer::jsonl(&path_s, TraceLevel::Off, "demo").unwrap();
        assert!(!t.enabled(TraceLevel::Round));
        assert!(!path.exists());
        let t2 = Tracer::from_config("", "full", "demo").unwrap();
        assert!(!t2.enabled(TraceLevel::Round));
    }
}
