//! Chrome trace-event export: convert a JSONL trace into the JSON
//! format Perfetto / `chrome://tracing` load directly.
//!
//! The dual clocks become two trace "processes": pid 1 renders the
//! wall clock (one lane per thread: `main`, `worker0`...), pid 2 the
//! simulated clock (one lane per link / route).  Spans become `"X"`
//! complete events with microsecond `ts`/`dur`; instants become `"i"`
//! events.  Events are sorted by `(pid, tid, ts)` so every lane's
//! timestamps are monotone — the property the viewer (and the test
//! suite) relies on.

use std::collections::BTreeMap;
use std::io::{BufRead as _, Write as _};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// One pre-sorted Chrome event with its ordering key.
struct ChromeEvent {
    pid: u64,
    tid: u64,
    ts_us: f64,
    json: Json,
}

const WALL_PID: u64 = 1;
const SIM_PID: u64 = 2;

/// Convert the JSONL trace at `input` into a Chrome trace-event file
/// at `output`.  Returns the number of exported events (metadata
/// records excluded).
pub fn export_chrome(input: &str, output: &str) -> Result<usize> {
    let f = std::fs::File::open(input)
        .map_err(|e| Error::Io(std::io::Error::new(e.kind(), format!("{input}: {e}"))))?;
    let reader = std::io::BufReader::new(f);

    // Lane registry: (pid, lane name) -> tid, in first-seen order.
    let mut lanes: BTreeMap<(u64, String), u64> = BTreeMap::new();
    let mut next_tid: u64 = 1;
    let mut events: Vec<ChromeEvent> = Vec::new();

    let mut lane_tid = |lanes: &mut BTreeMap<(u64, String), u64>, pid: u64, lane: &str| -> u64 {
        if let Some(t) = lanes.get(&(pid, lane.to_string())) {
            return *t;
        }
        let t = next_tid;
        next_tid += 1;
        lanes.insert((pid, lane.to_string()), t);
        t
    };

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| Error::Json(format!("{input} line {}: {e}", lineno + 1)))?;
        super::validate_event(&j)
            .map_err(|e| Error::Json(format!("{input} line {}: {e}", lineno + 1)))?;
        let ev = j.str_field("ev")?;
        if ev == "header" || ev == "metrics" {
            continue;
        }
        let cat = j.str_field("cat")?.to_string();
        let name = j.str_field("name")?.to_string();
        let lane = j.str_field("lane")?.to_string();
        let args = j.get("attrs").cloned().unwrap_or_else(|| Json::obj(vec![]));
        let wall_ns = j.req("wall_ns")?.as_f64().unwrap_or(0.0);
        let sim = j.get("sim_s").and_then(Json::as_f64);
        match ev {
            "span" => {
                let dur_ns = j.req("wall_dur_ns")?.as_f64().unwrap_or(0.0);
                // Wall-axis rendering for every span.
                let tid = lane_tid(&mut lanes, WALL_PID, &lane);
                events.push(complete(
                    WALL_PID,
                    tid,
                    wall_ns / 1e3,
                    dur_ns / 1e3,
                    &cat,
                    &name,
                    &args,
                ));
                // Sim-axis rendering for spans inside the simulation.
                if let (Some(s), Some(d)) =
                    (sim, j.get("sim_dur_s").and_then(Json::as_f64))
                {
                    let tid = lane_tid(&mut lanes, SIM_PID, &lane);
                    events.push(complete(SIM_PID, tid, s * 1e6, d * 1e6, &cat, &name, &args));
                }
            }
            "instant" => {
                let tid = lane_tid(&mut lanes, WALL_PID, &lane);
                events.push(point(WALL_PID, tid, wall_ns / 1e3, &cat, &name, &args));
                if let Some(s) = sim {
                    let tid = lane_tid(&mut lanes, SIM_PID, &lane);
                    events.push(point(SIM_PID, tid, s * 1e6, &cat, &name, &args));
                }
            }
            _ => {}
        }
    }

    let exported = events.len();
    // Monotone ts per lane: total_cmp keeps the sort total even if a
    // poisoned trace smuggled a NaN timestamp in.
    events.sort_by(|a, b| {
        a.pid
            .cmp(&b.pid)
            .then(a.tid.cmp(&b.tid))
            .then(a.ts_us.total_cmp(&b.ts_us))
    });

    let mut all: Vec<Json> = Vec::new();
    for (pid, label) in [(WALL_PID, "wall clock"), (SIM_PID, "sim clock")] {
        all.push(metadata("process_name", pid, 0, label));
    }
    for ((pid, lane), tid) in &lanes {
        all.push(metadata("thread_name", *pid, *tid, lane));
    }
    all.extend(events.into_iter().map(|e| e.json));

    let doc = Json::obj(vec![
        ("traceEvents", Json::Arr(all)),
        ("displayTimeUnit", "ms".into()),
    ]);
    let mut out = std::io::BufWriter::new(std::fs::File::create(output)?);
    writeln!(out, "{}", doc.dump())?;
    out.flush()?;
    Ok(exported)
}

fn complete(
    pid: u64,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    cat: &str,
    name: &str,
    args: &Json,
) -> ChromeEvent {
    ChromeEvent {
        pid,
        tid,
        ts_us,
        json: Json::obj(vec![
            ("ph", "X".into()),
            ("pid", pid.into()),
            ("tid", tid.into()),
            ("ts", Json::Num(ts_us)),
            ("dur", Json::Num(dur_us)),
            ("cat", cat.into()),
            ("name", name.into()),
            ("args", args.clone()),
        ]),
    }
}

fn point(pid: u64, tid: u64, ts_us: f64, cat: &str, name: &str, args: &Json) -> ChromeEvent {
    ChromeEvent {
        pid,
        tid,
        ts_us,
        json: Json::obj(vec![
            ("ph", "i".into()),
            ("s", "t".into()),
            ("pid", pid.into()),
            ("tid", tid.into()),
            ("ts", Json::Num(ts_us)),
            ("cat", cat.into()),
            ("name", name.into()),
            ("args", args.clone()),
        ]),
    }
}

fn metadata(kind: &str, pid: u64, tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", "M".into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("name", kind.into()),
        ("args", Json::obj(vec![("name", name.into())])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_trace(tag: &str, lines: &[&str]) -> (String, String) {
        let dir = std::env::temp_dir();
        let stamp = std::process::id();
        let input = dir.join(format!("edgeflow_chrome_in_{tag}_{stamp}.jsonl"));
        let output = dir.join(format!("edgeflow_chrome_out_{tag}_{stamp}.json"));
        std::fs::write(&input, lines.join("\n")).unwrap();
        (
            input.to_str().unwrap().to_string(),
            output.to_str().unwrap().to_string(),
        )
    }

    #[test]
    fn exports_both_clock_processes_with_monotone_lanes() {
        let (input, output) = write_trace("ok", &[
            r#"{"v":1,"ev":"header","format":"edgeflow-trace","level":"full","run":"t"}"#,
            r#"{"v":1,"ev":"span","cat":"phase","name":"train","lane":"main","wall_ns":2000,"wall_dur_ns":1000,"attrs":{"round":0}}"#,
            r#"{"v":1,"ev":"span","cat":"phase","name":"idle","lane":"main","wall_ns":0,"wall_dur_ns":2000,"attrs":{"round":0}}"#,
            r#"{"v":1,"ev":"span","cat":"net","name":"upload","lane":"route:0->1","wall_ns":5000,"wall_dur_ns":0,"sim_s":1.5,"sim_dur_s":0.5,"attrs":{"bytes":64}}"#,
            r#"{"v":1,"ev":"instant","cat":"control","name":"deadline.set","lane":"main","wall_ns":100,"sim_s":2.0,"attrs":{}}"#,
        ]);
        let n = export_chrome(&input, &output).unwrap();
        // 3 wall spans + 1 sim span + 1 instant on each clock.
        assert_eq!(n, 6);
        let doc = Json::parse(std::fs::read_to_string(&output).unwrap().trim()).unwrap();
        let evs = doc.req("traceEvents").unwrap().as_arr().unwrap();
        // Per-lane ts monotonicity over the non-metadata events.
        let mut last: std::collections::BTreeMap<(u64, u64), f64> = Default::default();
        let mut sim_pid_seen = false;
        for e in evs {
            if e.str_field("ph").unwrap() == "M" {
                continue;
            }
            let pid = e.req("pid").unwrap().as_u64().unwrap();
            let tid = e.req("tid").unwrap().as_u64().unwrap();
            let ts = e.f64_field("ts").unwrap();
            if let Some(prev) = last.get(&(pid, tid)) {
                assert!(ts >= *prev, "lane ({pid},{tid}) ts went backwards");
            }
            last.insert((pid, tid), ts);
            if pid == SIM_PID {
                sim_pid_seen = true;
            }
        }
        assert!(sim_pid_seen, "sim-clock process missing");
        // Metadata names both processes.
        let names: Vec<String> = evs
            .iter()
            .filter(|e| e.str_field("ph").unwrap() == "M")
            .map(|e| e.req("args").unwrap().str_field("name").unwrap().to_string())
            .collect();
        assert!(names.iter().any(|n| n == "wall clock"));
        assert!(names.iter().any(|n| n == "sim clock"));
        assert!(names.iter().any(|n| n == "route:0->1"));
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }

    #[test]
    fn rejects_invalid_trace_lines() {
        let (input, output) = write_trace("bad", &[r#"{"v":1,"ev":"span"}"#]);
        assert!(export_chrome(&input, &output).is_err());
        let _ = std::fs::remove_file(&input);
        assert!(export_chrome("/nonexistent/trace.jsonl", &output).is_err());
    }
}
