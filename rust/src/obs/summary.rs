//! Trace rollups for `trace summarize`: stream a JSONL trace once and
//! aggregate per-phase and per-link totals, so traces are useful from
//! a terminal without a browser.
//!
//! Every line is run through [`super::validate_event`] on the way in,
//! so summarizing doubles as a schema check over the whole file.

use std::collections::BTreeMap;
use std::io::BufRead as _;

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Aggregated totals for one rollup key.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Rollup {
    pub count: u64,
    pub wall_s: f64,
    pub sim_s: f64,
    pub bytes: u64,
}

impl Rollup {
    fn add(&mut self, wall_dur_ns: f64, sim_dur_s: f64, bytes: u64) {
        self.count += 1;
        self.wall_s += wall_dur_ns / 1e9;
        self.sim_s += sim_dur_s;
        self.bytes += bytes;
    }
}

/// A summarized trace: span totals grouped two ways, plus the file's
/// header and final metrics snapshot when present.
#[derive(Debug, Default)]
pub struct TraceSummary {
    /// Spans grouped by `(cat, name)` — phases, rounds, cells...
    pub by_kind: BTreeMap<(String, String), Rollup>,
    /// Network spans (`net` / `link` categories) grouped by lane.
    pub by_lane: BTreeMap<String, Rollup>,
    pub header: Option<Json>,
    pub metrics: Option<Json>,
    pub events: u64,
}

/// Stream-summarize the JSONL trace at `path`.  Fails on the first
/// malformed line (with its line number).
pub fn summarize(path: &str) -> Result<TraceSummary> {
    let f = std::fs::File::open(path)
        .map_err(|e| Error::Io(std::io::Error::new(e.kind(), format!("{path}: {e}"))))?;
    let reader = std::io::BufReader::new(f);
    let mut out = TraceSummary::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| Error::Json(format!("{path} line {}: {e}", lineno + 1)))?;
        super::validate_event(&j)
            .map_err(|e| Error::Json(format!("{path} line {}: {e}", lineno + 1)))?;
        out.events += 1;
        match j.str_field("ev")? {
            "header" => out.header = Some(j),
            "metrics" => out.metrics = Some(j),
            "span" => {
                let cat = j.str_field("cat")?.to_string();
                let name = j.str_field("name")?.to_string();
                let wall_dur_ns = j.req("wall_dur_ns")?.as_f64().unwrap_or(0.0);
                let sim_dur_s = j.get("sim_dur_s").and_then(Json::as_f64).unwrap_or(0.0);
                let bytes = j
                    .get("attrs")
                    .and_then(|a| a.get("bytes"))
                    .and_then(Json::as_u64)
                    .unwrap_or(0);
                if cat == "net" || cat == "link" {
                    let lane = j.str_field("lane")?.to_string();
                    out.by_lane.entry(lane).or_default().add(wall_dur_ns, sim_dur_s, bytes);
                }
                out.by_kind
                    .entry((cat, name))
                    .or_default()
                    .add(wall_dur_ns, sim_dur_s, bytes);
            }
            _ => {} // instants carry no duration; counted in `events` only
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_trace(tag: &str, lines: &[&str]) -> String {
        let path = std::env::temp_dir().join(format!(
            "edgeflow_summary_{tag}_{}.jsonl",
            std::process::id()
        ));
        std::fs::write(&path, lines.join("\n")).unwrap();
        path.to_str().unwrap().to_string()
    }

    #[test]
    fn rolls_up_phases_and_links() {
        let path = write_trace("ok", &[
            r#"{"v":1,"ev":"header","format":"edgeflow-trace","level":"full","run":"t"}"#,
            r#"{"v":1,"ev":"span","cat":"phase","name":"train","lane":"main","wall_ns":0,"wall_dur_ns":2000000000,"attrs":{"round":0}}"#,
            r#"{"v":1,"ev":"span","cat":"phase","name":"train","lane":"main","wall_ns":0,"wall_dur_ns":1000000000,"attrs":{"round":1}}"#,
            r#"{"v":1,"ev":"span","cat":"net","name":"upload","lane":"route:0->1","wall_ns":0,"wall_dur_ns":0,"sim_s":1.0,"sim_dur_s":0.5,"attrs":{"bytes":64}}"#,
            r#"{"v":1,"ev":"span","cat":"net","name":"upload","lane":"route:0->1","wall_ns":0,"wall_dur_ns":0,"sim_s":2.0,"sim_dur_s":0.25,"attrs":{"bytes":36}}"#,
            r#"{"v":1,"ev":"instant","cat":"control","name":"plateau.stop","lane":"main","wall_ns":5,"attrs":{}}"#,
            r#"{"v":1,"ev":"metrics","registry":{"counters":{"rounds":2},"gauges":{},"histograms":{}}}"#,
        ]);
        let s = summarize(&path).unwrap();
        assert_eq!(s.events, 7);
        assert!(s.header.is_some());
        let m = s.metrics.as_ref().expect("metrics event");
        assert_eq!(
            m.req("registry").unwrap().req("counters").unwrap().usize_field("rounds").unwrap(),
            2
        );
        let train = s
            .by_kind
            .get(&("phase".to_string(), "train".to_string()))
            .expect("train rollup");
        assert_eq!(train.count, 2);
        assert!((train.wall_s - 3.0).abs() < 1e-9);
        let link = s.by_lane.get("route:0->1").expect("link rollup");
        assert_eq!(link.count, 2);
        assert_eq!(link.bytes, 100);
        assert!((link.sim_s - 0.75).abs() < 1e-12);
        // net spans appear in both groupings
        let upload = s
            .by_kind
            .get(&("net".to_string(), "upload".to_string()))
            .expect("upload rollup");
        assert_eq!(upload.bytes, 100);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reports_the_offending_line_number() {
        let path = write_trace("bad", &[
            r#"{"v":1,"ev":"header","format":"edgeflow-trace","level":"full","run":"t"}"#,
            r#"{"v":1,"ev":"span","cat":"x"}"#,
        ]);
        let err = summarize(&path).unwrap_err();
        assert!(format!("{err}").contains("line 2"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
