//! The wall-clock half of the dual-clock span model.
//!
//! Every span in a trace carries two time axes: the NetSim simulated
//! clock (seconds, owned by the DES) and the process wall clock
//! (nanoseconds since the tracer's epoch).  This module is the *only*
//! part of `obs` allowed to read the wall clock — the
//! `wall-clock-in-sim` lint allowlists exactly this file — so every
//! other obs module (and the instrumented simulation code) handles
//! opaque [`WallMark`]s instead of raw timestamps.

use std::time::{Duration, Instant};

use crate::util::timer::Timer;

use super::{TraceLevel, Tracer};

/// An opaque point on the wall clock.  Cheap to take anywhere (worker
/// threads included); only a [`WallEpoch`] can turn it into numbers.
#[derive(Debug, Clone, Copy)]
pub struct WallMark {
    at: Instant,
}

impl WallMark {
    pub fn now() -> WallMark {
        WallMark { at: Instant::now() }
    }
}

/// The tracer's time origin: wall offsets in emitted events are
/// nanoseconds since this point, so traces start near zero and u64
/// nanoseconds stay exactly representable in the JSON number space.
#[derive(Debug, Clone, Copy)]
pub struct WallEpoch {
    at: Instant,
}

impl WallEpoch {
    pub fn now() -> WallEpoch {
        WallEpoch { at: Instant::now() }
    }

    /// Nanoseconds from the epoch to `mark` (0 for marks taken before
    /// the epoch — possible only across tracer rebuilds).
    pub fn rel_ns(&self, mark: WallMark) -> u64 {
        mark.at.saturating_duration_since(self.at).as_nanos() as u64
    }

    /// `(start, duration)` nanoseconds for a span opened at `start`
    /// and closing now.
    pub fn span_ns(&self, start: WallMark) -> (u64, u64) {
        let s = self.rel_ns(start);
        let e = self.rel_ns(WallMark::now());
        (s, e.saturating_sub(s))
    }
}

/// The runner's phase timer, folded into the trace: one measurement
/// (the wrapped [`Timer`] lap) feeds both the `phase_seconds` report
/// surface and the emitted phase span, so the two can never disagree.
///
/// Spans ride a running wall cursor instead of fresh clock reads: the
/// emitted phase lanes tile the round exactly (each span starts where
/// the previous ended and its duration is the lap's), which keeps the
/// Chrome export gap-free and the span durations bit-consistent with
/// the CSV/JSON `phase_seconds`.
#[derive(Debug)]
pub struct PhaseTimer {
    timer: Timer,
    tracer: Tracer,
    /// Round attribute stamped on emitted phase spans.
    round: usize,
    /// Wall offset (ns since the tracer epoch) where the next lap's
    /// span starts.
    cursor_ns: u64,
}

impl PhaseTimer {
    pub fn new(tracer: Tracer) -> PhaseTimer {
        let cursor_ns = tracer.rel_now_ns();
        PhaseTimer { timer: Timer::new(), tracer, round: 0, cursor_ns }
    }

    /// Stamp subsequent phase spans with this round index.
    pub fn set_round(&mut self, t: usize) {
        self.round = t;
    }

    /// Record time since the previous lap under `name` (accumulating,
    /// exactly [`Timer::lap`]) and emit the matching phase span.
    pub fn lap(&mut self, name: &str) -> Duration {
        let d = self.timer.lap(name);
        let dur_ns = d.as_nanos() as u64;
        self.tracer.span_at(
            TraceLevel::Phase,
            "phase",
            name,
            "main",
            self.cursor_ns,
            dur_ns,
            None,
            vec![("round", self.round.into())],
        );
        self.cursor_ns += dur_ns;
        d
    }

    /// Accumulated duration for a named lap.
    pub fn get(&self, name: &str) -> Duration {
        self.timer.get(name)
    }

    /// `(name, seconds)` pairs in first-seen order — the
    /// `phase_seconds` report surface, unchanged from [`Timer::laps`].
    pub fn laps(&self) -> Vec<(String, f64)> {
        self.timer.laps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_offsets_are_monotone() {
        let epoch = WallEpoch::now();
        let a = WallMark::now();
        std::thread::sleep(Duration::from_millis(2));
        let b = WallMark::now();
        assert!(epoch.rel_ns(b) > epoch.rel_ns(a));
        let (start, dur) = epoch.span_ns(a);
        assert_eq!(start, epoch.rel_ns(a));
        assert!(dur >= 2_000_000, "{dur}");
    }

    #[test]
    fn marks_before_the_epoch_clamp_to_zero() {
        let m = WallMark::now();
        let epoch = WallEpoch::now();
        assert_eq!(epoch.rel_ns(m), 0);
    }

    #[test]
    fn phase_timer_mirrors_timer_laps() {
        let mut pt = PhaseTimer::new(Tracer::off());
        std::thread::sleep(Duration::from_millis(2));
        pt.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        pt.lap("a");
        pt.lap("b");
        assert!(pt.get("a") >= Duration::from_millis(4));
        let laps = pt.laps();
        assert_eq!(laps.len(), 2);
        assert_eq!(laps[0].0, "a");
        assert_eq!(laps[1].0, "b");
    }
}
