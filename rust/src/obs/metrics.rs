//! Deterministic metrics registry: counters, gauges and fixed-bound
//! histograms whose emitted form is bit-identical at any worker count.
//!
//! The contract has two halves.  Storage is `BTreeMap`-ordered, so
//! serialization order never depends on insertion order.  Aggregation
//! is *caller-ordered*: [`MetricsRegistry::merge`] folds `other` into
//! `self` exactly as given, and [`MetricsRegistry::merge_all`] folds a
//! slice left to right — callers hand partial registries over in a
//! fixed order (job order, never thread-completion order), so every
//! f64 sum performs its additions in the same sequence and the merged
//! bits cannot vary with scheduling.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Fixed-bucket histogram: `bounds` are ascending upper bounds, with
/// an implicit +inf bucket at the end (`counts.len() == bounds.len()
/// + 1`).  Bounds are fixed at construction — two histograms under
/// the same name must agree on them, which the registry enforces.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    total: u64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            total: 0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.total += 1;
    }

    /// Fold `other`'s observations into `self` (bounds must match;
    /// mismatched merges are a caller bug and are dropped, keeping the
    /// registry total-function — the debug build asserts).
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.bounds.len(), other.bounds.len(), "histogram bounds mismatch");
        if self.bounds.len() != other.bounds.len() {
            return;
        }
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.sum += other.sum;
        self.total += other.total;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bounds", Json::arr(self.bounds.iter().map(|b| Json::Num(*b)))),
            ("counts", Json::arr(self.counts.iter().map(|c| Json::from(*c)))),
            ("sum", Json::Num(self.sum)),
            ("total", self.total.into()),
        ])
    }
}

/// The registry.  All three families are name-keyed `BTreeMap`s; see
/// the module docs for the determinism contract.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to a counter (created at zero on first use).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge (last write wins).
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Observe a value into a fixed-bound histogram, created with
    /// `bounds` on first use.
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Fold `other` into `self`: counters and histogram cells add,
    /// gauges take `other`'s value (last-merged wins).  Callers must
    /// merge partials in a fixed order — see the module docs.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            match self.histograms.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// Fold `parts` left to right into one registry.
    pub fn merge_all(parts: &[MetricsRegistry]) -> MetricsRegistry {
        let mut out = MetricsRegistry::new();
        for p in parts {
            out.merge(p);
        }
        out
    }

    /// The serialized form: three name-sorted objects.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::from(*v)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            (
                "histograms",
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut r = MetricsRegistry::new();
        assert!(r.is_empty());
        r.inc("rounds", 1);
        r.inc("rounds", 2);
        r.set_gauge("acc", 0.5);
        r.set_gauge("acc", 0.75);
        assert_eq!(r.counter("rounds"), 3);
        assert_eq!(r.counter("missing"), 0);
        assert_eq!(r.gauge("acc"), Some(0.75));
        assert_eq!(r.gauge("missing"), None);
    }

    #[test]
    fn histogram_buckets_values_by_upper_bound() {
        let mut h = Histogram::new(&[0.1, 1.0, 10.0]);
        h.observe(0.05); // <= 0.1
        h.observe(0.1); // boundary lands in its bucket
        h.observe(0.5);
        h.observe(100.0); // overflow bucket
        assert_eq!(h.counts(), &[2, 1, 0, 1]);
        assert_eq!(h.total(), 4);
        assert!((h.sum() - 100.65).abs() < 1e-9);
    }

    #[test]
    fn merge_is_order_invariant_for_the_integer_parts() {
        let mut a = MetricsRegistry::new();
        a.inc("x", 1);
        a.observe("h", &[1.0], 0.5);
        let mut b = MetricsRegistry::new();
        b.inc("x", 2);
        b.inc("y", 7);
        b.observe("h", &[1.0], 2.0);
        let ab = MetricsRegistry::merge_all(&[a.clone(), b.clone()]);
        assert_eq!(ab.counter("x"), 3);
        assert_eq!(ab.counter("y"), 7);
        let h = ab.histogram("h").expect("merged histogram");
        assert_eq!(h.counts(), &[1, 1]);
        assert_eq!(h.total(), 2);
    }

    #[test]
    fn fixed_merge_order_is_bit_stable() {
        // Simulate "the same work split across different worker
        // counts": partial registries handed over in job order must
        // fold to bit-identical sums regardless of how the work was
        // sharded, because the fold order is the caller's fixed order.
        let vals = [0.1, 0.2, 0.30000000000000004, 1e-9, 7.5];
        let one: Vec<MetricsRegistry> = vals
            .iter()
            .map(|v| {
                let mut r = MetricsRegistry::new();
                r.observe("lat", &[1.0, 10.0], *v);
                r.inc("n", 1);
                r
            })
            .collect();
        let merged_fine = MetricsRegistry::merge_all(&one);
        // Same observations pre-folded into two shards (job order
        // preserved within and across shards).
        let mut s0 = MetricsRegistry::new();
        let mut s1 = MetricsRegistry::new();
        for v in &vals[..3] {
            s0.observe("lat", &[1.0, 10.0], *v);
            s0.inc("n", 1);
        }
        for v in &vals[3..] {
            s1.observe("lat", &[1.0, 10.0], *v);
            s1.inc("n", 1);
        }
        let merged_coarse = MetricsRegistry::merge_all(&[s0, s1]);
        assert_eq!(
            merged_fine.to_json().dump(),
            merged_coarse.to_json().dump(),
            "fold order fixed by the caller => identical bits"
        );
    }

    #[test]
    fn json_shape_is_name_sorted() {
        let mut r = MetricsRegistry::new();
        r.inc("z", 1);
        r.inc("a", 1);
        r.set_gauge("m", 1.5);
        r.observe("h", &[1.0], 0.25);
        let s = r.to_json().dump();
        assert!(s.find("\"a\"").expect("a") < s.find("\"z\"").expect("z"));
        assert!(s.contains("\"counters\""));
        assert!(s.contains("\"gauges\""));
        assert!(s.contains("\"histograms\""));
        assert!(s.contains("\"bounds\""));
    }
}
