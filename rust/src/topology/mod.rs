//! Edge-network topology model (paper Fig 4's four structures).
//!
//! Nodes are clients, edge base stations, backbone routers, and the cloud;
//! links carry bandwidth/latency so both hop-count accounting (the paper's
//! communication-load metric) and discrete-event timing ([`crate::netsim`])
//! run over the same graph.

pub mod accounting;
pub mod builder;
pub mod graph;
pub mod route;

pub use accounting::CommAccountant;
pub use builder::{build, TopologyParams};
pub use graph::{LinkId, NodeId, NodeKind, Topology};
pub use route::RouteTable;
