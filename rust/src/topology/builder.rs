//! Constructors for the paper's four edge-network structures (Fig 4).
//!
//! All four attach `clients_per_cluster` clients to each of `clusters`
//! base stations over radio links; they differ in how base stations reach
//! the cloud (and, for EdgeFLow, each other):
//!
//! * **simple** — every BS has a direct backbone link to the cloud
//!   (local — edge — cloud; the shallowest structure).
//! * **breadth_parallel** — base stations fan into aggregation routers
//!   (groups of `fanout`), routers connect to the cloud: broad and
//!   shallow, 2 backbone hops.
//! * **depth_linear** — base stations form a chain BS0—BS1—…—BS(M-1) and
//!   only the far end reaches the cloud: the deepest structure, where the
//!   average BS→cloud distance grows linearly with M.
//! * **hybrid** — chains of `chain_len` base stations whose heads fan into
//!   routers, then the cloud: the paper's "hybrid breadth-depth complex"
//!   case.
//!
//! Neighboring base stations are additionally linked in all structures
//! except `simple` — matching the paper's premise that adjacent edge sites
//! have direct channels EdgeFLow's migration can ride.  In `simple`,
//! BS↔BS traffic routes through the cloud, which is exactly why the
//! paper's Fig 4 shows the smallest gain there.

use crate::config::TopologyKind;
use crate::topology::graph::{NodeKind, Topology};
use crate::util::error::Result;

/// Topology construction parameters (bandwidths in Mbps, latencies in ms).
#[derive(Debug, Clone)]
pub struct TopologyParams {
    pub kind: TopologyKind,
    pub clusters: usize,
    pub clients_per_cluster: usize,
    /// Radio link: client <-> BS.
    pub radio_mbps: f64,
    pub radio_ms: f64,
    /// Edge link: BS <-> BS (adjacent sites).
    pub edge_mbps: f64,
    pub edge_ms: f64,
    /// Backbone link: BS/router <-> router/cloud.
    pub backbone_mbps: f64,
    pub backbone_ms: f64,
    /// Router fan-in for breadth/hybrid structures.
    pub fanout: usize,
    /// Chain length for the hybrid structure.
    pub chain_len: usize,
}

impl TopologyParams {
    pub fn new(kind: TopologyKind, clusters: usize, clients_per_cluster: usize) -> Self {
        TopologyParams {
            kind,
            clusters,
            clients_per_cluster,
            radio_mbps: 100.0,
            radio_ms: 2.0,
            edge_mbps: 1_000.0,
            edge_ms: 1.0,
            backbone_mbps: 10_000.0,
            backbone_ms: 5.0,
            fanout: 4,
            chain_len: 3,
        }
    }
}

/// Build one of the paper's four structures.
pub fn build(p: &TopologyParams) -> Result<Topology> {
    let mut t = Topology::new();
    let cloud = t.add_node(NodeKind::Cloud);

    // Base stations + their clients (client ids are cluster-major).
    let mut bs = Vec::with_capacity(p.clusters);
    for m in 0..p.clusters {
        let b = t.add_node(NodeKind::EdgeBs(m));
        bs.push(b);
        for j in 0..p.clients_per_cluster {
            let c = t.add_node(NodeKind::Client(m * p.clients_per_cluster + j));
            t.add_link(c, b, p.radio_mbps, p.radio_ms);
        }
    }

    match p.kind {
        TopologyKind::Simple => {
            // Star: every BS one backbone hop from the cloud.  No direct
            // BS<->BS channels.
            for &b in &bs {
                t.add_link(b, cloud, p.backbone_mbps, p.backbone_ms);
            }
        }
        TopologyKind::BreadthParallel => {
            // BS -> router (groups of fanout) -> cloud; ring of BS links.
            let groups = p.clusters.div_ceil(p.fanout);
            for g in 0..groups {
                let r = t.add_node(NodeKind::Router);
                t.add_link(r, cloud, p.backbone_mbps, p.backbone_ms);
                for i in (g * p.fanout)..((g + 1) * p.fanout).min(p.clusters) {
                    t.add_link(bs[i], r, p.backbone_mbps, p.backbone_ms);
                }
            }
            link_bs_ring(&mut t, &bs, p);
        }
        TopologyKind::DepthLinear => {
            // Chain; only the tail reaches the cloud.
            for w in bs.windows(2) {
                t.add_link(w[0], w[1], p.edge_mbps, p.edge_ms);
            }
            if let Some(&tail) = bs.last() {
                t.add_link(tail, cloud, p.backbone_mbps, p.backbone_ms);
            }
        }
        TopologyKind::Hybrid => {
            // Chains of `chain_len`; chain heads fan into routers; routers
            // into the cloud; consecutive chains bridged at the tail.
            let chains: Vec<&[_]> = bs.chunks(p.chain_len).collect();
            let groups = chains.len().div_ceil(p.fanout);
            let mut routers = Vec::new();
            for _ in 0..groups {
                let r = t.add_node(NodeKind::Router);
                t.add_link(r, cloud, p.backbone_mbps, p.backbone_ms);
                routers.push(r);
            }
            for (ci, chain) in chains.iter().enumerate() {
                for w in chain.windows(2) {
                    t.add_link(w[0], w[1], p.edge_mbps, p.edge_ms);
                }
                t.add_link(chain[0], routers[ci / p.fanout], p.backbone_mbps, p.backbone_ms);
                // Bridge chain tails so the edge mesh is connected without
                // the backbone.
                if ci + 1 < chains.len() {
                    t.add_link(
                        // chunks() never yields an empty slice
                        chain[chain.len() - 1],
                        chains[ci + 1][0],
                        p.edge_mbps,
                        p.edge_ms,
                    );
                }
            }
        }
    }
    Ok(t)
}

/// Ring of direct BS<->BS links (adjacent edge sites).
fn link_bs_ring(t: &mut Topology, bs: &[crate::topology::graph::NodeId], p: &TopologyParams) {
    if bs.len() < 2 {
        return;
    }
    for w in bs.windows(2) {
        t.add_link(w[0], w[1], p.edge_mbps, p.edge_ms);
    }
    if bs.len() > 2 {
        t.add_link(bs[bs.len() - 1], bs[0], p.edge_mbps, p.edge_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::route::RouteTable;

    fn params(kind: TopologyKind) -> TopologyParams {
        TopologyParams::new(kind, 10, 10)
    }

    #[test]
    fn all_structures_build_and_connect() {
        for kind in TopologyKind::ALL {
            let t = build(&params(kind)).unwrap();
            assert_eq!(t.base_stations().len(), 10, "{kind:?}");
            assert_eq!(t.clients().len(), 100, "{kind:?}");
            let rt = RouteTable::hops(&t);
            // Every client reaches the cloud and every BS.
            let cloud = t.cloud().unwrap();
            for c in t.clients() {
                assert!(rt.dist(c, cloud).is_some(), "{kind:?} client unreachable");
            }
            for a in t.base_stations() {
                for b in t.base_stations() {
                    assert!(rt.dist(a, b).is_some(), "{kind:?} BS pair unreachable");
                }
            }
        }
    }

    #[test]
    fn simple_is_one_hop_bs_to_cloud() {
        let t = build(&params(TopologyKind::Simple)).unwrap();
        let rt = RouteTable::hops(&t);
        let cloud = t.cloud().unwrap();
        for b in t.base_stations() {
            assert_eq!(rt.dist(b, cloud), Some(1));
        }
    }

    #[test]
    fn depth_linear_distance_grows() {
        let t = build(&params(TopologyKind::DepthLinear)).unwrap();
        let rt = RouteTable::hops(&t);
        let cloud = t.cloud().unwrap();
        let bs = t.base_stations();
        // BS0 is 10 hops from the cloud, BS9 is 1.
        assert_eq!(rt.dist(bs[9], cloud), Some(1));
        assert_eq!(rt.dist(bs[0], cloud), Some(10));
    }

    #[test]
    fn breadth_parallel_is_two_hops() {
        let t = build(&params(TopologyKind::BreadthParallel)).unwrap();
        let rt = RouteTable::hops(&t);
        let cloud = t.cloud().unwrap();
        for b in t.base_stations() {
            assert_eq!(rt.dist(b, cloud), Some(2));
        }
    }

    #[test]
    fn neighbor_bs_one_hop_except_simple() {
        for kind in [
            TopologyKind::BreadthParallel,
            TopologyKind::DepthLinear,
            TopologyKind::Hybrid,
        ] {
            let t = build(&params(kind)).unwrap();
            let rt = RouteTable::hops(&t);
            let bs = t.base_stations();
            assert_eq!(rt.dist(bs[0], bs[1]), Some(1), "{kind:?}");
        }
        let t = build(&params(TopologyKind::Simple)).unwrap();
        let rt = RouteTable::hops(&t);
        let bs = t.base_stations();
        // via the cloud
        assert_eq!(rt.dist(bs[0], bs[1]), Some(2));
    }
}
