//! Node/link graph with adjacency lists.

use crate::util::error::{Error, Result};

/// Node handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// Link handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

/// What a node is, for routing policy and reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Local device; payload is the client id.
    Client(usize),
    /// Edge base station anchoring cluster `m`.
    EdgeBs(usize),
    /// Backbone router (no attached clients).
    Router,
    /// The (traditional) cloud aggregation server.
    Cloud,
}

/// An undirected link with capacity characteristics.
#[derive(Debug, Clone)]
pub struct Link {
    pub a: NodeId,
    pub b: NodeId,
    /// Megabits per second.
    pub bandwidth_mbps: f64,
    /// One-way propagation latency, milliseconds.
    pub latency_ms: f64,
}

/// The network graph.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    kinds: Vec<NodeKind>,
    links: Vec<Link>,
    /// adjacency: node -> [(neighbor, link)]
    adj: Vec<Vec<(NodeId, LinkId)>>,
}

impl Topology {
    pub fn new() -> Topology {
        Topology::default()
    }

    pub fn add_node(&mut self, kind: NodeKind) -> NodeId {
        self.kinds.push(kind);
        self.adj.push(Vec::new());
        NodeId(self.kinds.len() - 1)
    }

    pub fn add_link(
        &mut self,
        a: NodeId,
        b: NodeId,
        bandwidth_mbps: f64,
        latency_ms: f64,
    ) -> LinkId {
        assert!(a.0 < self.kinds.len() && b.0 < self.kinds.len(), "bad node");
        assert_ne!(a, b, "self-link");
        // A poisoned link would propagate NaN event times through the DES;
        // reject it at the source.
        assert!(
            bandwidth_mbps.is_finite() && bandwidth_mbps > 0.0,
            "bad bandwidth {bandwidth_mbps} (must be finite and positive)"
        );
        assert!(
            latency_ms.is_finite() && latency_ms >= 0.0,
            "bad latency {latency_ms} (must be finite and non-negative)"
        );
        let id = LinkId(self.links.len());
        self.links.push(Link { a, b, bandwidth_mbps, latency_ms });
        self.adj[a.0].push((b, id));
        self.adj[b.0].push((a, id));
        id
    }

    pub fn node_count(&self) -> usize {
        self.kinds.len()
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    pub fn kind(&self, n: NodeId) -> NodeKind {
        self.kinds[n.0]
    }

    pub fn link(&self, l: LinkId) -> &Link {
        &self.links[l.0]
    }

    pub fn neighbors(&self, n: NodeId) -> &[(NodeId, LinkId)] {
        &self.adj[n.0]
    }

    /// First node of `kind` (e.g. the cloud).
    pub fn find(&self, pred: impl Fn(NodeKind) -> bool) -> Option<NodeId> {
        self.kinds.iter().position(|&k| pred(k)).map(NodeId)
    }

    /// The cloud node, if present.
    pub fn cloud(&self) -> Result<NodeId> {
        self.find(|k| k == NodeKind::Cloud)
            .ok_or_else(|| Error::Topology("no cloud node".into()))
    }

    /// Base station of cluster `m`.
    pub fn edge_bs(&self, m: usize) -> Result<NodeId> {
        self.find(|k| k == NodeKind::EdgeBs(m))
            .ok_or_else(|| Error::Topology(format!("no edge BS for cluster {m}")))
    }

    /// Node for client `id`.
    pub fn client(&self, id: usize) -> Result<NodeId> {
        self.find(|k| k == NodeKind::Client(id))
            .ok_or_else(|| Error::Topology(format!("no node for client {id}")))
    }

    /// All base stations in cluster order.
    pub fn base_stations(&self) -> Vec<NodeId> {
        let mut bs: Vec<(usize, NodeId)> = self
            .kinds
            .iter()
            .enumerate()
            .filter_map(|(i, &k)| match k {
                NodeKind::EdgeBs(m) => Some((m, NodeId(i))),
                _ => None,
            })
            .collect();
        bs.sort_unstable();
        bs.into_iter().map(|(_, n)| n).collect()
    }

    /// All client nodes.
    pub fn clients(&self) -> Vec<NodeId> {
        (0..self.kinds.len())
            .filter(|&i| matches!(self.kinds[i], NodeKind::Client(_)))
            .map(NodeId)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let mut t = Topology::new();
        let c0 = t.add_node(NodeKind::Client(0));
        let bs = t.add_node(NodeKind::EdgeBs(0));
        let cloud = t.add_node(NodeKind::Cloud);
        t.add_link(c0, bs, 100.0, 1.0);
        t.add_link(bs, cloud, 1000.0, 10.0);
        assert_eq!(t.node_count(), 3);
        assert_eq!(t.link_count(), 2);
        assert_eq!(t.cloud().unwrap(), cloud);
        assert_eq!(t.edge_bs(0).unwrap(), bs);
        assert_eq!(t.client(0).unwrap(), c0);
        assert_eq!(t.neighbors(bs).len(), 2);
        assert!(t.edge_bs(3).is_err());
    }

    #[test]
    #[should_panic(expected = "self-link")]
    fn rejects_self_link() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        t.add_link(a, a, 1.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "bad latency")]
    fn rejects_nan_latency() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        t.add_link(a, b, 1.0, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "bad bandwidth")]
    fn rejects_zero_bandwidth() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        t.add_link(a, b, 0.0, 1.0);
    }
}
