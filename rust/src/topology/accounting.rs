//! Communication accounting: the paper's Fig 4 metric.
//!
//! The paper measures "communication load by the count of parameters
//! uploaded per round", weighting each transfer by the number of hops it
//! traverses.  The accountant records every logical transfer, attributes
//! the bytes to each link on its route, and exposes the totals the
//! compression ratio is computed from.

use std::collections::BTreeMap;

use crate::topology::graph::{LinkId, NodeId, Topology};
use crate::topology::route::RouteTable;
use crate::util::error::{Error, Result};

/// One logical transfer record.
#[derive(Debug, Clone)]
pub struct Transfer {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    pub hops: usize,
    /// Free-form label ("upload", "migration", "broadcast", ...).
    pub label: &'static str,
    pub round: usize,
}

/// Aggregated communication ledger for one experiment.
#[derive(Debug, Default)]
pub struct CommAccountant {
    transfers: Vec<Transfer>,
    per_link_bytes: BTreeMap<usize, u64>,
}

impl CommAccountant {
    pub fn new() -> CommAccountant {
        CommAccountant::default()
    }

    /// Record a transfer routed by `routes`; returns the hop count.
    pub fn record(
        &mut self,
        topo: &Topology,
        routes: &RouteTable,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        label: &'static str,
        round: usize,
    ) -> Result<usize> {
        let path = routes.path(src, dst).ok_or_else(|| {
            Error::Topology(format!("no route {src:?} -> {dst:?}"))
        })?;
        for &l in &path {
            debug_assert!(l.0 < topo.link_count());
            *self.per_link_bytes.entry(l.0).or_insert(0) += bytes;
        }
        let hops = path.len();
        self.transfers.push(Transfer { src, dst, bytes, hops, label, round });
        Ok(hops)
    }

    /// Total byte-hops (bytes x hops summed over transfers) — the paper's
    /// load metric, scaled to bytes.
    pub fn byte_hops(&self) -> u64 {
        self.transfers.iter().map(|t| t.bytes * t.hops as u64).sum()
    }

    /// Total bytes injected (ignoring path length).
    pub fn bytes_sent(&self) -> u64 {
        self.transfers.iter().map(|t| t.bytes).sum()
    }

    /// Transfers recorded.
    pub fn transfer_count(&self) -> usize {
        self.transfers.len()
    }

    /// Byte-hops restricted to a label.
    pub fn byte_hops_for(&self, label: &str) -> u64 {
        self.transfers
            .iter()
            .filter(|t| t.label == label)
            .map(|t| t.bytes * t.hops as u64)
            .sum()
    }

    /// Per-link byte totals (link id -> bytes).
    pub fn link_loads(&self) -> &BTreeMap<usize, u64> {
        &self.per_link_bytes
    }

    /// The busiest link and its bytes.
    pub fn hottest_link(&self) -> Option<(LinkId, u64)> {
        self.per_link_bytes
            .iter()
            .max_by_key(|(_, &b)| b)
            .map(|(&l, &b)| (LinkId(l), b))
    }

    /// Conservation check: sum over links == sum over transfers of
    /// bytes*hops.  True by construction; exposed for property tests.
    pub fn conserves_bytes(&self) -> bool {
        let link_sum: u64 = self.per_link_bytes.values().sum();
        link_sum == self.byte_hops()
    }

    pub fn transfers(&self) -> &[Transfer] {
        &self.transfers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;
    use crate::topology::builder::{build, TopologyParams};

    #[test]
    fn records_and_conserves() {
        let t = build(&TopologyParams::new(TopologyKind::DepthLinear, 4, 2)).unwrap();
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let cloud = t.cloud().unwrap();
        let bs0 = t.edge_bs(0).unwrap();
        let hops = acc.record(&t, &rt, bs0, cloud, 1000, "upload", 0).unwrap();
        assert_eq!(hops, 4); // chain of 4 BS, far end to cloud
        assert_eq!(acc.byte_hops(), 4000);
        assert_eq!(acc.bytes_sent(), 1000);
        assert!(acc.conserves_bytes());
    }

    #[test]
    fn labels_separate() {
        let t = build(&TopologyParams::new(TopologyKind::Simple, 2, 2)).unwrap();
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let cloud = t.cloud().unwrap();
        let c0 = t.client(0).unwrap();
        acc.record(&t, &rt, c0, cloud, 10, "upload", 0).unwrap();
        acc.record(&t, &rt, cloud, c0, 20, "broadcast", 0).unwrap();
        assert_eq!(acc.byte_hops_for("upload"), 20); // 2 hops x 10
        assert_eq!(acc.byte_hops_for("broadcast"), 40);
        assert_eq!(acc.transfer_count(), 2);
    }

    #[test]
    fn hottest_link_found() {
        let t = build(&TopologyParams::new(TopologyKind::Simple, 2, 1)).unwrap();
        let rt = RouteTable::hops(&t);
        let mut acc = CommAccountant::new();
        let cloud = t.cloud().unwrap();
        for round in 0..3 {
            acc.record(&t, &rt, t.client(0).unwrap(), cloud, 5, "u", round).unwrap();
        }
        let (_, bytes) = acc.hottest_link().unwrap();
        assert_eq!(bytes, 15);
    }
}
