//! Shortest-path routing: BFS hop counts and Dijkstra latency paths.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::topology::graph::{LinkId, NodeId, Topology};

/// All-pairs-on-demand route table.  Paths are recomputed per source; for
/// the graph sizes here (hundreds of nodes) this is microseconds.
pub struct RouteTable<'a> {
    topo: &'a Topology,
    /// Edge weight: None = hop count, Some = latency-weighted Dijkstra.
    weighted: bool,
}

impl<'a> RouteTable<'a> {
    /// Hop-count routing (the paper's communication-load metric).
    pub fn hops(topo: &'a Topology) -> RouteTable<'a> {
        RouteTable { topo, weighted: false }
    }

    /// Latency-weighted routing (used by the DES for path selection).
    pub fn latency(topo: &'a Topology) -> RouteTable<'a> {
        RouteTable { topo, weighted: true }
    }

    fn weight(&self, l: LinkId) -> f64 {
        if self.weighted {
            self.topo.link(l).latency_ms
        } else {
            1.0
        }
    }

    /// Shortest path `src -> dst` as a list of links, or None if
    /// disconnected.  The path is deterministic (ties broken by node id).
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<Vec<LinkId>> {
        if src == dst {
            return Some(Vec::new());
        }
        let n = self.topo.node_count();
        let mut dist = vec![f64::INFINITY; n];
        let mut prev: Vec<Option<(NodeId, LinkId)>> = vec![None; n];
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        dist[src.0] = 0.0;
        heap.push(Reverse((0, src.0)));
        while let Some(Reverse((d_key, u))) = heap.pop() {
            let d = f64::from_bits(d_key);
            if d > dist[u] {
                continue;
            }
            if u == dst.0 {
                break;
            }
            let mut nbrs: Vec<_> = self.topo.neighbors(NodeId(u)).to_vec();
            nbrs.sort_by_key(|(n, _)| n.0);
            for (v, l) in nbrs {
                let nd = d + self.weight(l);
                if nd < dist[v.0] {
                    dist[v.0] = nd;
                    prev[v.0] = Some((NodeId(u), l));
                    heap.push(Reverse((nd.to_bits(), v.0)));
                }
            }
        }
        if dist[dst.0].is_infinite() {
            return None;
        }
        let mut path = Vec::new();
        let mut cur = dst;
        while cur != src {
            let (p, l) = prev[cur.0]?;
            path.push(l);
            cur = p;
        }
        path.reverse();
        Some(path)
    }

    /// Hop count (or total latency when weighted), None if disconnected.
    pub fn dist(&self, src: NodeId, dst: NodeId) -> Option<usize> {
        self.path(src, dst).map(|p| p.len())
    }

    /// Total latency along the shortest path, in ms.
    pub fn path_latency_ms(&self, src: NodeId, dst: NodeId) -> Option<f64> {
        self.path(src, dst)
            .map(|p| p.iter().map(|&l| self.topo.link(l).latency_ms).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::graph::{NodeKind, Topology};

    /// a - b - c with a shortcut a - c of higher latency.
    fn diamond() -> (Topology, NodeId, NodeId, NodeId) {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        let c = t.add_node(NodeKind::Router);
        t.add_link(a, b, 100.0, 1.0);
        t.add_link(b, c, 100.0, 1.0);
        t.add_link(a, c, 100.0, 10.0);
        (t, a, b, c)
    }

    #[test]
    fn hop_routing_prefers_fewest_links() {
        let (t, a, _b, c) = diamond();
        let rt = RouteTable::hops(&t);
        assert_eq!(rt.dist(a, c), Some(1)); // direct link wins on hops
    }

    #[test]
    fn latency_routing_prefers_fast_path() {
        let (t, a, _b, c) = diamond();
        let rt = RouteTable::latency(&t);
        let p = rt.path(a, c).unwrap();
        assert_eq!(p.len(), 2); // 1+1 ms via b beats 10 ms direct
        assert_eq!(rt.path_latency_ms(a, c), Some(2.0));
    }

    #[test]
    fn self_path_is_empty() {
        let (t, a, ..) = diamond();
        assert_eq!(RouteTable::hops(&t).path(a, a).unwrap().len(), 0);
    }

    #[test]
    fn disconnected_returns_none() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        assert!(RouteTable::hops(&t).path(a, b).is_none());
    }

    #[test]
    fn path_is_contiguous() {
        let (t, a, _b, c) = diamond();
        let rt = RouteTable::latency(&t);
        let p = rt.path(a, c).unwrap();
        // links must chain a -> ... -> c
        let mut cur = a;
        for l in p {
            let link = t.link(l);
            cur = if link.a == cur { link.b } else { link.a };
        }
        assert_eq!(cur, c);
    }

    #[test]
    fn symmetric_hop_distance() {
        let (t, a, b, c) = diamond();
        let rt = RouteTable::hops(&t);
        for (x, y) in [(a, b), (b, c), (a, c)] {
            assert_eq!(rt.dist(x, y), rt.dist(y, x));
        }
    }
}
