//! Shortest-path routing: hop counts, Dijkstra latency paths, and
//! bandwidth-aware transfer-time paths (`latency + bytes/bandwidth`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::topology::graph::{LinkId, NodeId, Topology};

/// Per-link Dijkstra weight.
#[derive(Debug, Clone, Copy)]
enum EdgeWeight {
    /// Unit weight: hop-count routing.
    Hops,
    /// Propagation latency only (ms).
    Latency,
    /// Seconds to push `bytes` across the link: propagation latency plus
    /// serialization time at the link's bandwidth.  Unlike pure latency
    /// this stops a bulk transfer from preferring a thin low-latency
    /// link over a fat slightly-slower one.
    TransferTime { bytes: u64 },
}

/// All-pairs-on-demand route table.  Paths are recomputed per source; for
/// the graph sizes here (hundreds of nodes) this is microseconds.
pub struct RouteTable<'a> {
    topo: &'a Topology,
    weighting: EdgeWeight,
}

impl<'a> RouteTable<'a> {
    /// Hop-count routing (the paper's communication-load metric).
    pub fn hops(topo: &'a Topology) -> RouteTable<'a> {
        RouteTable { topo, weighting: EdgeWeight::Hops }
    }

    /// Latency-weighted routing (path selection when the transfer size is
    /// unknown or negligible).
    pub fn latency(topo: &'a Topology) -> RouteTable<'a> {
        RouteTable { topo, weighting: EdgeWeight::Latency }
    }

    /// Bandwidth-aware routing for a transfer of `bytes`: each link costs
    /// `latency + bytes/bandwidth` seconds.  This is what the DES rides
    /// when the model size is known — big migrations stop preferring
    /// thin low-latency links (ROADMAP open item).
    pub fn transfer_time(topo: &'a Topology, bytes: u64) -> RouteTable<'a> {
        RouteTable { topo, weighting: EdgeWeight::TransferTime { bytes } }
    }

    fn weight(&self, l: LinkId) -> f64 {
        let link = self.topo.link(l);
        match self.weighting {
            EdgeWeight::Hops => 1.0,
            EdgeWeight::Latency => link.latency_ms,
            EdgeWeight::TransferTime { bytes } => {
                link.latency_ms / 1e3
                    + (bytes as f64 * 8.0) / (link.bandwidth_mbps * 1e6)
            }
        }
    }

    /// Shortest path `src -> dst` as a list of links, or None if
    /// disconnected.  The path is deterministic (ties broken by node id).
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<Vec<LinkId>> {
        if src == dst {
            return Some(Vec::new());
        }
        let n = self.topo.node_count();
        let mut dist = vec![f64::INFINITY; n];
        let mut prev: Vec<Option<(NodeId, LinkId)>> = vec![None; n];
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        dist[src.0] = 0.0;
        heap.push(Reverse((0, src.0)));
        while let Some(Reverse((d_key, u))) = heap.pop() {
            let d = f64::from_bits(d_key);
            if d > dist[u] {
                continue;
            }
            if u == dst.0 {
                break;
            }
            let mut nbrs: Vec<_> = self.topo.neighbors(NodeId(u)).to_vec();
            nbrs.sort_by_key(|(n, _)| n.0);
            for (v, l) in nbrs {
                let nd = d + self.weight(l);
                if nd < dist[v.0] {
                    dist[v.0] = nd;
                    prev[v.0] = Some((NodeId(u), l));
                    heap.push(Reverse((nd.to_bits(), v.0)));
                }
            }
        }
        if dist[dst.0].is_infinite() {
            return None;
        }
        let mut path = Vec::new();
        let mut cur = dst;
        while cur != src {
            let (p, l) = prev[cur.0]?;
            path.push(l);
            cur = p;
        }
        path.reverse();
        Some(path)
    }

    /// Hop count (or total latency when weighted), None if disconnected.
    pub fn dist(&self, src: NodeId, dst: NodeId) -> Option<usize> {
        self.path(src, dst).map(|p| p.len())
    }

    /// Total latency along the shortest path, in ms.
    pub fn path_latency_ms(&self, src: NodeId, dst: NodeId) -> Option<f64> {
        self.path(src, dst)
            .map(|p| p.iter().map(|&l| self.topo.link(l).latency_ms).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::graph::{NodeKind, Topology};

    /// a - b - c with a shortcut a - c of higher latency.
    fn diamond() -> (Topology, NodeId, NodeId, NodeId) {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        let c = t.add_node(NodeKind::Router);
        t.add_link(a, b, 100.0, 1.0);
        t.add_link(b, c, 100.0, 1.0);
        t.add_link(a, c, 100.0, 10.0);
        (t, a, b, c)
    }

    #[test]
    fn hop_routing_prefers_fewest_links() {
        let (t, a, _b, c) = diamond();
        let rt = RouteTable::hops(&t);
        assert_eq!(rt.dist(a, c), Some(1)); // direct link wins on hops
    }

    #[test]
    fn latency_routing_prefers_fast_path() {
        let (t, a, _b, c) = diamond();
        let rt = RouteTable::latency(&t);
        let p = rt.path(a, c).unwrap();
        assert_eq!(p.len(), 2); // 1+1 ms via b beats 10 ms direct
        assert_eq!(rt.path_latency_ms(a, c), Some(2.0));
    }

    #[test]
    fn self_path_is_empty() {
        let (t, a, ..) = diamond();
        assert_eq!(RouteTable::hops(&t).path(a, a).unwrap().len(), 0);
    }

    #[test]
    fn disconnected_returns_none() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        assert!(RouteTable::hops(&t).path(a, b).is_none());
    }

    #[test]
    fn path_is_contiguous() {
        let (t, a, _b, c) = diamond();
        let rt = RouteTable::latency(&t);
        let p = rt.path(a, c).unwrap();
        // links must chain a -> ... -> c
        let mut cur = a;
        for l in p {
            let link = t.link(l);
            cur = if link.a == cur { link.b } else { link.a };
        }
        assert_eq!(cur, c);
    }

    /// a — c direct over a thin fast link; a — b — c over fat slow links.
    /// Latency routing always takes the shortcut; transfer-time routing
    /// must abandon it once the payload is big enough that serialization
    /// dominates propagation.
    fn thin_shortcut() -> (Topology, NodeId, NodeId) {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Router);
        let b = t.add_node(NodeKind::Router);
        let c = t.add_node(NodeKind::Router);
        t.add_link(a, c, 1.0, 1.0); // 1 Mbps, 1 ms: thin and fast
        t.add_link(a, b, 1_000.0, 10.0); // 1 Gbps, 10 ms: fat and slower
        t.add_link(b, c, 1_000.0, 10.0);
        (t, a, c)
    }

    #[test]
    fn transfer_time_routing_diverges_from_latency_on_big_payloads() {
        let (t, a, c) = thin_shortcut();
        // Latency routing: 1 ms direct beats 20 ms via b, at any size.
        assert_eq!(RouteTable::latency(&t).path(a, c).unwrap().len(), 1);
        // Tiny payload: serialization is negligible, shortcut still wins.
        let small = RouteTable::transfer_time(&t, 100);
        assert_eq!(small.path(a, c).unwrap().len(), 1);
        // 1 MB: 8 s on the 1 Mbps shortcut vs ~36 ms via b — the
        // bandwidth-aware table must leave the thin link.
        let big = RouteTable::transfer_time(&t, 1_000_000);
        assert_eq!(big.path(a, c).unwrap().len(), 2);
    }

    #[test]
    fn des_delivers_faster_on_transfer_time_routes() {
        // Regression for the ROADMAP open item: ride the same 1 MB
        // transfer through the DES on both route tables; the
        // bandwidth-aware route must deliver strictly (and dramatically)
        // earlier than the latency-shortest one.
        let (t, a, c) = thin_shortcut();
        let lat = RouteTable::latency(&t);
        let tt = RouteTable::transfer_time(&t, 1_000_000);
        let run_on = |rt: &RouteTable| {
            let mut sim = crate::netsim::NetSim::new(&t);
            sim.submit(rt, a, c, 1_000_000, 0.0).unwrap();
            sim.run()[0].latency_s()
        };
        let on_latency_route = run_on(&lat);
        let on_transfer_route = run_on(&tt);
        assert!((on_latency_route - 8.001).abs() < 1e-9, "{on_latency_route}");
        assert!(
            on_transfer_route < on_latency_route / 100.0,
            "{on_transfer_route} vs {on_latency_route}"
        );
    }

    #[test]
    fn transfer_time_matches_latency_on_uniform_links() {
        // When every link has the same bandwidth, serialization adds a
        // uniform per-hop cost and the latency differences decide the
        // route exactly as they do for pure latency weighting.
        let (t, a, _b, c) = diamond();
        let lat = RouteTable::latency(&t);
        let tt = RouteTable::transfer_time(&t, 50_000);
        assert_eq!(lat.path(a, c).unwrap(), tt.path(a, c).unwrap());
    }

    #[test]
    fn symmetric_hop_distance() {
        let (t, a, b, c) = diamond();
        let rt = RouteTable::hops(&t);
        for (x, y) in [(a, b), (b, c), (a, c)] {
            assert_eq!(rt.dist(x, y), rt.dist(y, x));
        }
    }
}
