//! Deterministic pseudo-random numbers (SplitMix64 + Xoshiro256**).
//!
//! Every stochastic decision in the coordinator — client sampling, data
//! partitioning, migration order, synthetic pixels — flows through this
//! module with an explicit seed, so whole experiments replay bit-exactly.
//! No crates.io RNG is vendored in this image; the generators below are
//! the reference implementations of Blackman & Vigna.

use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::{f64_from_hex, f64_to_hex, u64_from_hex, u64_to_hex};

/// SplitMix64 — used for seeding and cheap stateless streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

/// Serializable generator state: everything a [`Rng`] needs to continue
/// its stream bit-exactly after a checkpoint/resume cycle (the xoshiro
/// words plus the cached Box-Muller spare).
#[derive(Debug, Clone, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare_normal: Option<f64>,
}

impl RngState {
    /// Checkpoint-grade JSON: u64 words and the f64 spare travel as hex
    /// bit patterns (JSON numbers top out at 2^53 of integer precision).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("s", Json::arr(self.s.iter().map(|&w| Json::from(u64_to_hex(w))))),
            (
                "spare_normal",
                match self.spare_normal {
                    Some(v) => Json::from(f64_to_hex(v)),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RngState> {
        let arr = j
            .req("s")?
            .as_arr()
            .ok_or_else(|| Error::Json("rng state field \"s\" must be an array".into()))?;
        if arr.len() != 4 {
            return Err(Error::Json(format!(
                "rng state has {} words, want 4",
                arr.len()
            )));
        }
        let mut s = [0u64; 4];
        for (i, v) in arr.iter().enumerate() {
            s[i] = u64_from_hex(v.as_str().ok_or_else(|| {
                Error::Json("rng state word must be a hex string".into())
            })?)?;
        }
        let spare_normal = match j.req("spare_normal")? {
            Json::Null => None,
            v => Some(f64_from_hex(v.as_str().ok_or_else(|| {
                Error::Json("spare_normal must be a hex string".into())
            })?)?),
        };
        Ok(RngState { s, spare_normal })
    }
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Rng {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Independent child stream (for per-client / per-cluster RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the stream position (checkpoint/resume).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator mid-stream from a [`RngState`] snapshot; the
    /// continuation is bit-identical to the uninterrupted stream.
    pub fn from_state(st: &RngState) -> Rng {
        Rng { s: st.s, spare_normal: st.spare_normal }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, n)` (Lemire's method).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Index drawn from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total weight");
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Symmetric Dirichlet draw (used by alpha-skew partitions).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        // Gamma(alpha) via Marsaglia-Tsang (with boost for alpha < 1).
        let mut out: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = out.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut out {
            *v /= sum;
        }
        out
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u: f64 = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn xoshiro_reference_vector() {
        // Reference: seed state from SplitMix64(0) and check stability of
        // our implementation (golden values captured from this impl).
        let mut r = Rng::new(0);
        let v: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
        let mut r2 = Rng::new(0);
        let v2: Vec<u64> = (0..3).map(|_| r2.next_u64()).collect();
        assert_eq!(v, v2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(23);
        for alpha in [0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            assert_eq!(p.len(), 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "alpha={alpha} sum={s}");
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(29);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn state_snapshot_resumes_bit_identically() {
        let mut a = Rng::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        a.normal(); // park a spare in the Box-Muller cache
        let snap = a.state();
        let mut b = Rng::from_state(&snap);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.normal(), b.normal());
    }

    #[test]
    fn state_json_roundtrips() {
        let mut r = Rng::new(7);
        r.normal();
        let st = r.state();
        let back = RngState::from_json(&st.to_json()).unwrap();
        assert_eq!(back, st);
        // parse through text too (what a checkpoint file does)
        let text = st.to_json().dump();
        let reparsed =
            RngState::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(reparsed, st);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
