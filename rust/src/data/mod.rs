//! Data substrate: synthetic datasets, non-IID partitioning, batch loading.
//!
//! The image has no network access, so FashionMNIST / CIFAR-10 are replaced
//! by procedurally-generated class-conditional datasets ([`synth`]) that
//! preserve what the paper's evaluation actually exercises: 10-way
//! separability, per-class sample pools for the non-IID partitioner, and a
//! non-trivially learnable signal.  See DESIGN.md §3.

pub mod dataset;
pub mod loader;
pub mod partition;
pub mod synth;

pub use dataset::{Batch, Dataset};
pub use loader::ClientLoader;
pub use partition::{build_federation, ClientSpec, Federation};
