//! In-memory image dataset with contiguous f32 storage.

/// A labelled image dataset.  Pixels are stored contiguously per sample in
/// `[H, W, C]` row-major order, values already normalized to `[0, 1]`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
    images: Vec<f32>,
    labels: Vec<u32>,
}

/// A gathered minibatch: `x` is `[B, H, W, C]` flat, `y` is `[B]`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl Dataset {
    pub fn new(h: usize, w: usize, c: usize, classes: usize) -> Dataset {
        Dataset { h, w, c, classes, images: Vec::new(), labels: Vec::new() }
    }

    /// Pixels per sample.
    pub fn sample_len(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Append one sample; `pixels.len()` must equal `sample_len()`.
    pub fn push(&mut self, pixels: &[f32], label: u32) {
        assert_eq!(pixels.len(), self.sample_len(), "bad sample size");
        assert!((label as usize) < self.classes, "label out of range");
        self.images.extend_from_slice(pixels);
        self.labels.push(label);
    }

    pub fn label(&self, i: usize) -> u32 {
        self.labels[i]
    }

    pub fn pixels(&self, i: usize) -> &[f32] {
        let n = self.sample_len();
        &self.images[i * n..(i + 1) * n]
    }

    /// Count samples per class.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }

    /// Gather the given sample indices into one batch buffer.
    pub fn gather(&self, idx: &[usize]) -> Batch {
        let n = self.sample_len();
        let mut x = Vec::with_capacity(idx.len() * n);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.pixels(i));
            y.push(self.labels[i] as i32);
        }
        Batch { x, y }
    }

    /// Gather with padding: repeats the final sample to fill `target` rows
    /// (used for the fixed-shape eval executable's last partial batch).
    /// Returns the batch and the number of real (non-padding) rows.
    pub fn gather_padded(&self, idx: &[usize], target: usize) -> (Batch, usize) {
        assert!(!idx.is_empty() && idx.len() <= target);
        let mut full = idx.to_vec();
        while full.len() < target {
            // lint:allow(panic-reachability): unreachable — the assert
            // above guarantees idx is non-empty.
            full.push(*idx.last().unwrap());
        }
        (self.gather(&full), idx.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let mut d = Dataset::new(2, 2, 1, 3);
        d.push(&[0.0, 0.1, 0.2, 0.3], 0);
        d.push(&[1.0, 1.1, 1.2, 1.3], 1);
        d.push(&[2.0, 2.1, 2.2, 2.3], 2);
        d
    }

    #[test]
    fn push_and_access() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.label(1), 1);
        assert_eq!(d.pixels(2), &[2.0, 2.1, 2.2, 2.3]);
        assert_eq!(d.class_histogram(), vec![1, 1, 1]);
    }

    #[test]
    fn gather_orders_samples() {
        let d = tiny();
        let b = d.gather(&[2, 0]);
        assert_eq!(b.y, vec![2, 0]);
        assert_eq!(&b.x[..4], &[2.0, 2.1, 2.2, 2.3]);
        assert_eq!(&b.x[4..], &[0.0, 0.1, 0.2, 0.3]);
    }

    #[test]
    fn gather_padded_repeats_last() {
        let d = tiny();
        let (b, real) = d.gather_padded(&[1], 3);
        assert_eq!(real, 1);
        assert_eq!(b.y, vec![1, 1, 1]);
    }

    #[test]
    #[should_panic(expected = "bad sample size")]
    fn rejects_bad_sample() {
        let mut d = Dataset::new(2, 2, 1, 3);
        d.push(&[0.0], 0);
    }
}
