//! Procedural class-conditional image generators.
//!
//! Stand-ins for FashionMNIST (`SynthFashion`, 28x28x1) and CIFAR-10
//! (`SynthCifar`, 32x32x3): each of the 10 classes has a distinct
//! procedural motif (oriented gratings x Gaussian blobs x radial rings,
//! all class-parameterized), and every sample adds per-sample jitter —
//! phase shifts, blob displacement, amplitude scaling, pixel noise — so
//! classes are separable but not trivially so.  `SynthCifar` uses three
//! color channels with class-conditional color mixing and *stronger*
//! jitter, preserving the paper's "CIFAR-10 is harder" ordering.
//!
//! Generation is deterministic in `(kind, seed, class, index)`.

use crate::config::DatasetKind;
use crate::data::dataset::Dataset;
use crate::rng::Rng;

/// Per-class motif parameters (fixed per dataset seed).
#[derive(Debug, Clone)]
struct ClassMotif {
    /// Grating frequency (cycles across the image).
    freq: f64,
    /// Grating orientation in radians.
    angle: f64,
    /// Blob center in unit coordinates.
    blob: (f64, f64),
    /// Blob radius.
    radius: f64,
    /// Ring frequency for the radial component.
    ring_freq: f64,
    /// Per-channel color weights (len = channels).
    color: Vec<f64>,
}

/// Synthetic dataset generator.
pub struct SynthGen {
    kind: DatasetKind,
    motifs: Vec<ClassMotif>,
    /// Per-sample noise sigma.
    noise: f64,
    /// Jitter scale (translation/phase).
    jitter: f64,
    seed: u64,
}

impl SynthGen {
    pub fn new(kind: DatasetKind, seed: u64) -> SynthGen {
        let (_, _, c) = kind.image();
        let mut rng = Rng::new(seed ^ 0x5EED_DA7A);
        let classes = kind.classes();
        let mut motifs = Vec::with_capacity(classes);
        for class in 0..classes {
            // Class-keyed structure plus a small seeded perturbation: classes
            // keep distinct frequency/orientation bands across seeds.
            let f = class as f64;
            motifs.push(ClassMotif {
                freq: 2.0 + (f % 5.0) * 1.5 + rng.range(-0.2, 0.2),
                angle: f * std::f64::consts::PI / 10.0 + rng.range(-0.05, 0.05),
                blob: (
                    0.25 + 0.5 * ((f * 7.0) % 10.0) / 10.0,
                    0.25 + 0.5 * ((f * 3.0) % 10.0) / 10.0,
                ),
                radius: 0.12 + 0.05 * ((f * 13.0) % 10.0) / 10.0,
                ring_freq: 3.0 + (f % 3.0) * 2.0,
                color: (0..c)
                    .map(|ch| {
                        0.35 + 0.65 * (((f + 1.0) * (ch as f64 + 2.0) * 17.0) % 10.0) / 10.0
                    })
                    .collect(),
            });
        }
        let (noise, jitter) = match kind {
            DatasetKind::SynthFashion => (0.10, 0.06),
            DatasetKind::SynthCifar => (0.18, 0.12),
        };
        SynthGen { kind, motifs, noise, jitter, seed }
    }

    /// Generate sample `index` of `class` into `out` (len = H*W*C).
    pub fn render(&self, class: usize, index: u64, out: &mut [f32]) {
        let (h, w, c) = self.kind.image();
        assert_eq!(out.len(), h * w * c);
        let m = &self.motifs[class];
        // Per-sample jitter stream.
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add((class as u64) << 32)
                .wrapping_add(index),
        );
        let phase = rng.range(0.0, std::f64::consts::TAU);
        let dx = rng.range(-self.jitter, self.jitter);
        let dy = rng.range(-self.jitter, self.jitter);
        let amp = rng.range(0.75, 1.05);
        let angle = m.angle + rng.range(-0.08, 0.08);
        let (sin_a, cos_a) = angle.sin_cos();
        let bx = m.blob.0 + dx;
        let by = m.blob.1 + dy;
        let inv_r2 = 1.0 / (2.0 * m.radius * m.radius);

        for y in 0..h {
            let fy = y as f64 / h as f64;
            for x in 0..w {
                let fx = x as f64 / w as f64;
                // Rotated coordinate for the grating.
                let u = fx * cos_a + fy * sin_a;
                let grating = (std::f64::consts::TAU * m.freq * u + phase).sin();
                // Gaussian blob.
                let d2 = (fx - bx) * (fx - bx) + (fy - by) * (fy - by);
                let blob = (-d2 * inv_r2).exp();
                // Radial rings around the blob center.
                let ring = (std::f64::consts::TAU * m.ring_freq * d2.sqrt() * 4.0).cos();
                let base = 0.45 + amp * (0.22 * grating + 0.38 * blob + 0.12 * ring * blob);
                for ch in 0..c {
                    let cw = m.color[ch];
                    let v = base * cw + self.noise * rng.normal();
                    out[(y * w + x) * c + ch] = v.clamp(0.0, 1.0) as f32;
                }
            }
        }
    }

    /// Build a dataset with exactly `per_class[c]` samples of each class,
    /// using sample indices starting at `index_base[c]` (so train/test draws
    /// never collide).  Samples are appended class-by-class.
    pub fn generate(&self, per_class: &[usize], index_base: &[u64]) -> Dataset {
        let (h, w, c) = self.kind.image();
        let mut ds = Dataset::new(h, w, c, self.kind.classes());
        let mut buf = vec![0f32; h * w * c];
        for (class, &n) in per_class.iter().enumerate() {
            for i in 0..n {
                self.render(class, index_base[class] + i as u64, &mut buf);
                ds.push(&buf, class as u32);
            }
        }
        ds
    }

    /// Balanced test set of `total` samples (rounded up to a multiple of
    /// the class count), drawn from a disjoint index range above `2^40`.
    pub fn test_set(&self, total: usize) -> Dataset {
        let classes = self.kind.classes();
        let per = total.div_ceil(classes);
        let per_class = vec![per; classes];
        let base = vec![1u64 << 40; classes];
        self.generate(&per_class, &base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        let g = SynthGen::new(DatasetKind::SynthFashion, 7);
        let mut a = vec![0f32; 28 * 28];
        let mut b = vec![0f32; 28 * 28];
        g.render(3, 42, &mut a);
        g.render(3, 42, &mut b);
        assert_eq!(a, b);
        g.render(3, 43, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn seed_changes_samples() {
        let g1 = SynthGen::new(DatasetKind::SynthFashion, 1);
        let g2 = SynthGen::new(DatasetKind::SynthFashion, 2);
        let mut a = vec![0f32; 28 * 28];
        let mut b = vec![0f32; 28 * 28];
        g1.render(0, 0, &mut a);
        g2.render(0, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn pixels_in_unit_range() {
        let g = SynthGen::new(DatasetKind::SynthCifar, 3);
        let mut buf = vec![0f32; 32 * 32 * 3];
        for class in 0..10 {
            g.render(class, class as u64, &mut buf);
            assert!(buf.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn classes_are_distinguishable_by_nearest_centroid() {
        // A sanity floor: class centroids must classify held-out samples
        // far above chance (10%).  This guards the generator's class
        // signal without training a model.
        for kind in [DatasetKind::SynthFashion, DatasetKind::SynthCifar] {
            let g = SynthGen::new(kind, 11);
            let (h, w, c) = kind.image();
            let dim = h * w * c;
            let per_train = 30usize;
            let mut centroids = vec![vec![0f64; dim]; 10];
            let mut buf = vec![0f32; dim];
            for class in 0..10 {
                for i in 0..per_train {
                    g.render(class, i as u64, &mut buf);
                    for (acc, &v) in centroids[class].iter_mut().zip(&buf) {
                        *acc += v as f64;
                    }
                }
                for v in &mut centroids[class] {
                    *v /= per_train as f64;
                }
            }
            let mut correct = 0;
            let total = 10 * 20;
            for class in 0..10 {
                for i in 0..20 {
                    g.render(class, 10_000 + i as u64, &mut buf);
                    let best = (0..10)
                        .min_by(|&a, &b| {
                            let da: f64 = centroids[a]
                                .iter()
                                .zip(&buf)
                                .map(|(m, &v)| (m - v as f64).powi(2))
                                .sum();
                            let db: f64 = centroids[b]
                                .iter()
                                .zip(&buf)
                                .map(|(m, &v)| (m - v as f64).powi(2))
                                .sum();
                            da.total_cmp(&db)
                        })
                        .unwrap();
                    if best == class {
                        correct += 1;
                    }
                }
            }
            let acc = correct as f64 / total as f64;
            assert!(acc > 0.5, "{kind:?}: nearest-centroid acc {acc} too low");
        }
    }

    #[test]
    fn cifar_has_higher_intra_class_variance() {
        // The "CIFAR-10 is harder" ordering comes from higher noise+jitter,
        // which must show up as larger per-pixel std within a class.
        let intra_std = |kind: DatasetKind| {
            let g = SynthGen::new(kind, 5);
            let (h, w, c) = kind.image();
            let dim = h * w * c;
            let n = 40usize;
            let mut buf = vec![0f32; dim];
            let mut sum = vec![0f64; dim];
            let mut sumsq = vec![0f64; dim];
            for i in 0..n {
                g.render(0, i as u64, &mut buf);
                for (j, &v) in buf.iter().enumerate() {
                    sum[j] += v as f64;
                    sumsq[j] += (v as f64) * (v as f64);
                }
            }
            (0..dim)
                .map(|j| {
                    let m = sum[j] / n as f64;
                    (sumsq[j] / n as f64 - m * m).max(0.0).sqrt()
                })
                .sum::<f64>()
                / dim as f64
        };
        assert!(
            intra_std(DatasetKind::SynthCifar) > intra_std(DatasetKind::SynthFashion),
            "cifar should be noisier"
        );
    }

    #[test]
    fn generate_respects_per_class_counts() {
        let g = SynthGen::new(DatasetKind::SynthFashion, 13);
        let ds = g.generate(&[3, 0, 5, 0, 0, 0, 0, 0, 0, 1], &[0; 10]);
        assert_eq!(ds.len(), 9);
        assert_eq!(ds.class_histogram(), vec![3, 0, 5, 0, 0, 0, 0, 0, 0, 1]);
    }

    #[test]
    fn test_set_is_balanced() {
        let g = SynthGen::new(DatasetKind::SynthFashion, 17);
        let ds = g.test_set(95);
        let h = ds.class_histogram();
        assert!(h.iter().all(|&n| n == 10), "{h:?}");
    }
}
