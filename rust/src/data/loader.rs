//! Seeded per-client minibatch streams.
//!
//! The paper's local update (Eq. 2) samples a fresh random minibatch
//! `ξ ⊂ D_n` at every local step; the loader reproduces that: each call
//! yields `K` batches of `B` sample indices drawn from the client's
//! partition (without replacement within a batch, with replacement across
//! batches), deterministically from `(seed, client, round)`.

use crate::data::dataset::{Batch, Dataset};
use crate::data::partition::ClientSpec;
use crate::rng::Rng;

/// Stateless minibatch sampler for one federation.
#[derive(Debug, Clone)]
pub struct ClientLoader {
    seed: u64,
    batch: usize,
}

impl ClientLoader {
    pub fn new(seed: u64, batch: usize) -> ClientLoader {
        assert!(batch > 0);
        ClientLoader { seed, batch }
    }

    /// Index batches for `k` local steps of `client` at `round`.
    pub fn batches_idx(&self, client: &ClientSpec, round: usize, k: usize) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x2545F4914F6CDD1D)
                .wrapping_add((client.id as u64) << 24)
                .wrapping_add(round as u64),
        );
        let n = client.samples.len();
        (0..k)
            .map(|_| {
                if n >= self.batch {
                    rng.sample_indices(n, self.batch)
                        .into_iter()
                        .map(|j| client.samples[j])
                        .collect()
                } else {
                    // Degenerate tiny client: sample with replacement.
                    (0..self.batch)
                        .map(|_| client.samples[rng.below(n)])
                        .collect()
                }
            })
            .collect()
    }

    /// Gathered `[K*B]` super-batch for the `local_update` executable:
    /// `x` is `[K, B, H, W, C]` flat, `y` is `[K, B]` flat.
    pub fn local_batches(
        &self,
        train: &Dataset,
        client: &ClientSpec,
        round: usize,
        k: usize,
    ) -> Batch {
        let idx: Vec<usize> = self
            .batches_idx(client, round, k)
            .into_iter()
            .flatten()
            .collect();
        train.gather(&idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetKind, Distribution};
    use crate::data::partition::build_federation;

    fn fed() -> crate::data::partition::Federation {
        build_federation(
            DatasetKind::SynthFashion,
            &Distribution::Iid,
            4,
            2,
            40,
            20,
            3,
        )
        .unwrap()
    }

    #[test]
    fn batches_are_deterministic_per_round() {
        let f = fed();
        let l = ClientLoader::new(9, 8);
        let a = l.batches_idx(&f.clients[0], 5, 3);
        let b = l.batches_idx(&f.clients[0], 5, 3);
        assert_eq!(a, b);
        let c = l.batches_idx(&f.clients[0], 6, 3);
        assert_ne!(a, c);
    }

    #[test]
    fn batches_stay_inside_partition() {
        let f = fed();
        let l = ClientLoader::new(9, 8);
        for client in &f.clients {
            for batch in l.batches_idx(client, 0, 4) {
                assert_eq!(batch.len(), 8);
                for i in batch {
                    assert!(client.samples.contains(&i));
                }
            }
        }
    }

    #[test]
    fn no_duplicates_within_batch_when_possible() {
        let f = fed();
        let l = ClientLoader::new(9, 8);
        for batch in l.batches_idx(&f.clients[1], 2, 5) {
            let mut d = batch.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), batch.len());
        }
    }

    #[test]
    fn tiny_client_samples_with_replacement() {
        let f = fed();
        let mut small = f.clients[0].clone();
        small.samples.truncate(3);
        let l = ClientLoader::new(9, 8);
        let b = l.batches_idx(&small, 0, 1);
        assert_eq!(b[0].len(), 8); // filled despite only 3 samples
    }

    #[test]
    fn local_batches_shapes() {
        let f = fed();
        let l = ClientLoader::new(9, 8);
        let b = l.local_batches(&f.train, &f.clients[0], 0, 3);
        assert_eq!(b.y.len(), 3 * 8);
        assert_eq!(b.x.len(), 3 * 8 * f.train.sample_len());
    }

    #[test]
    fn different_clients_get_different_batches() {
        let f = fed();
        let l = ClientLoader::new(9, 8);
        let a = l.batches_idx(&f.clients[0], 0, 1);
        let b = l.batches_idx(&f.clients[1], 0, 1);
        assert_ne!(a, b);
    }
}
