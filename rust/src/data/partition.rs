//! Client data partitioning: IID, x%-non-IID, and the paper's NIID A/B
//! mixes (§IV.A, Fig 2), with exactly-once sample assignment.
//!
//! The builder first computes per-client *class quotas*, then synthesizes
//! exactly the demanded number of samples per class and hands out disjoint
//! index ranges — so "every sample belongs to exactly one client" holds by
//! construction (and is property-tested in `rust/tests/prop_coordinator.rs`).

use crate::config::{DatasetKind, Distribution};
use crate::data::dataset::Dataset;
use crate::data::synth::SynthGen;
use crate::rng::Rng;
use crate::util::error::{Error, Result};

/// Per-client partition description.
#[derive(Debug, Clone)]
pub struct ClientSpec {
    pub id: usize,
    /// Cluster (edge base station) this client is anchored to.
    pub cluster: usize,
    /// Samples of each class this client owns.
    pub quotas: Vec<usize>,
    /// Indices into the federation's train dataset (disjoint across clients).
    pub samples: Vec<usize>,
    /// The concrete distribution this client was assigned (after mix
    /// presets are expanded and shuffled).
    pub distribution: Distribution,
}

impl ClientSpec {
    /// Class histogram of this client's data (== quotas by construction).
    pub fn histogram(&self, train: &Dataset) -> Vec<usize> {
        let mut h = vec![0usize; train.classes];
        for &i in &self.samples {
            h[train.label(i) as usize] += 1;
        }
        h
    }
}

/// A fully-materialized federated dataset.
#[derive(Debug)]
pub struct Federation {
    pub train: Dataset,
    pub test: Dataset,
    pub clients: Vec<ClientSpec>,
    pub clusters: usize,
}

impl Federation {
    /// Client ids in cluster `m`.
    pub fn cluster_members(&self, m: usize) -> Vec<usize> {
        self.clients
            .iter()
            .filter(|c| c.cluster == m)
            .map(|c| c.id)
            .collect()
    }
}

/// Compute one client's class quotas for a distribution.
fn client_quotas(
    dist: &Distribution,
    classes: usize,
    samples: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    match dist {
        Distribution::Iid => spread_uniform(samples, classes, rng),
        Distribution::NonIid { major_fraction } => {
            let mut q = vec![0usize; classes];
            // 1 or 2 major categories (paper: "one or two major categories").
            let n_major = 1 + rng.below(2);
            let majors = rng.sample_indices(classes, n_major);
            let major_total =
                ((*major_fraction) * samples as f64).round() as usize;
            let major_total = major_total.min(samples);
            // Split the major mass across the chosen majors.
            for (i, &m) in majors.iter().enumerate() {
                q[m] += major_total / n_major + usize::from(i < major_total % n_major);
            }
            // Remainder spread over the non-major classes.
            let rest = samples - major_total;
            if rest > 0 {
                let others: Vec<usize> =
                    (0..classes).filter(|c| !majors.contains(c)).collect();
                let spread = spread_uniform(rest, others.len(), rng);
                for (slot, &cls) in others.iter().enumerate() {
                    q[cls] += spread[slot];
                }
            }
            q
        }
        Distribution::NiidA | Distribution::NiidB => {
            unreachable!("mix presets are expanded per-client in build_federation")
        }
    }
}

/// Spread `total` samples uniformly over `bins`, randomizing which bins get
/// the +1 remainder.
fn spread_uniform(total: usize, bins: usize, rng: &mut Rng) -> Vec<usize> {
    let base = total / bins;
    let extra = total % bins;
    let mut q = vec![base; bins];
    for &i in rng.sample_indices(bins, extra).iter() {
        q[i] += 1;
    }
    q
}

/// Expand a (possibly mixed) distribution into one concrete per-client
/// distribution assignment.  Paper presets scale with the client count:
/// NIID A = 10% IID + 20% @95% + 70% @98%; NIID B = 10% IID + 90% @100%.
pub fn expand_distribution(dist: &Distribution, clients: usize) -> Vec<Distribution> {
    match dist {
        Distribution::NiidA => {
            let n_iid = clients / 10;
            let n_95 = clients * 2 / 10;
            (0..clients)
                .map(|i| {
                    if i < n_iid {
                        Distribution::Iid
                    } else if i < n_iid + n_95 {
                        Distribution::NonIid { major_fraction: 0.95 }
                    } else {
                        Distribution::NonIid { major_fraction: 0.98 }
                    }
                })
                .collect()
        }
        Distribution::NiidB => {
            let n_iid = clients / 10;
            (0..clients)
                .map(|i| {
                    if i < n_iid {
                        Distribution::Iid
                    } else {
                        Distribution::NonIid { major_fraction: 1.0 }
                    }
                })
                .collect()
        }
        other => vec![other.clone(); clients],
    }
}

/// Build the complete federation: quotas -> synthesis -> disjoint
/// assignment -> shuffled fixed clusters.
pub fn build_federation(
    kind: DatasetKind,
    dist: &Distribution,
    clients: usize,
    clusters: usize,
    samples_per_client: usize,
    test_samples: usize,
    seed: u64,
) -> Result<Federation> {
    if clients == 0 || clusters == 0 || clients % clusters != 0 {
        return Err(Error::Data(format!(
            "bad federation shape: {clients} clients / {clusters} clusters"
        )));
    }
    let classes = kind.classes();
    let mut rng = Rng::new(seed ^ 0xFEDE_7A7E);

    // 1. Per-client quotas.  Clusters are *geographic* (client id maps to
    //    the base station it is radio-attached to, cluster-major — the
    //    same layout `topology::builder` uses), so instead of shuffling
    //    cluster membership we shuffle which client gets which
    //    distribution, keeping mix presets from degenerating into
    //    "cluster 0 = all the IID clients".
    let mut per_client_dist = expand_distribution(dist, clients);
    rng.shuffle(&mut per_client_dist);
    let quotas: Vec<Vec<usize>> = per_client_dist
        .iter()
        .map(|d| client_quotas(d, classes, samples_per_client, &mut rng))
        .collect();

    // 2. Synthesize exactly the demanded samples per class.
    let mut class_totals = vec![0usize; classes];
    for q in &quotas {
        for (c, n) in q.iter().enumerate() {
            class_totals[c] += n;
        }
    }
    let gen = SynthGen::new(kind, seed);
    let train = gen.generate(&class_totals, &vec![0u64; classes]);
    let test = gen.test_set(test_samples);

    // Class offsets in the (class-contiguous) train dataset.
    let mut offsets = vec![0usize; classes + 1];
    for c in 0..classes {
        offsets[c + 1] = offsets[c] + class_totals[c];
    }

    // 3. Disjoint index assignment.
    let mut cursors = offsets[..classes].to_vec();
    // 4. Fixed geographic clusters: client id -> base station, matching
    //    the topology builder's cluster-major client layout.
    let cluster_size = clients / clusters;
    let cluster_of: Vec<usize> = (0..clients).map(|i| i / cluster_size).collect();

    let mut specs = Vec::with_capacity(clients);
    for (id, q) in quotas.into_iter().enumerate() {
        let mut samples = Vec::with_capacity(samples_per_client);
        for (c, &n) in q.iter().enumerate() {
            for _ in 0..n {
                samples.push(cursors[c]);
                cursors[c] += 1;
            }
        }
        rng.shuffle(&mut samples);
        specs.push(ClientSpec {
            id,
            cluster: cluster_of[id],
            quotas: q,
            samples,
            distribution: per_client_dist[id].clone(),
        });
    }
    debug_assert_eq!(cursors, offsets[1..].to_vec());

    Ok(Federation { train, test, clients: specs, clusters })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed(dist: Distribution) -> Federation {
        build_federation(
            DatasetKind::SynthFashion,
            &dist,
            20,
            4,
            60,
            50,
            7,
        )
        .unwrap()
    }

    #[test]
    fn iid_quotas_are_uniformish() {
        let f = fed(Distribution::Iid);
        for c in &f.clients {
            assert_eq!(c.quotas.iter().sum::<usize>(), 60);
            assert!(c.quotas.iter().all(|&n| n == 6), "{:?}", c.quotas);
        }
    }

    #[test]
    fn noniid_quotas_concentrate() {
        let f = fed(Distribution::NonIid { major_fraction: 0.95 });
        for c in &f.clients {
            let total: usize = c.quotas.iter().sum();
            assert_eq!(total, 60);
            let mut sorted = c.quotas.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            let major2: usize = sorted[..2].iter().sum();
            assert!(
                major2 >= (0.95f64 * 60.0) as usize,
                "top-2 classes hold {major2}/60"
            );
        }
    }

    #[test]
    fn full_noniid_single_or_double_class() {
        let f = fed(Distribution::NonIid { major_fraction: 1.0 });
        for c in &f.clients {
            let nonzero = c.quotas.iter().filter(|&&n| n > 0).count();
            assert!(nonzero <= 2, "{:?}", c.quotas);
        }
    }

    #[test]
    fn niid_a_mix_fractions() {
        let dists = expand_distribution(&Distribution::NiidA, 100);
        let iid = dists.iter().filter(|d| **d == Distribution::Iid).count();
        let p95 = dists
            .iter()
            .filter(|d| **d == Distribution::NonIid { major_fraction: 0.95 })
            .count();
        let p98 = dists
            .iter()
            .filter(|d| **d == Distribution::NonIid { major_fraction: 0.98 })
            .count();
        assert_eq!((iid, p95, p98), (10, 20, 70));
    }

    #[test]
    fn niid_b_mix_fractions() {
        let dists = expand_distribution(&Distribution::NiidB, 100);
        let iid = dists.iter().filter(|d| **d == Distribution::Iid).count();
        assert_eq!(iid, 10);
        assert_eq!(dists.len(), 100);
    }

    #[test]
    fn samples_are_disjoint_and_exhaustive() {
        let f = fed(Distribution::NiidA);
        let mut seen = vec![false; f.train.len()];
        for c in &f.clients {
            for &i in &c.samples {
                assert!(!seen[i], "sample {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "unassigned samples remain");
    }

    #[test]
    fn quotas_match_actual_labels() {
        let f = fed(Distribution::NiidB);
        for c in &f.clients {
            assert_eq!(c.histogram(&f.train), c.quotas, "client {}", c.id);
        }
    }

    #[test]
    fn clusters_are_balanced() {
        let f = fed(Distribution::Iid);
        for m in 0..4 {
            assert_eq!(f.cluster_members(m).len(), 5);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = fed(Distribution::NiidA);
        let b = fed(Distribution::NiidA);
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.samples, y.samples);
            assert_eq!(x.cluster, y.cluster);
        }
    }

    #[test]
    fn rejects_bad_shape() {
        assert!(build_federation(
            DatasetKind::SynthFashion,
            &Distribution::Iid,
            10,
            3,
            60,
            50,
            0
        )
        .is_err());
    }
}
