//! Typed gate for the PJRT/XLA runtime.
//!
//! The real backend (the `xla` crate over the `xla_extension` 0.5.1 C
//! library) is a native dependency this offline image does not vendor.
//! This crate carries the exact API subset `edgeflow::runtime` compiles
//! against, and **fails at `PjRtClient::cpu()`** with an actionable
//! message — so the whole coordinator stack (data, topology, netsim,
//! strategies, aggregation, pool, CLI plumbing) builds and its tests run
//! without the native runtime, while everything artifact-driven degrades
//! to a clean runtime error / test skip instead of a link failure.
//!
//! Swapping in the real crate is a one-line Cargo.toml change.  The
//! parallel round loop requires the binding's handle types to be
//! `Send + Sync`; a compile-time assertion in
//! `edgeflow::runtime::executor` rejects thread-unsafe bindings.

use std::fmt;
use std::path::Path;

/// XLA/PJRT error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: this build uses the typed xla stub \
         (rust/vendor/xla). Link the real `xla` crate / xla_extension \
         native library to execute artifacts."
            .to_string(),
    )
}

/// PJRT client handle (stub: construction fails).
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with borrowed input buffers; `[replica][output]` shape.
    pub fn execute_b(&self, _inputs: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation ready to compile.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (tuple/tensor view of an execution result).
pub struct Literal;

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("stub"));
    }
}
