//! Minimal offline stand-in for the `log` facade.
//!
//! This container image does not vendor crates.io, so the workspace
//! carries the subset of `log` 0.4's API that the edgeflow crate uses:
//! the five level macros, `Level`/`LevelFilter`, `Log`, `Record`,
//! `Metadata`, `set_boxed_logger` and `set_max_level`.  Swapping in the
//! real crate is a one-line Cargo.toml change; no source edits needed.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global verbosity ceiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Record metadata (level + target).
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when a logger is installed twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger (first install wins).
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current verbosity ceiling (as its numeric rank).
pub fn max_level_rank() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > max_level_rank() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, _: &Record) {
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filtered_records_do_not_reach_the_logger() {
        let _ = set_boxed_logger(Box::new(Counter));
        set_max_level(LevelFilter::Info);
        info!("counted {}", 1);
        debug!("not counted");
        assert_eq!(HITS.load(Ordering::Relaxed), 1);
        assert!(set_boxed_logger(Box::new(Counter)).is_err());
    }
}
