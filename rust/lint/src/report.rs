//! Machine-readable lint output and baseline diffing.
//!
//! `--format json` renders a [`crate::Report`] in a stable schema
//! (golden-tested; bump `VERSION` on any shape change):
//!
//! ```json
//! {
//!   "version": 2,
//!   "files_scanned": 42,
//!   "findings": [
//!     { "rule": "…", "file": "…", "line": 7,
//!       "pragma": "none" | "allowed",
//!       "message": "…", "snippet": "…",
//!       "witness": [
//!         { "fn": "Engine::load", "file": "…", "line": 12 }
//!       ] }
//!   ],
//!   "summary": { "violations": 2, "suppressed": 5,
//!                "suppressed_by_rule": { "unwrap-in-library": 4 } }
//! }
//! ```
//!
//! `witness` is the interprocedural rules' call chain: each hop names
//! a fn, its file, and — for intermediate hops — the line of the call
//! into the *next* hop; the terminal hop's line is the effect site
//! itself.  Local rules render an empty chain.
//!
//! `--baseline <file>` takes a previous JSON report and fails only on
//! findings that are *new* relative to it.  Identity is the multiset
//! of `(rule, file, snippet)` — deliberately not the line number, so
//! a pre-existing finding survives pure line shifts, but a second
//! occurrence of the same pattern in the same file still counts as
//! new.  Only `"pragma": "none"` entries participate: a suppression
//! that later loses its pragma is a new finding, as it should be.
//!
//! The crate is dependency-free, so this module carries its own
//! minimal recursive-descent JSON parser — it only ever reads the
//! tool's own output.

use std::collections::BTreeMap;

use crate::{Diagnostic, Report};

/// Schema version stamped into every JSON report.  v2 added the
/// per-finding `witness` chain and `summary.suppressed_by_rule`.
pub const VERSION: u64 = 2;

pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Render the stable JSON schema.  Findings are sorted by
/// (file, line, rule, pragma) so output is bit-stable run to run.
pub fn render_json(report: &Report) -> String {
    let mut findings: Vec<(&Diagnostic, &'static str)> = report
        .diagnostics
        .iter()
        .map(|d| (d, "none"))
        .chain(report.suppressed.iter().map(|d| (d, "allowed")))
        .collect();
    findings.sort_by(|(a, ap), (b, bp)| {
        (&a.file, a.line, a.rule, *ap).cmp(&(&b.file, b.line, b.rule, *bp))
    });

    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"version\": {VERSION},\n"));
    out.push_str(&format!(
        "  \"files_scanned\": {},\n",
        report.files_scanned
    ));
    out.push_str("  \"findings\": [");
    for (k, (d, pragma)) in findings.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str("\n    {\n");
        out.push_str(&format!("      \"rule\": \"{}\",\n", esc(d.rule.id())));
        out.push_str(&format!("      \"file\": \"{}\",\n", esc(&d.file)));
        out.push_str(&format!("      \"line\": {},\n", d.line));
        out.push_str(&format!("      \"pragma\": \"{pragma}\",\n"));
        out.push_str(&format!("      \"message\": \"{}\",\n", esc(&d.message)));
        out.push_str(&format!("      \"snippet\": \"{}\",\n", esc(&d.snippet)));
        out.push_str("      \"witness\": [");
        for (h, hop) in d.witness.iter().enumerate() {
            if h > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n        {{ \"fn\": \"{}\", \"file\": \"{}\", \"line\": {} }}",
                esc(&hop.func),
                esc(&hop.file),
                hop.line
            ));
        }
        if d.witness.is_empty() {
            out.push_str("]\n");
        } else {
            out.push_str("\n      ]\n");
        }
        out.push_str("    }");
    }
    if findings.is_empty() {
        out.push_str("],\n");
    } else {
        out.push_str("\n  ],\n");
    }
    let by_rule = suppressed_by_rule(report);
    out.push_str("  \"summary\": {\n");
    out.push_str(&format!(
        "    \"violations\": {},\n",
        report.diagnostics.len()
    ));
    out.push_str(&format!(
        "    \"suppressed\": {},\n",
        report.suppressed.len()
    ));
    out.push_str("    \"suppressed_by_rule\": {");
    for (k, (rule, n)) in by_rule.iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        out.push_str(&format!("\n      \"{rule}\": {n}"));
    }
    if by_rule.is_empty() {
        out.push_str("}\n");
    } else {
        out.push_str("\n    }\n");
    }
    out.push_str("  }\n}\n");
    out
}

/// Suppression counts per rule id, sorted by id — the pragma-debt
/// ledger the text summary and JSON both show.
pub fn suppressed_by_rule(report: &Report) -> Vec<(&'static str, usize)> {
    let mut by_rule: BTreeMap<&'static str, usize> = BTreeMap::new();
    for d in &report.suppressed {
        *by_rule.entry(d.rule.id()).or_insert(0) += 1;
    }
    by_rule.into_iter().collect()
}

/// One baseline entry: the identity triple of a previous finding.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub snippet: String,
}

/// Parse a previous `--format json` report into its baseline entries
/// (unsuppressed findings only).
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>, String> {
    let v = parse_json(text)?;
    let version = v
        .get("version")
        .and_then(Json::as_u64)
        .ok_or_else(|| "baseline: missing \"version\"".to_string())?;
    if version != VERSION {
        return Err(format!(
            "baseline: schema version {version} (this tool writes {VERSION})"
        ));
    }
    let findings = match v.get("findings") {
        Some(Json::Arr(items)) => items,
        _ => return Err("baseline: missing \"findings\" array".to_string()),
    };
    let mut out = Vec::new();
    for f in findings {
        let pragma = f
            .get("pragma")
            .and_then(Json::as_str)
            .unwrap_or("none");
        if pragma != "none" {
            continue;
        }
        let field = |k: &str| -> Result<String, String> {
            f.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("baseline: finding missing \"{k}\""))
        };
        out.push(BaselineEntry {
            rule: field("rule")?,
            file: field("file")?,
            snippet: field("snippet")?,
        });
    }
    Ok(out)
}

/// The report's violations that are NOT covered by the baseline,
/// multiset-style: a baseline entry absorbs at most one occurrence.
pub fn new_findings<'a>(
    report: &'a Report,
    baseline: &[BaselineEntry],
) -> Vec<&'a Diagnostic> {
    let mut budget: BTreeMap<(&str, &str, &str), usize> = BTreeMap::new();
    for b in baseline {
        *budget
            .entry((b.rule.as_str(), b.file.as_str(), b.snippet.as_str()))
            .or_insert(0) += 1;
    }
    let mut fresh = Vec::new();
    for d in &report.diagnostics {
        let key = (d.rule.id(), d.file.as_str(), d.snippet.as_str());
        match budget.get_mut(&key) {
            Some(n) if *n > 0 => *n -= 1,
            _ => fresh.push(d),
        }
    }
    fresh
}

// ---------------------------------------------------------------------------
// Minimal JSON parser (reads only this tool's own output).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_u64(&self) -> Option<u64> {
        match self {
            // lint:allow(float-ordering): exact integer-representability
            // check — a whole-valued f64 has fract() bit-equal to 0.0.
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }
}

fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = P {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("json: trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl P<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "json: expected {:?} at byte {}",
                c as char, self.i
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("json: bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
            _ => Err(format!("json: unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("json: expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("json: expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("json: unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| "json: bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "json: bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("json: bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Strings are valid UTF-8 by construction (&str input);
                    // copy the whole code point.
                    let s = &self.b[self.i..];
                    let text = std::str::from_utf8(s)
                        .map_err(|_| "json: invalid utf-8".to_string())?;
                    let c = text.chars().next().ok_or("json: truncated")?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.b.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self
            .b
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "json: invalid utf-8".to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("json: bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Rule;

    fn diag(rule: Rule, file: &str, line: usize, snippet: &str) -> Diagnostic {
        Diagnostic {
            file: file.to_string(),
            line,
            rule,
            message: format!("{} message", rule.id()),
            snippet: snippet.to_string(),
            witness: Vec::new(),
        }
    }

    fn report(diagnostics: Vec<Diagnostic>, suppressed: Vec<Diagnostic>, files: usize) -> Report {
        Report {
            diagnostics,
            suppressed,
            files_scanned: files,
            effects: crate::effects::EffectsSummary::default(),
        }
    }

    #[test]
    fn json_round_trips_through_own_parser() {
        let report = report(
            vec![diag(
                Rule::FloatOrdering,
                "rust/src/a.rs",
                3,
                "x.partial_cmp(&y) // \"quoted\"",
            )],
            vec![diag(Rule::UnwrapInLibrary, "rust/src/b.rs", 9, "v.unwrap()")],
            2,
        );
        let text = render_json(&report);
        let v = parse_json(&text).expect("own output parses");
        assert_eq!(v.get("version").and_then(Json::as_u64), Some(VERSION));
        assert_eq!(v.get("files_scanned").and_then(Json::as_u64), Some(2));
        let findings = match v.get("findings") {
            Some(Json::Arr(items)) => items,
            other => panic!("findings: {other:?}"),
        };
        assert_eq!(findings.len(), 2);
        assert_eq!(
            findings[0].get("snippet").and_then(Json::as_str),
            Some("x.partial_cmp(&y) // \"quoted\"")
        );
        assert_eq!(
            findings[1].get("pragma").and_then(Json::as_str),
            Some("allowed")
        );
        // Only the unsuppressed finding enters the baseline.
        let base = parse_baseline(&text).expect("baseline parses");
        assert_eq!(base.len(), 1);
        assert_eq!(base[0].rule, "float-ordering");
        // The per-rule suppression ledger is in the summary.
        let summary = v.get("summary").expect("summary");
        let by_rule = summary.get("suppressed_by_rule").expect("by_rule");
        assert_eq!(
            by_rule.get("unwrap-in-library").and_then(Json::as_u64),
            Some(1)
        );
    }

    #[test]
    fn witness_chains_round_trip_through_json() {
        let mut d = diag(
            Rule::TransitiveWallClock,
            "rust/src/fl/runner.rs",
            40,
            "pub fn drive() {",
        );
        d.witness = vec![
            crate::WitnessHop {
                func: "drive".to_string(),
                file: "rust/src/fl/runner.rs".to_string(),
                line: 41,
            },
            crate::WitnessHop {
                func: "Engine::compile_file".to_string(),
                file: "rust/src/runtime/executor.rs".to_string(),
                line: 115,
            },
        ];
        let text = render_json(&report(vec![d], Vec::new(), 1));
        let v = parse_json(&text).expect("parses");
        let findings = match v.get("findings") {
            Some(Json::Arr(items)) => items,
            other => panic!("findings: {other:?}"),
        };
        let witness = match findings[0].get("witness") {
            Some(Json::Arr(items)) => items,
            other => panic!("witness: {other:?}"),
        };
        assert_eq!(witness.len(), 2);
        assert_eq!(witness[0].get("fn").and_then(Json::as_str), Some("drive"));
        assert_eq!(
            witness[1].get("fn").and_then(Json::as_str),
            Some("Engine::compile_file")
        );
        assert_eq!(witness[1].get("line").and_then(Json::as_u64), Some(115));
        // Local findings carry an empty chain, and the baseline parser
        // is witness-agnostic.
        assert!(parse_baseline(&text).is_ok());
    }

    #[test]
    fn baseline_absorbs_old_but_not_new() {
        let old = report(
            vec![diag(Rule::UnwrapInLibrary, "rust/src/fl/a.rs", 5, "v.unwrap()")],
            Vec::new(),
            1,
        );
        let base = parse_baseline(&render_json(&old)).expect("baseline");

        // Same finding moved to another line: covered.
        let moved = report(
            vec![diag(Rule::UnwrapInLibrary, "rust/src/fl/a.rs", 12, "v.unwrap()")],
            Vec::new(),
            1,
        );
        assert!(new_findings(&moved, &base).is_empty());

        // A second occurrence of the same snippet: multiset says new.
        let doubled = report(
            vec![
                diag(Rule::UnwrapInLibrary, "rust/src/fl/a.rs", 5, "v.unwrap()"),
                diag(Rule::UnwrapInLibrary, "rust/src/fl/a.rs", 30, "v.unwrap()"),
            ],
            Vec::new(),
            1,
        );
        assert_eq!(new_findings(&doubled, &base).len(), 1);

        // A different rule on the same snippet: new.
        let other_rule = report(
            vec![diag(Rule::FloatOrdering, "rust/src/fl/a.rs", 5, "v.unwrap()")],
            Vec::new(),
            1,
        );
        assert_eq!(new_findings(&other_rule, &base).len(), 1);
    }

    #[test]
    fn baseline_rejects_other_versions() {
        let text = "{\"version\": 1, \"findings\": []}";
        assert!(parse_baseline(text).is_err());
    }

    #[test]
    fn empty_report_renders_empty_findings() {
        let report = report(Vec::new(), Vec::new(), 7);
        let text = render_json(&report);
        assert!(text.contains("\"findings\": [],"), "{text}");
        assert!(text.contains("\"suppressed_by_rule\": {}"), "{text}");
        let base = parse_baseline(&text).expect("parses");
        assert!(base.is_empty());
    }
}
