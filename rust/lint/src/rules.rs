//! The rule engine: scans the masked code view line by line, applies
//! the per-module scope table, and honors `lint:allow` pragmas.
//!
//! Pragma syntax (the reason is mandatory — an allow without a reason
//! is itself a violation and suppresses nothing):
//!
//! ```text
//!   // lint:allow(unwrap-in-library): the invariant that makes this
//!   // infallible, in one or two lines.
//! ```
//!
//! A pragma applies to the code on its own line, or — when it sits on
//! a comment-only line — to the first code line after the contiguous
//! comment block it belongs to.  A blank line breaks the attachment.

use std::collections::BTreeSet;

use crate::scope;
use crate::tokenize::mask;
use crate::{Diagnostic, Rule};

/// What linting one file produced.
pub struct LintOutcome {
    /// Violations (and pragma errors), in line order.
    pub diagnostics: Vec<Diagnostic>,
    /// Violations matched by a justified `lint:allow` pragma.
    pub suppressed: usize,
}

/// Lint one file's source text.  `rel_path` is the repo-relative path
/// (`rust/src/fl/runner.rs`) the scope table keys on.
pub fn lint_source(rel_path: &str, source: &str) -> LintOutcome {
    let rel = rel_path.replace('\\', "/");
    let m = mask(source);
    let n = m.code.len();
    let file_is_test = scope::is_test_path(&rel);
    let regions = test_regions(&m.code);
    let line_is_test = |idx: usize| {
        file_is_test || regions.iter().any(|&(s, e)| s <= idx && idx <= e)
    };

    // Pragma and SAFETY-comment attachment: comment-only lines carry
    // forward to the next code line; blank lines break the chain.
    let mut allows: Vec<BTreeSet<&'static str>> = vec![BTreeSet::new(); n];
    let mut safety_ok: Vec<bool> = vec![false; n];
    let mut diagnostics: Vec<Diagnostic> = Vec::new();
    let mut pending: BTreeSet<&'static str> = BTreeSet::new();
    let mut pending_safety = false;
    for i in 0..n {
        let has_code = !m.code[i].trim().is_empty();
        let comment = m.comment[i].as_str();
        let mut own: BTreeSet<&'static str> = BTreeSet::new();
        parse_pragmas(&rel, i + 1, comment, &mut own, &mut diagnostics);
        let own_safety = comment.contains("SAFETY:");
        if has_code {
            allows[i] = &pending | &own;
            safety_ok[i] = pending_safety || own_safety;
            pending.clear();
            pending_safety = false;
        } else if !comment.trim().is_empty() {
            pending.extend(own.iter().copied());
            pending_safety = pending_safety || own_safety;
        } else {
            pending.clear();
            pending_safety = false;
        }
    }

    let mut suppressed = 0;
    let push = |line_idx: usize,
                    rule: Rule,
                    message: String,
                    allows: &[BTreeSet<&'static str>],
                    out: &mut Vec<Diagnostic>,
                    suppressed: &mut usize| {
        if allows[line_idx].contains(rule.id()) {
            *suppressed += 1;
        } else {
            out.push(Diagnostic {
                file: rel.clone(),
                line: line_idx + 1,
                rule,
                message,
            });
        }
    };

    for i in 0..n {
        let code = m.code[i].as_str();
        if code.trim().is_empty() {
            continue;
        }

        if scope::rule_applies(Rule::FloatOrdering, &rel) {
            for _ in 0..count_word(code, ".partial_cmp") {
                push(
                    i,
                    Rule::FloatOrdering,
                    "partial_cmp is NaN-unsound in an ordering; use \
                     total_cmp (or an Ord key)"
                        .into(),
                    &allows,
                    &mut diagnostics,
                    &mut suppressed,
                );
            }
            if !line_is_test(i) {
                for _ in 0..float_eq_count(code) {
                    push(
                        i,
                        Rule::FloatOrdering,
                        "exact float ==/!= outside a test oracle; compare \
                         with a tolerance, or justify the exact-bit check \
                         with lint:allow"
                            .into(),
                        &allows,
                        &mut diagnostics,
                        &mut suppressed,
                    );
                }
            }
        }

        if scope::rule_applies(Rule::WallClockInSim, &rel) {
            let hits = count_word(code, "Instant") + count_word(code, "SystemTime");
            for _ in 0..hits {
                push(
                    i,
                    Rule::WallClockInSim,
                    "wall-clock time in a simulated-time module; ride \
                     NetSim's clock (allowlist: util/logging, util/timer, \
                     bench/, runtime/executor)"
                        .into(),
                    &allows,
                    &mut diagnostics,
                    &mut suppressed,
                );
            }
        }

        if scope::rule_applies(Rule::UnorderedIteration, &rel) {
            let hits = count_word(code, "HashMap") + count_word(code, "HashSet");
            for _ in 0..hits {
                push(
                    i,
                    Rule::UnorderedIteration,
                    "unordered container in a determinism-critical module; \
                     iteration order is unspecified — use BTreeMap/BTreeSet \
                     or a sorted Vec"
                        .into(),
                    &allows,
                    &mut diagnostics,
                    &mut suppressed,
                );
            }
        }

        if scope::rule_applies(Rule::UnwrapInLibrary, &rel) && !line_is_test(i) {
            let hits = count_word(code, ".unwrap()")
                + count_word(code, ".expect(")
                + count_word(code, "panic!");
            for _ in 0..hits {
                push(
                    i,
                    Rule::UnwrapInLibrary,
                    "unwrap/expect/panic in library code; return a typed \
                     util::error Result, or state the invariant with \
                     lint:allow"
                        .into(),
                    &allows,
                    &mut diagnostics,
                    &mut suppressed,
                );
            }
        }

        if scope::rule_applies(Rule::UnsafeAudit, &rel)
            && count_word(code, "unsafe") > 0
            && !safety_ok[i]
        {
            push(
                i,
                Rule::UnsafeAudit,
                "unsafe without a SAFETY: comment on the line or the \
                 comment block directly above it"
                    .into(),
                &allows,
                &mut diagnostics,
                &mut suppressed,
            );
        }
    }

    diagnostics.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    LintOutcome { diagnostics, suppressed }
}

/// Lines covered by `#[cfg(test)]` items, as inclusive 0-based ranges.
/// Brace-matching starts at the attribute, so the region ends at the
/// gated item's closing brace (or its `;` for body-less items).
fn test_regions(code: &[String]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0;
    while i < code.len() {
        let pos = match code[i].find("#[cfg(test)") {
            Some(p) => p,
            None => {
                i += 1;
                continue;
            }
        };
        let mut depth: i64 = 0;
        let mut started = false;
        let mut line = i;
        let mut col = pos;
        'scan: while line < code.len() {
            for ch in code[line][col..].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => {
                        depth -= 1;
                        if started && depth == 0 {
                            break 'scan;
                        }
                    }
                    ';' if !started => break 'scan,
                    _ => {}
                }
            }
            line += 1;
            col = 0;
        }
        let end = line.min(code.len().saturating_sub(1));
        regions.push((i, end));
        i = end + 1;
    }
    regions
}

/// Parse every `lint:allow` pragma in one line's comment text: the
/// marker, a parenthesized rule list, then `: reason`.  Valid allows
/// land in `out`; malformed pragmas emit `pragma` diagnostics and
/// allow nothing.  Only the parenthesized form is treated as a
/// pragma — prose that merely *mentions* the marker stays inert.
fn parse_pragmas(
    rel: &str,
    line_no: usize,
    comment: &str,
    out: &mut BTreeSet<&'static str>,
    diags: &mut Vec<Diagnostic>,
) {
    let mut rest = comment;
    while let Some(pos) = rest.find("lint:allow(") {
        let after_paren = &rest[pos + "lint:allow(".len()..];
        let close = match after_paren.find(')') {
            Some(c) => c,
            None => {
                diags.push(pragma_diag(
                    rel,
                    line_no,
                    "malformed pragma: unclosed rule list",
                ));
                return;
            }
        };
        let list = &after_paren[..close];
        let tail = &after_paren[close + 1..];
        let mut named: Vec<&'static str> = Vec::new();
        for name in list.split(',') {
            let name = name.trim();
            match Rule::from_id(name) {
                Some(r) => named.push(r.id()),
                None => diags.push(pragma_diag(
                    rel,
                    line_no,
                    &format!("unknown rule {name:?} in lint:allow"),
                )),
            }
        }
        // The justification is mandatory: `): reason` with non-empty
        // reason text on the pragma line itself.
        let t = tail.trim_start();
        let reason_ok = t.starts_with(':') && !t[1..].trim().is_empty();
        if reason_ok {
            out.extend(named);
        } else {
            diags.push(pragma_diag(
                rel,
                line_no,
                "lint:allow pragma is missing its `: reason` justification \
                 — suppressions must explain the invariant",
            ));
        }
        rest = tail;
    }
}

fn pragma_diag(rel: &str, line: usize, message: &str) -> Diagnostic {
    Diagnostic {
        file: rel.to_string(),
        line,
        rule: Rule::Pragma,
        message: message.to_string(),
    }
}

fn is_tok_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'.' || b == b'_'
}

/// Count occurrences of `needle` in `hay` with identifier boundaries
/// on whichever ends of the needle are identifier characters.
fn count_word(hay: &str, needle: &str) -> usize {
    let hb = hay.as_bytes();
    let nb = needle.as_bytes();
    if nb.is_empty() {
        return 0;
    }
    let mut count = 0;
    let mut start = 0;
    while let Some(p) = hay[start..].find(needle) {
        let p = start + p;
        let first = nb[0];
        let before_ok = if first.is_ascii_alphanumeric() || first == b'_' {
            p == 0 || !(hb[p - 1].is_ascii_alphanumeric() || hb[p - 1] == b'_')
        } else {
            true
        };
        let last = nb[nb.len() - 1];
        let end = p + nb.len();
        let after_ok = if last.is_ascii_alphanumeric() || last == b'_' {
            end >= hb.len() || !(hb[end].is_ascii_alphanumeric() || hb[end] == b'_')
        } else {
            true
        };
        if before_ok && after_ok {
            count += 1;
        }
        start = p + nb.len();
    }
    count
}

/// Count `==`/`!=` comparisons where either operand is a float
/// literal.  Comparing two float *variables* needs type information a
/// tokenizer does not have; literal comparisons are the ones this
/// codebase actually writes (sparsity skips, integer-representability
/// checks) and the ones a reviewer cannot tell apart from bugs.
fn float_eq_count(code: &str) -> usize {
    let b = code.as_bytes();
    let mut count = 0;
    let mut i = 0;
    while i + 1 < b.len() {
        let op = (b[i] == b'=' || b[i] == b'!') && b[i + 1] == b'=';
        let not_triple = i + 2 >= b.len() || b[i + 2] != b'=';
        let not_tail = i == 0
            || !(b[i - 1] == b'=' || b[i - 1] == b'!' || b[i - 1] == b'<' || b[i - 1] == b'>');
        if !(op && not_triple && not_tail) {
            i += 1;
            continue;
        }
        // Left operand token.
        let mut j = i;
        while j > 0 && b[j - 1] == b' ' {
            j -= 1;
        }
        let mut lo = j;
        while lo > 0 && is_tok_byte(b[lo - 1]) {
            lo -= 1;
        }
        let left = &code[lo..j];
        // Right operand token (allow a leading unary minus).
        let mut k = i + 2;
        while k < b.len() && b[k] == b' ' {
            k += 1;
        }
        if k < b.len() && b[k] == b'-' {
            k += 1;
        }
        let mut hi = k;
        while hi < b.len() && is_tok_byte(b[hi]) {
            hi += 1;
        }
        let right = &code[k..hi];
        if is_float_literal(left) || is_float_literal(right) {
            count += 1;
        }
        i += 2;
    }
    count
}

/// Whether a scanned token is a float literal (`0.0`, `1.`, `1e9`,
/// `2.5e3`, `5f32`, `0.0_f64`).
fn is_float_literal(tok: &str) -> bool {
    if tok.is_empty() || !tok.as_bytes()[0].is_ascii_digit() {
        return false;
    }
    let core = tok
        .strip_suffix("f32")
        .or_else(|| tok.strip_suffix("f64"))
        .map(|s| s.trim_end_matches('_'))
        .unwrap_or(tok);
    let suffixed = core.len() != tok.len();
    if core.starts_with("0x") || core.starts_with("0b") || core.starts_with("0o") {
        return false;
    }
    let mut has_dot = false;
    let mut has_exp = false;
    for c in core.chars() {
        match c {
            '0'..='9' | '_' => {}
            '.' => has_dot = true,
            'e' | 'E' => has_exp = true,
            _ => return false,
        }
    }
    suffixed || has_dot || has_exp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_literals() {
        for yes in ["0.0", "1.", "1e9", "2.5e3", "5f32", "0.0_f64", "1e"] {
            assert!(is_float_literal(yes), "{yes}");
        }
        for no in ["0", "42", "x", "self.0", "0xFF", "a.b", "", "1.0.max"] {
            assert!(!is_float_literal(no), "{no}");
        }
    }

    #[test]
    fn float_eq_detection() {
        assert_eq!(float_eq_count("if xi == 0.0 {"), 1);
        assert_eq!(float_eq_count("if xi != 0.0 {"), 1);
        assert_eq!(float_eq_count("if 0.5 == x {"), 1);
        assert_eq!(float_eq_count("if x == -1.0 {"), 1);
        assert_eq!(float_eq_count("if x == 5f32 {"), 1);
        assert_eq!(float_eq_count("if n == 0 {"), 0);
        assert_eq!(float_eq_count("if x >= 0.0 {"), 0);
        assert_eq!(float_eq_count("if x <= 1.0 {"), 0);
        assert_eq!(float_eq_count("let y = x == 1e-6;"), 1);
        assert_eq!(float_eq_count("a == 0.0 && b != 2.5"), 2);
    }

    #[test]
    fn word_boundaries() {
        assert_eq!(count_word("let t = Instant::now();", "Instant"), 1);
        assert_eq!(count_word("let t = Instants::now();", "Instant"), 0);
        assert_eq!(count_word("x.partial_cmp(&y)", ".partial_cmp"), 1);
        assert_eq!(count_word("fn partial_cmp(&self)", ".partial_cmp"), 0);
        assert_eq!(count_word("v.unwrap_or(0)", ".unwrap()"), 0);
        assert_eq!(count_word("v.unwrap()", ".unwrap()"), 1);
        assert_eq!(count_word("v.expect_err(\"e\")", ".expect("), 0);
        assert_eq!(count_word("panic!(\"boom\")", "panic!"), 1);
        assert_eq!(count_word("not_a_panic!(1)", "panic!"), 0);
    }

    #[test]
    fn cfg_test_regions_cover_the_mod() {
        let src = "\
pub fn lib() {}\n\
\n\
#[cfg(test)]\n\
mod tests {\n\
    #[test]\n\
    fn t() {\n\
        assert!(super::lib() == ());\n\
    }\n\
}\n\
pub fn after() {}\n";
        let m = mask(src);
        let r = test_regions(&m.code);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, 2);
        assert_eq!(r[0].1, 8);
    }

    #[test]
    fn pragma_requires_reason() {
        let mut out = BTreeSet::new();
        let mut diags = Vec::new();
        parse_pragmas(
            "f.rs",
            1,
            " lint:allow(unwrap-in-library): proven non-empty above",
            &mut out,
            &mut diags,
        );
        assert!(out.contains("unwrap-in-library"));
        assert!(diags.is_empty());

        let mut out = BTreeSet::new();
        let mut diags = Vec::new();
        parse_pragmas("f.rs", 1, " lint:allow(unwrap-in-library)", &mut out, &mut diags);
        assert!(out.is_empty());
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, Rule::Pragma);
    }

    #[test]
    fn pragma_rejects_unknown_rules() {
        let mut out = BTreeSet::new();
        let mut diags = Vec::new();
        parse_pragmas("f.rs", 3, " lint:allow(no-such-rule): why", &mut out, &mut diags);
        assert!(out.is_empty());
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("no-such-rule"));
    }
}
