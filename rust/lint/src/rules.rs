//! The rule engine: scans the masked code view line by line, applies
//! the per-module scope table, and honors `lint:allow` pragmas.
//!
//! Pragma syntax (the reason is mandatory — an allow without a reason
//! is itself a violation and suppresses nothing):
//!
//! ```text
//!   // lint:allow(unwrap-in-library): the invariant that makes this
//!   // infallible, in one or two lines.
//! ```
//!
//! A pragma applies to the code on its own line, or — when it sits on
//! a comment-only line — to the first code line after the contiguous
//! comment block it belongs to.  A blank line breaks the attachment.
//! Doc comments (`///`, `//!`) never carry pragmas: they are rendered
//! documentation, so pragma syntax *mentioned* there (like the example
//! above) stays inert — write directives in plain `//` comments.
//!
//! Since PR 7 the engine runs in two tiers.  [`analyze`] produces a
//! [`FileAnalysis`] per file: the masked views, the item index
//! ([`crate::items`]), every pragma as an *allow atom* (which pragma
//! line allows which rule on which code line), and the local
//! (single-file) rule findings.  The cross-file passes —
//! [`crate::contracts`] and [`stale_pragma_pass`] — then consume and
//! extend those analyses.  [`lint_source`] remains the local-only
//! entry point: explicit-PATH scans use it, because contract and
//! stale-pragma verdicts are only meaningful when the whole tree was
//! read.

use std::collections::BTreeSet;

use crate::items::{self, FileItems};
use crate::scope;
use crate::tokenize::mask;
use crate::{Diagnostic, Rule, WitnessHop};

/// What linting one file produced.
pub struct LintOutcome {
    /// Violations (and pragma errors), in line order.
    pub diagnostics: Vec<Diagnostic>,
    /// Violations matched by a justified `lint:allow` pragma.
    pub suppressed: usize,
}

/// One parsed `lint:allow` grant: `rule` may be suppressed on the
/// code line `attach` by the pragma written on `pragma_line`.
struct AllowAtom {
    rule: &'static str,
    /// 1-based line the pragma text sits on.
    pragma_line: usize,
    /// 0-based code line the grant applies to; `None` when the pragma
    /// dangled (blank line or EOF before any code followed it).
    attach: Option<usize>,
}

/// Everything the engine knows about one file after the local pass.
pub struct FileAnalysis {
    /// Repo-relative path with `/` separators.
    pub rel: String,
    /// Raw source lines (diagnostic snippets come from here).
    pub raw: Vec<String>,
    /// Masked code view (strings/comments blanked).
    pub code: Vec<String>,
    /// String-literal view (literal text at its columns).
    pub strings: Vec<String>,
    /// Item index: structs, enums, fns, consts, match arms.
    pub items: FileItems,
    /// Violations, in (line, rule) order after [`finish`].
    pub diagnostics: Vec<Diagnostic>,
    /// Findings a justified pragma suppressed (kept whole — the JSON
    /// report shows them with `"pragma": "allowed"`).
    pub suppressed: Vec<Diagnostic>,
    allows: Vec<AllowAtom>,
    /// Indices into `allows` that suppressed at least one finding.
    used: BTreeSet<usize>,
    safety_ok: Vec<bool>,
    /// Whether the whole file is test code ([`scope::is_test_path`]).
    pub(crate) is_test_file: bool,
    /// `#[cfg(test)]` regions as inclusive 0-based line ranges.
    pub(crate) test_regions: Vec<(usize, usize)>,
}

impl FileAnalysis {
    /// File a finding of `rule` at 0-based line `line_idx`: suppressed
    /// if an allow atom for the rule attaches to that line (all such
    /// atoms are marked used), a violation otherwise.
    pub fn report(&mut self, line_idx: usize, rule: Rule, message: String) {
        self.report_witnessed(line_idx, rule, message, Vec::new());
    }

    /// [`report`](Self::report) with a witness call chain attached
    /// (the interprocedural rules use this).
    pub fn report_witnessed(
        &mut self,
        line_idx: usize,
        rule: Rule,
        message: String,
        witness: Vec<WitnessHop>,
    ) {
        let diag = Diagnostic {
            file: self.rel.clone(),
            line: line_idx + 1,
            rule,
            message,
            snippet: snippet(&self.raw, line_idx),
            witness,
        };
        if self.consume_allow(line_idx, rule.id()) {
            self.suppressed.push(diag);
        } else {
            self.diagnostics.push(diag);
        }
    }

    /// Mark every allow atom for `rule_id` attached to `line_idx` as
    /// used, returning whether any existed.  The effect seeder calls
    /// this directly: a justified pragma on a seed line keeps that
    /// site from tainting every caller.
    pub(crate) fn consume_allow(&mut self, line_idx: usize, rule_id: &str) -> bool {
        let mut hit = false;
        for (k, atom) in self.allows.iter().enumerate() {
            if atom.attach == Some(line_idx) && atom.rule == rule_id {
                self.used.insert(k);
                hit = true;
            }
        }
        hit
    }

    /// Whether 0-based `line_idx` is test code (test file or inside a
    /// `#[cfg(test)]` region).
    pub(crate) fn line_is_test(&self, line_idx: usize) -> bool {
        self.is_test_file
            || self
                .test_regions
                .iter()
                .any(|&(s, e)| s <= line_idx && line_idx <= e)
    }

    /// Sort both finding lists into (line, rule) order.
    pub fn finish(&mut self) {
        self.diagnostics
            .sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
        self.suppressed
            .sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    }
}

fn snippet(raw: &[String], line_idx: usize) -> String {
    raw.get(line_idx).map(|l| l.trim().to_string()).unwrap_or_default()
}

/// Run the local (single-file) analysis: mask, parse items, resolve
/// pragma attachment, and apply the five per-line rules.
pub fn analyze(rel_path: &str, source: &str) -> FileAnalysis {
    let rel = rel_path.replace('\\', "/");
    let m = mask(source);
    let n = m.code.len();
    let raw: Vec<String> = source.lines().map(|l| l.to_string()).collect();
    let file_is_test = scope::is_test_path(&rel);
    let regions = test_regions(&m.code);

    // Pragma and SAFETY-comment attachment: comment-only lines carry
    // forward to the next code line; blank lines break the chain.
    let mut allows: Vec<AllowAtom> = Vec::new();
    let mut safety_ok: Vec<bool> = vec![false; n];
    let mut diagnostics: Vec<Diagnostic> = Vec::new();
    let mut pending: Vec<(usize, &'static str)> = Vec::new();
    let mut pending_safety = false;
    for i in 0..n {
        let has_code = !m.code[i].trim().is_empty();
        let comment = m.comment[i].as_str();
        let mut own: Vec<&'static str> = Vec::new();
        // Doc comments are documentation, not directives: pragma syntax
        // quoted in them must not create allow grants (which the
        // stale-pragma pass would then flag as unused).
        let doc = {
            let t = raw.get(i).map(|l| l.trim_start()).unwrap_or("");
            t.starts_with("///") || t.starts_with("//!")
        };
        if !doc {
            parse_pragmas(&rel, i + 1, comment, &raw, &mut own, &mut diagnostics);
        }
        let own_safety = comment.contains("SAFETY:");
        if has_code {
            for (pragma_line, rule) in pending.drain(..) {
                allows.push(AllowAtom {
                    rule,
                    pragma_line,
                    attach: Some(i),
                });
            }
            for rule in own.drain(..) {
                allows.push(AllowAtom {
                    rule,
                    pragma_line: i + 1,
                    attach: Some(i),
                });
            }
            safety_ok[i] = pending_safety || own_safety;
            pending_safety = false;
        } else if !comment.trim().is_empty() {
            for rule in own.drain(..) {
                pending.push((i + 1, rule));
            }
            pending_safety = pending_safety || own_safety;
        } else {
            // A blank line detaches the pending block: those pragmas
            // guard nothing and surface in the stale-pragma pass.
            for (pragma_line, rule) in pending.drain(..) {
                allows.push(AllowAtom {
                    rule,
                    pragma_line,
                    attach: None,
                });
            }
            pending_safety = false;
        }
    }
    for (pragma_line, rule) in pending.drain(..) {
        allows.push(AllowAtom {
            rule,
            pragma_line,
            attach: None,
        });
    }

    let mut fa = FileAnalysis {
        rel,
        items: items::parse_items(&m.code),
        raw,
        code: m.code,
        strings: m.strings,
        diagnostics,
        suppressed: Vec::new(),
        allows,
        used: BTreeSet::new(),
        safety_ok,
        is_test_file: file_is_test,
        test_regions: regions.clone(),
    };
    local_rules(&mut fa, file_is_test, &regions);
    fa
}

/// The five single-file rules of PR 6, applied line by line.
fn local_rules(fa: &mut FileAnalysis, file_is_test: bool, regions: &[(usize, usize)]) {
    let n = fa.code.len();
    let line_is_test =
        |idx: usize| file_is_test || regions.iter().any(|&(s, e)| s <= idx && idx <= e);

    for i in 0..n {
        let code = std::mem::take(&mut fa.code[i]);
        if code.trim().is_empty() {
            fa.code[i] = code;
            continue;
        }

        if scope::rule_applies(Rule::FloatOrdering, &fa.rel) {
            for _ in 0..count_word(&code, ".partial_cmp") {
                fa.report(
                    i,
                    Rule::FloatOrdering,
                    "partial_cmp is NaN-unsound in an ordering; use \
                     total_cmp (or an Ord key)"
                        .into(),
                );
            }
            if !line_is_test(i) {
                for _ in 0..float_eq_count(&code) {
                    fa.report(
                        i,
                        Rule::FloatOrdering,
                        "exact float ==/!= outside a test oracle; compare \
                         with a tolerance, or justify the exact-bit check \
                         with lint:allow"
                            .into(),
                    );
                }
            }
        }

        if scope::rule_applies(Rule::WallClockInSim, &fa.rel) {
            let hits = count_word(&code, "Instant") + count_word(&code, "SystemTime");
            for _ in 0..hits {
                fa.report(
                    i,
                    Rule::WallClockInSim,
                    "wall-clock time in a simulated-time module; ride \
                     NetSim's clock (allowlist: util/logging, util/timer, \
                     bench/, runtime/executor, obs/wallclock)"
                        .into(),
                );
            }
        }

        if scope::rule_applies(Rule::UnorderedIteration, &fa.rel) {
            let hits = count_word(&code, "HashMap") + count_word(&code, "HashSet");
            for _ in 0..hits {
                fa.report(
                    i,
                    Rule::UnorderedIteration,
                    "unordered container in a determinism-critical module; \
                     iteration order is unspecified — use BTreeMap/BTreeSet \
                     or a sorted Vec"
                        .into(),
                );
            }
        }

        if scope::rule_applies(Rule::UnwrapInLibrary, &fa.rel) && !line_is_test(i) {
            let hits = count_word(&code, ".unwrap()")
                + count_word(&code, ".expect(")
                + count_word(&code, "panic!");
            for _ in 0..hits {
                fa.report(
                    i,
                    Rule::UnwrapInLibrary,
                    "unwrap/expect/panic in library code; return a typed \
                     util::error Result, or state the invariant with \
                     lint:allow"
                        .into(),
                );
            }
        }

        if scope::rule_applies(Rule::UnsafeAudit, &fa.rel)
            && count_word(&code, "unsafe") > 0
            && !fa.safety_ok[i]
        {
            fa.report(
                i,
                Rule::UnsafeAudit,
                "unsafe without a SAFETY: comment on the line or the \
                 comment block directly above it"
                    .into(),
            );
        }

        fa.code[i] = code;
    }
}

/// The stale-pragma pass, run after every other rule (local *and*
/// contract) has had its chance to consume allow atoms.  An atom no
/// finding used is dead weight that silently rots as code churns —
/// flag it at its own line.  A stale finding may itself be kept alive
/// by a `lint:allow(stale-pragma): reason` on the same code line
/// (one level only: an unused stale-pragma allow is flagged with no
/// further meta-suppression).
pub fn stale_pragma_pass(fa: &mut FileAnalysis) {
    // Two rounds: plain rules first (their stale findings may consume
    // stale-pragma atoms), then any still-unused stale-pragma atoms.
    for meta_round in [false, true] {
        let unused: Vec<(usize, Option<usize>, &'static str)> = fa
            .allows
            .iter()
            .enumerate()
            .filter(|(k, a)| {
                !fa.used.contains(k) && (a.rule == Rule::StalePragma.id()) == meta_round
            })
            .map(|(_, a)| (a.pragma_line, a.attach, a.rule))
            .collect();
        for (pragma_line, attach, rule) in unused {
            let line_idx = pragma_line - 1;
            let message = match attach {
                Some(_) => format!(
                    "lint:allow({rule}) no longer suppresses anything on \
                     its attached code line — the guarded pattern is gone; \
                     delete the stale pragma"
                ),
                None => format!(
                    "lint:allow({rule}) is detached (no code line follows \
                     its comment block) and suppresses nothing — delete it"
                ),
            };
            let diag = Diagnostic {
                file: fa.rel.clone(),
                line: pragma_line,
                rule: Rule::StalePragma,
                message,
                snippet: snippet(&fa.raw, line_idx),
                witness: Vec::new(),
            };
            // Suppression: a stale-pragma atom attached to the same
            // code line as the stale atom.  Meta-round findings and
            // dangling pragmas are not suppressible.
            let mut hit = false;
            if !meta_round {
                if let Some(code_line) = attach {
                    for k in 0..fa.allows.len() {
                        if fa.allows[k].attach == Some(code_line)
                            && fa.allows[k].rule == Rule::StalePragma.id()
                        {
                            fa.used.insert(k);
                            hit = true;
                        }
                    }
                }
            }
            if hit {
                fa.suppressed.push(diag);
            } else {
                fa.diagnostics.push(diag);
            }
        }
    }
    fa.finish();
}

/// Lint one file's source text with the local rules only.  `rel_path`
/// is the repo-relative path (`rust/src/fl/runner.rs`) the scope
/// table keys on.  Cross-file contract rules and the stale-pragma
/// pass need the whole tree and run via [`crate::lint_tree`].
pub fn lint_source(rel_path: &str, source: &str) -> LintOutcome {
    let mut fa = analyze(rel_path, source);
    fa.finish();
    LintOutcome {
        suppressed: fa.suppressed.len(),
        diagnostics: fa.diagnostics,
    }
}

/// Lines covered by `#[cfg(test)]` items, as inclusive 0-based ranges.
/// Brace-matching starts at the attribute, so the region ends at the
/// gated item's closing brace (or its `;` for body-less items).
fn test_regions(code: &[String]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0;
    while i < code.len() {
        let pos = match code[i].find("#[cfg(test)") {
            Some(p) => p,
            None => {
                i += 1;
                continue;
            }
        };
        let mut depth: i64 = 0;
        let mut started = false;
        let mut line = i;
        let mut col = pos;
        'scan: while line < code.len() {
            for ch in code[line][col..].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => {
                        depth -= 1;
                        if started && depth == 0 {
                            break 'scan;
                        }
                    }
                    ';' if !started => break 'scan,
                    _ => {}
                }
            }
            line += 1;
            col = 0;
        }
        let end = line.min(code.len().saturating_sub(1));
        regions.push((i, end));
        i = end + 1;
    }
    regions
}

/// Parse every `lint:allow` pragma in one line's comment text: the
/// marker, a parenthesized rule list, then `: reason`.  Valid allows
/// land in `out`; malformed pragmas emit `pragma` diagnostics and
/// allow nothing.  Only the parenthesized form is treated as a
/// pragma — prose that merely *mentions* the marker stays inert.
fn parse_pragmas(
    rel: &str,
    line_no: usize,
    comment: &str,
    raw: &[String],
    out: &mut Vec<&'static str>,
    diags: &mut Vec<Diagnostic>,
) {
    let mut rest = comment;
    while let Some(pos) = rest.find("lint:allow(") {
        let after_paren = &rest[pos + "lint:allow(".len()..];
        let close = match after_paren.find(')') {
            Some(c) => c,
            None => {
                diags.push(pragma_diag(
                    rel,
                    line_no,
                    raw,
                    "malformed pragma: unclosed rule list",
                ));
                return;
            }
        };
        let list = &after_paren[..close];
        let tail = &after_paren[close + 1..];
        let mut named: Vec<&'static str> = Vec::new();
        for name in list.split(',') {
            let name = name.trim();
            match Rule::from_id(name) {
                Some(r) => named.push(r.id()),
                None => diags.push(pragma_diag(
                    rel,
                    line_no,
                    raw,
                    &format!("unknown rule {name:?} in lint:allow"),
                )),
            }
        }
        // The justification is mandatory: `): reason` with non-empty
        // reason text on the pragma line itself.
        let t = tail.trim_start();
        let reason_ok = t.starts_with(':') && !t[1..].trim().is_empty();
        if reason_ok {
            out.extend(named);
        } else {
            diags.push(pragma_diag(
                rel,
                line_no,
                raw,
                "lint:allow pragma is missing its `: reason` justification \
                 — suppressions must explain the invariant",
            ));
        }
        rest = tail;
    }
}

fn pragma_diag(rel: &str, line: usize, raw: &[String], message: &str) -> Diagnostic {
    Diagnostic {
        file: rel.to_string(),
        line,
        rule: Rule::Pragma,
        message: message.to_string(),
        snippet: snippet(raw, line.saturating_sub(1)),
        witness: Vec::new(),
    }
}

fn is_tok_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'.' || b == b'_'
}

/// Count occurrences of `needle` in `hay` with identifier boundaries
/// on whichever ends of the needle are identifier characters.
pub(crate) fn count_word(hay: &str, needle: &str) -> usize {
    let hb = hay.as_bytes();
    let nb = needle.as_bytes();
    if nb.is_empty() {
        return 0;
    }
    let mut count = 0;
    let mut start = 0;
    while let Some(p) = hay[start..].find(needle) {
        let p = start + p;
        let first = nb[0];
        let before_ok = if first.is_ascii_alphanumeric() || first == b'_' {
            p == 0 || !(hb[p - 1].is_ascii_alphanumeric() || hb[p - 1] == b'_')
        } else {
            true
        };
        let last = nb[nb.len() - 1];
        let end = p + nb.len();
        let after_ok = if last.is_ascii_alphanumeric() || last == b'_' {
            end >= hb.len() || !(hb[end].is_ascii_alphanumeric() || hb[end] == b'_')
        } else {
            true
        };
        if before_ok && after_ok {
            count += 1;
        }
        start = p + nb.len();
    }
    count
}

/// Count `==`/`!=` comparisons where either operand is a float
/// literal.  Comparing two float *variables* needs type information a
/// tokenizer does not have; literal comparisons are the ones this
/// codebase actually writes (sparsity skips, integer-representability
/// checks) and the ones a reviewer cannot tell apart from bugs.
fn float_eq_count(code: &str) -> usize {
    let b = code.as_bytes();
    let mut count = 0;
    let mut i = 0;
    while i + 1 < b.len() {
        let op = (b[i] == b'=' || b[i] == b'!') && b[i + 1] == b'=';
        let not_triple = i + 2 >= b.len() || b[i + 2] != b'=';
        let not_tail = i == 0
            || !(b[i - 1] == b'=' || b[i - 1] == b'!' || b[i - 1] == b'<' || b[i - 1] == b'>');
        if !(op && not_triple && not_tail) {
            i += 1;
            continue;
        }
        // Left operand token.
        let mut j = i;
        while j > 0 && b[j - 1] == b' ' {
            j -= 1;
        }
        let mut lo = j;
        while lo > 0 && is_tok_byte(b[lo - 1]) {
            lo -= 1;
        }
        let left = &code[lo..j];
        // Right operand token (allow a leading unary minus).
        let mut k = i + 2;
        while k < b.len() && b[k] == b' ' {
            k += 1;
        }
        if k < b.len() && b[k] == b'-' {
            k += 1;
        }
        let mut hi = k;
        while hi < b.len() && is_tok_byte(b[hi]) {
            hi += 1;
        }
        let right = &code[k..hi];
        if is_float_literal(left) || is_float_literal(right) {
            count += 1;
        }
        i += 2;
    }
    count
}

/// Whether a scanned token is a float literal (`0.0`, `1.`, `1e9`,
/// `2.5e3`, `5f32`, `0.0_f64`).
fn is_float_literal(tok: &str) -> bool {
    if tok.is_empty() || !tok.as_bytes()[0].is_ascii_digit() {
        return false;
    }
    let core = tok
        .strip_suffix("f32")
        .or_else(|| tok.strip_suffix("f64"))
        .map(|s| s.trim_end_matches('_'))
        .unwrap_or(tok);
    let suffixed = core.len() != tok.len();
    if core.starts_with("0x") || core.starts_with("0b") || core.starts_with("0o") {
        return false;
    }
    let mut has_dot = false;
    let mut has_exp = false;
    for c in core.chars() {
        match c {
            '0'..='9' | '_' => {}
            '.' => has_dot = true,
            'e' | 'E' => has_exp = true,
            _ => return false,
        }
    }
    suffixed || has_dot || has_exp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_literals() {
        for yes in ["0.0", "1.", "1e9", "2.5e3", "5f32", "0.0_f64", "1e"] {
            assert!(is_float_literal(yes), "{yes}");
        }
        for no in ["0", "42", "x", "self.0", "0xFF", "a.b", "", "1.0.max"] {
            assert!(!is_float_literal(no), "{no}");
        }
    }

    #[test]
    fn float_eq_detection() {
        assert_eq!(float_eq_count("if xi == 0.0 {"), 1);
        assert_eq!(float_eq_count("if xi != 0.0 {"), 1);
        assert_eq!(float_eq_count("if 0.5 == x {"), 1);
        assert_eq!(float_eq_count("if x == -1.0 {"), 1);
        assert_eq!(float_eq_count("if x == 5f32 {"), 1);
        assert_eq!(float_eq_count("if n == 0 {"), 0);
        assert_eq!(float_eq_count("if x >= 0.0 {"), 0);
        assert_eq!(float_eq_count("if x <= 1.0 {"), 0);
        assert_eq!(float_eq_count("let y = x == 1e-6;"), 1);
        assert_eq!(float_eq_count("a == 0.0 && b != 2.5"), 2);
    }

    #[test]
    fn word_boundaries() {
        assert_eq!(count_word("let t = Instant::now();", "Instant"), 1);
        assert_eq!(count_word("let t = Instants::now();", "Instant"), 0);
        assert_eq!(count_word("x.partial_cmp(&y)", ".partial_cmp"), 1);
        assert_eq!(count_word("fn partial_cmp(&self)", ".partial_cmp"), 0);
        assert_eq!(count_word("v.unwrap_or(0)", ".unwrap()"), 0);
        assert_eq!(count_word("v.unwrap()", ".unwrap()"), 1);
        assert_eq!(count_word("v.expect_err(\"e\")", ".expect("), 0);
        assert_eq!(count_word("panic!(\"boom\")", "panic!"), 1);
        assert_eq!(count_word("not_a_panic!(1)", "panic!"), 0);
    }

    #[test]
    fn cfg_test_regions_cover_the_mod() {
        let src = "\
pub fn lib() {}\n\
\n\
#[cfg(test)]\n\
mod tests {\n\
    #[test]\n\
    fn t() {\n\
        assert!(super::lib() == ());\n\
    }\n\
}\n\
pub fn after() {}\n";
        let m = mask(src);
        let r = test_regions(&m.code);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, 2);
        assert_eq!(r[0].1, 8);
    }

    #[test]
    fn pragma_requires_reason() {
        let raw: Vec<String> = Vec::new();
        let mut out = Vec::new();
        let mut diags = Vec::new();
        parse_pragmas(
            "f.rs",
            1,
            " lint:allow(unwrap-in-library): proven non-empty above",
            &raw,
            &mut out,
            &mut diags,
        );
        assert!(out.contains(&"unwrap-in-library"));
        assert!(diags.is_empty());

        let mut out = Vec::new();
        let mut diags = Vec::new();
        parse_pragmas(
            "f.rs",
            1,
            " lint:allow(unwrap-in-library)",
            &raw,
            &mut out,
            &mut diags,
        );
        assert!(out.is_empty());
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, Rule::Pragma);
    }

    #[test]
    fn pragma_rejects_unknown_rules() {
        let raw: Vec<String> = Vec::new();
        let mut out = Vec::new();
        let mut diags = Vec::new();
        parse_pragmas(
            "f.rs",
            3,
            " lint:allow(no-such-rule): why",
            &raw,
            &mut out,
            &mut diags,
        );
        assert!(out.is_empty());
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("no-such-rule"));
    }

    #[test]
    fn stale_pragma_fires_on_unused_allow() {
        let src = "\
// lint:allow(unwrap-in-library): this used to guard an unwrap
pub fn tidy() -> usize {
    0
}
";
        let mut fa = analyze("rust/src/fl/x.rs", src);
        stale_pragma_pass(&mut fa);
        assert_eq!(fa.diagnostics.len(), 1, "{:?}", fa.diagnostics);
        assert_eq!(fa.diagnostics[0].rule, Rule::StalePragma);
        assert_eq!(fa.diagnostics[0].line, 1);
    }

    #[test]
    fn used_pragma_is_not_stale() {
        let src = "\
pub fn take(v: Option<usize>) -> usize {
    // lint:allow(unwrap-in-library): caller checked is_some above
    v.unwrap()
}
";
        let mut fa = analyze("rust/src/fl/x.rs", src);
        stale_pragma_pass(&mut fa);
        assert!(fa.diagnostics.is_empty(), "{:?}", fa.diagnostics);
        assert_eq!(fa.suppressed.len(), 1);
    }

    #[test]
    fn dangling_pragma_is_stale() {
        let src = "\
// lint:allow(unwrap-in-library): detached by the blank line below

pub fn f() -> usize {
    1
}
";
        let mut fa = analyze("rust/src/fl/x.rs", src);
        stale_pragma_pass(&mut fa);
        assert_eq!(fa.diagnostics.len(), 1);
        assert_eq!(fa.diagnostics[0].rule, Rule::StalePragma);
        assert!(fa.diagnostics[0].message.contains("detached"));
    }

    #[test]
    fn stale_finding_is_itself_suppressible_once() {
        let src = "\
// lint:allow(unwrap-in-library): kept for the next refactor step
// lint:allow(stale-pragma): the unwrap returns in PR 8; keep the grant
pub fn f() -> usize {
    1
}
";
        let mut fa = analyze("rust/src/fl/x.rs", src);
        stale_pragma_pass(&mut fa);
        assert!(fa.diagnostics.is_empty(), "{:?}", fa.diagnostics);
        // The stale finding was suppressed, and the stale-pragma atom
        // that did the suppressing counts as used (no meta-cascade).
        assert_eq!(fa.suppressed.len(), 1);
        assert_eq!(fa.suppressed[0].rule, Rule::StalePragma);
    }

    #[test]
    fn doc_comments_never_carry_pragmas() {
        // Pragma syntax quoted in rendered documentation must neither
        // grant a suppression nor count as a stale pragma.
        let src = "\
/// Suppress with `lint:allow(unwrap-in-library): reason` on the line.
pub fn documented(v: Option<usize>) -> usize {
    v.unwrap()
}
";
        let mut fa = analyze("rust/src/fl/x.rs", src);
        stale_pragma_pass(&mut fa);
        // The unwrap still fires (the doc text suppressed nothing) and
        // no stale-pragma finding appears.
        assert_eq!(fa.diagnostics.len(), 1, "{:?}", fa.diagnostics);
        assert_eq!(fa.diagnostics[0].rule, Rule::UnwrapInLibrary);
        assert!(fa.suppressed.is_empty());
    }

    #[test]
    fn unused_stale_pragma_allow_is_flagged() {
        let src = "\
// lint:allow(stale-pragma): nothing here is stale
pub fn f() -> usize {
    1
}
";
        let mut fa = analyze("rust/src/fl/x.rs", src);
        stale_pragma_pass(&mut fa);
        assert_eq!(fa.diagnostics.len(), 1);
        assert_eq!(fa.diagnostics[0].rule, Rule::StalePragma);
    }
}
