//! Per-module rule-scope table.
//!
//! Paths are repo-relative with `/` separators (`rust/src/fl/runner.rs`).
//! Each rule carries its own scope, grounded in the contracts the
//! ROADMAP records per PR: the bit-identity contract (PR 1), the
//! simulated-clock contract (PR 2), checkpoint/resume bit-identity
//! (PR 3) and the typed-error surface of the engine layer (PR 4/5).

use crate::Rule;

/// Modules allowed to read the wall clock: logging timestamps, the
/// phase timer, the bench harness, the executor's compile/phase
/// timing, and the wall-clock half of the obs dual-clock span model
/// (`obs/wallclock.rs` — the rest of `obs/` handles opaque marks).
/// Everything else under `rust/src/` — in particular the
/// simulated-time modules `netsim/` and `fl/` — must ride `NetSim`'s
/// clock.
pub const WALL_CLOCK_ALLOW: [&str; 5] = [
    "rust/src/bench/",
    "rust/src/util/logging.rs",
    "rust/src/util/timer.rs",
    "rust/src/runtime/executor.rs",
    "rust/src/obs/wallclock.rs",
];

/// Determinism-critical modules where unordered containers are banned
/// outright: aggregation order decides report bits, the runner and
/// session own checkpoint serialization, metrics and the JSON/CSV
/// writers are the export surface, `runtime/params.rs` serializes
/// model state, and `obs/` promises bit-identical traces and metrics
/// at any worker count.
pub const UNORDERED_SCOPE: [&str; 8] = [
    "rust/src/fl/aggregate.rs",
    "rust/src/fl/runner.rs",
    "rust/src/fl/session.rs",
    "rust/src/metrics/",
    "rust/src/util/json.rs",
    "rust/src/util/csv.rs",
    "rust/src/runtime/params.rs",
    "rust/src/obs/",
];

/// Library layers that must surface typed `util::error` results
/// instead of panicking.  `obs/` rides inside the training loop, so a
/// tracing panic would take the run down with it.
pub const UNWRAP_SCOPE: [&str; 3] =
    ["rust/src/fl/", "rust/src/runtime/", "rust/src/obs/"];

/// Whether `rule` is enforced for the file at `rel_path`.
pub fn rule_applies(rule: Rule, rel_path: &str) -> bool {
    let rel = rel_path.replace('\\', "/");
    match rule {
        Rule::FloatOrdering | Rule::UnsafeAudit | Rule::Pragma => true,
        // The contract rules are anchored by the tables in
        // `contracts.rs`, not by path; stale-pragma follows the
        // pragmas themselves.  Scope-wise they apply everywhere.
        Rule::CheckpointParity
        | Rule::CsvSchemaParity
        | Rule::ConfigSurfaceParity
        | Rule::StalePragma => true,
        // The interprocedural rules pick their own roots from the
        // effects tables in `effects.rs` (surface lists, visibility,
        // the LocalUpdateHandle anchor); path-wise they apply to the
        // whole graph, which only indexes rust/src/**.
        Rule::TransitiveWallClock
        | Rule::PanicReachability
        | Rule::PureLocalUpdate => rel.starts_with("rust/src/"),
        Rule::WallClockInSim => {
            rel.starts_with("rust/src/")
                && !WALL_CLOCK_ALLOW.iter().any(|p| rel.starts_with(p))
        }
        Rule::UnorderedIteration => {
            UNORDERED_SCOPE.iter().any(|p| rel.starts_with(p))
        }
        Rule::UnwrapInLibrary => UNWRAP_SCOPE.iter().any(|p| rel.starts_with(p)),
    }
}

/// Whether the whole file is test code (integration-test trees).
/// `#[cfg(test)]` regions inside library files are detected separately
/// by the rule engine.
pub fn is_test_path(rel_path: &str) -> bool {
    let rel = rel_path.replace('\\', "/");
    rel.starts_with("rust/tests/") || rel.contains("/tests/")
}

/// One-line scope description per rule, for `--list-rules`.
pub fn describe(rule: Rule) -> &'static str {
    match rule {
        Rule::FloatOrdering => {
            "everywhere (float `==`/`!=` is exempt inside test oracles)"
        }
        Rule::WallClockInSim => {
            "rust/src/** except bench/, util/logging.rs, util/timer.rs, \
             runtime/executor.rs, obs/wallclock.rs"
        }
        Rule::UnorderedIteration => {
            "fl/aggregate, fl/runner, fl/session, metrics/, util/json, \
             util/csv, runtime/params, obs/"
        }
        Rule::UnwrapInLibrary => {
            "rust/src/fl/**, rust/src/runtime/** and rust/src/obs/** \
             (non-test code)"
        }
        Rule::UnsafeAudit => "everywhere",
        Rule::CheckpointParity => {
            "the checkpointed session types (contract table in \
             lint/src/contracts.rs); whole-tree scans only"
        }
        Rule::CsvSchemaParity => {
            "METRICS_CSV_HEADER vs RoundRecord and its row encoder; \
             whole-tree scans only"
        }
        Rule::ConfigSurfaceParity => {
            "ExperimentConfig JSON emit/parse and CLI override arms, \
             CampaignSpec JSON emit/parse; whole-tree scans only"
        }
        Rule::TransitiveWallClock => {
            "fns on the runner/session/aggregate, netsim/, metrics/, \
             json/csv and runtime/params surfaces whose *callees* reach \
             Instant/SystemTime (direct reads are wall-clock-in-sim's \
             job); whole-tree scans only"
        }
        Rule::PanicReachability => {
            "public fns in rust/src/fl/** and rust/src/runtime/** from \
             which an unjustified panic site is reachable through at \
             least one call; whole-tree scans only"
        }
        Rule::PureLocalUpdate => {
            "every LocalUpdateHandle::run impl: no wall-clock, RNG or \
             ambient-state effect reachable at any depth; whole-tree \
             scans only"
        }
        Rule::StalePragma => {
            "every lint:allow pragma (an unused grant is a violation); \
             whole-tree scans only"
        }
        Rule::Pragma => "wherever a lint:allow pragma appears",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_allowlist() {
        assert!(rule_applies(Rule::WallClockInSim, "rust/src/fl/runner.rs"));
        assert!(rule_applies(Rule::WallClockInSim, "rust/src/netsim/sim.rs"));
        assert!(!rule_applies(Rule::WallClockInSim, "rust/src/bench/mod.rs"));
        assert!(!rule_applies(Rule::WallClockInSim, "rust/src/util/timer.rs"));
        assert!(!rule_applies(
            Rule::WallClockInSim,
            "rust/src/runtime/executor.rs"
        ));
        // Only the wall-clock half of obs may read the clock.
        assert!(rule_applies(Rule::WallClockInSim, "rust/src/obs/mod.rs"));
        assert!(rule_applies(Rule::WallClockInSim, "rust/src/obs/chrome.rs"));
        assert!(!rule_applies(
            Rule::WallClockInSim,
            "rust/src/obs/wallclock.rs"
        ));
        // Outside rust/src the rule does not apply at all (benches and
        // examples measure the process, not the simulation).
        assert!(!rule_applies(
            Rule::WallClockInSim,
            "rust/benches/bench_parallel.rs"
        ));
    }

    #[test]
    fn unwrap_scope_is_library_layers() {
        assert!(rule_applies(Rule::UnwrapInLibrary, "rust/src/fl/comm.rs"));
        assert!(rule_applies(
            Rule::UnwrapInLibrary,
            "rust/src/runtime/pool.rs"
        ));
        assert!(rule_applies(Rule::UnwrapInLibrary, "rust/src/obs/mod.rs"));
        assert!(!rule_applies(Rule::UnwrapInLibrary, "rust/src/main.rs"));
        assert!(!rule_applies(Rule::UnwrapInLibrary, "rust/src/cli/mod.rs"));
        assert!(!rule_applies(
            Rule::UnwrapInLibrary,
            "rust/tests/integration_fl.rs"
        ));
    }

    #[test]
    fn unordered_scope_names_serialization_paths() {
        assert!(rule_applies(
            Rule::UnorderedIteration,
            "rust/src/fl/aggregate.rs"
        ));
        assert!(rule_applies(Rule::UnorderedIteration, "rust/src/metrics/mod.rs"));
        assert!(rule_applies(Rule::UnorderedIteration, "rust/src/util/json.rs"));
        assert!(rule_applies(
            Rule::UnorderedIteration,
            "rust/src/obs/metrics.rs"
        ));
        assert!(!rule_applies(
            Rule::UnorderedIteration,
            "rust/src/topology/graph.rs"
        ));
    }

    #[test]
    fn test_paths() {
        assert!(is_test_path("rust/tests/integration_native.rs"));
        assert!(!is_test_path("rust/src/fl/runner.rs"));
        assert!(!is_test_path("rust/benches/bench_native.rs"));
    }
}
