//! Layer-1 item-level parser: structs with named fields, enum
//! variants (including struct-like variant fields), fn signatures
//! with impl owners and body spans, consts with value spans, and
//! `match` arm heads — extracted from the masked code view with
//! brace/bracket tracking.  Deliberately *not* a full AST: the
//! contract rules in [`crate::contracts`] only need names, lines and
//! spans, and a token-level scan stays robust on an offline,
//! dependency-free build.
//!
//! Known (accepted) limits, chosen for simplicity over generality:
//! nested `match` arms inside another arm's body are not extracted,
//! and shift operators inside type-position const expressions
//! (`[u64; 1 << 4]`) would confuse the angle-bracket counter — the
//! codebase writes neither.

/// A named field of a struct or struct-like enum variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    /// 1-based source line of the field declaration.
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct StructItem {
    pub name: String,
    pub line: usize,
    /// Named fields; empty for tuple and unit structs.
    pub fields: Vec<Field>,
}

#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub line: usize,
    /// Named fields of a struct-like variant; empty otherwise.
    pub fields: Vec<Field>,
}

#[derive(Clone, Debug)]
pub struct EnumItem {
    pub name: String,
    pub line: usize,
    pub variants: Vec<Variant>,
}

#[derive(Clone, Debug)]
pub struct FnItem {
    pub name: String,
    /// The implementing type when the fn sits in an `impl` block
    /// (`impl Foo` and `impl Trait for Foo` both yield `Foo`).
    pub owner: Option<String>,
    /// The trait's last path segment when the fn sits in an
    /// `impl Trait for Type` block (`impl fmt::Display for Foo`
    /// yields `Display`); `None` in inherent impls and free fns.
    pub trait_of: Option<String>,
    /// Whether the fn carries a `pub` / `pub(crate)` / `pub(in …)`
    /// visibility qualifier.
    pub is_pub: bool,
    pub line: usize,
    /// Body span as inclusive 1-based lines (opening `{` line to the
    /// matching `}` line); `None` for body-less trait signatures.
    pub body: Option<(usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct ConstItem {
    pub name: String,
    pub line: usize,
    /// Inclusive 1-based lines from `const` to its terminating `;`.
    pub span: (usize, usize),
}

#[derive(Clone, Debug)]
pub struct MatchArm {
    /// 1-based line of the arm's first pattern token.
    pub line: usize,
    /// The pattern-and-guard text before `=>`, tokens joined by one
    /// space (`Some ( x ) if x > 0`).
    pub head: String,
}

/// Everything the item parser extracts from one file.
#[derive(Default)]
pub struct FileItems {
    pub structs: Vec<StructItem>,
    pub enums: Vec<EnumItem>,
    pub fns: Vec<FnItem>,
    pub consts: Vec<ConstItem>,
    pub match_arms: Vec<MatchArm>,
}

impl FileItems {
    /// Look up a struct by name.
    pub fn struct_named(&self, name: &str) -> Option<&StructItem> {
        self.structs.iter().find(|s| s.name == name)
    }

    /// Look up an enum by name.
    pub fn enum_named(&self, name: &str) -> Option<&EnumItem> {
        self.enums.iter().find(|e| e.name == name)
    }

    /// Look up a fn by name, optionally constrained to an impl owner.
    /// With `owner: None` any fn of that name matches (first wins —
    /// the lexical order is deterministic).
    pub fn fn_named(&self, name: &str, owner: Option<&str>) -> Option<&FnItem> {
        self.fns.iter().find(|f| {
            f.name == name
                && match owner {
                    Some(o) => f.owner.as_deref() == Some(o),
                    None => true,
                }
        })
    }

    /// Look up a const by name.
    pub fn const_named(&self, name: &str) -> Option<&ConstItem> {
        self.consts.iter().find(|c| c.name == name)
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum Tok {
    Ident(String),
    Punct(char),
}

pub(crate) struct Token {
    pub(crate) tok: Tok,
    /// 1-based source line.
    pub(crate) line: usize,
}

pub(crate) fn lex(code: &[String]) -> Vec<Token> {
    let mut toks = Vec::new();
    for (idx, line) in code.iter().enumerate() {
        let chars: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            if c.is_whitespace() {
                i += 1;
            } else if c.is_ascii_alphanumeric() || c == '_' {
                let start = i;
                while i < chars.len()
                    && (chars[i].is_ascii_alphanumeric() || chars[i] == '_')
                {
                    i += 1;
                }
                toks.push(Token {
                    tok: Tok::Ident(chars[start..i].iter().collect()),
                    line: idx + 1,
                });
            } else {
                toks.push(Token {
                    tok: Tok::Punct(c),
                    line: idx + 1,
                });
                i += 1;
            }
        }
    }
    toks
}

struct Parser<'a> {
    toks: &'a [Token],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self, k: usize) -> Option<&'a Token> {
        self.toks.get(self.i + k)
    }

    fn is_punct(&self, k: usize, c: char) -> bool {
        matches!(self.peek(k), Some(t) if t.tok == Tok::Punct(c))
    }

    fn is_kw(&self, k: usize, w: &str) -> bool {
        matches!(self.peek(k), Some(t) if matches!(&t.tok, Tok::Ident(s) if s == w))
    }

    fn bump(&mut self) -> Option<&'a Token> {
        let t = self.toks.get(self.i);
        if t.is_some() {
            self.i += 1;
        }
        t
    }

    /// Consume an identifier token, returning it.
    fn ident(&mut self) -> Option<(&'a str, usize)> {
        match self.peek(0) {
            Some(t) => match &t.tok {
                Tok::Ident(s) => {
                    self.i += 1;
                    Some((s.as_str(), t.line))
                }
                Tok::Punct(_) => None,
            },
            None => None,
        }
    }

    /// Skip `#[…]` attributes (any number).
    fn skip_attrs(&mut self) {
        while self.is_punct(0, '#') && self.is_punct(1, '[') {
            self.bump();
            self.skip_balanced('[', ']');
        }
    }

    /// Starting at an `open` token, consume through its matching
    /// `close`.  Returns the line of the close (or the last token's
    /// line on malformed input).
    fn skip_balanced(&mut self, open: char, close: char) -> usize {
        let mut depth = 0i64;
        let mut last = self.peek(0).map(|t| t.line).unwrap_or(0);
        while let Some(t) = self.bump() {
            last = t.line;
            match t.tok {
                Tok::Punct(c) if c == open => depth += 1,
                Tok::Punct(c) if c == close => {
                    depth -= 1;
                    if depth == 0 {
                        return last;
                    }
                }
                _ => {}
            }
        }
        last
    }

    /// Skip a generic parameter/argument list starting at `<`.  `->`
    /// inside `Fn() -> T` bounds must not close the list, so a `>`
    /// directly preceded by `-` is not counted.
    fn skip_generics(&mut self) {
        let mut depth = 0i64;
        let mut prev_minus = false;
        while let Some(t) = self.bump() {
            match t.tok {
                Tok::Punct('<') => {
                    depth += 1;
                    prev_minus = false;
                }
                Tok::Punct('>') => {
                    if prev_minus {
                        prev_minus = false;
                        continue;
                    }
                    depth -= 1;
                    if depth == 0 {
                        return;
                    }
                }
                Tok::Punct('-') => prev_minus = true,
                _ => prev_minus = false,
            }
        }
    }

    /// Skip a field's type up to (and through) the `,` that ends it,
    /// or up to — but not through — the `}` that closes the body.
    /// Parens, brackets, braces and generics are tracked so commas
    /// inside `BTreeMap<usize, f64>` or `(f64, f64)` don't end the
    /// field early.
    fn skip_field_type(&mut self) {
        let mut paren = 0i64;
        let mut bracket = 0i64;
        let mut brace = 0i64;
        let mut angle = 0i64;
        let mut prev_minus = false;
        while let Some(t) = self.peek(0) {
            match t.tok {
                Tok::Punct(',')
                    if paren == 0 && bracket == 0 && brace == 0 && angle == 0 =>
                {
                    self.bump();
                    return;
                }
                Tok::Punct('}') if paren == 0 && bracket == 0 && angle == 0 => {
                    if brace == 0 {
                        return;
                    }
                    brace -= 1;
                    self.bump();
                    prev_minus = false;
                    continue;
                }
                _ => {}
            }
            let t = match self.bump() {
                Some(t) => t,
                None => return,
            };
            match t.tok {
                Tok::Punct('(') => paren += 1,
                Tok::Punct(')') => paren -= 1,
                Tok::Punct('[') => bracket += 1,
                Tok::Punct(']') => bracket -= 1,
                Tok::Punct('{') => brace += 1,
                Tok::Punct('<') => angle += 1,
                Tok::Punct('>') => {
                    if prev_minus {
                        prev_minus = false;
                        continue;
                    }
                    angle -= 1;
                }
                _ => {}
            }
            prev_minus = matches!(t.tok, Tok::Punct('-'));
        }
    }
}

/// Parse one file's masked code view into its item index.
pub fn parse_items(code: &[String]) -> FileItems {
    let toks = lex(code);
    let mut p = Parser { toks: &toks, i: 0 };
    let mut items = FileItems::default();
    // (owner of the enclosing impl, its trait, brace depth just outside it)
    let mut impl_stack: Vec<(Option<String>, Option<String>, i64)> = Vec::new();
    let mut depth = 0i64;

    while let Some(t) = p.peek(0) {
        match &t.tok {
            Tok::Punct('{') => {
                depth += 1;
                p.bump();
            }
            Tok::Punct('}') => {
                depth -= 1;
                if let Some(&(_, _, d)) = impl_stack.last() {
                    if depth == d {
                        impl_stack.pop();
                    }
                }
                p.bump();
            }
            Tok::Punct('#') if p.is_punct(1, '[') => {
                p.bump();
                p.skip_balanced('[', ']');
            }
            Tok::Ident(w) if w == "struct" => parse_struct(&mut p, &mut items),
            Tok::Ident(w) if w == "enum" => parse_enum(&mut p, &mut items),
            Tok::Ident(w) if w == "fn" => {
                let (owner, trait_of) = match impl_stack.last() {
                    Some((o, t, _)) => (o.clone(), t.clone()),
                    None => (None, None),
                };
                let is_pub = pub_before(&toks, p.i);
                parse_fn(&mut p, &mut items, owner, trait_of, is_pub);
            }
            Tok::Ident(w) if w == "impl" => {
                let (owner, trait_of) = parse_impl_header(&mut p);
                impl_stack.push((owner, trait_of, depth));
            }
            Tok::Ident(w) if w == "const" => parse_const(&mut p, &mut items),
            Tok::Ident(w) if w == "match" => parse_match(&mut p, &mut items),
            _ => {
                p.bump();
            }
        }
    }
    items
}

/// Parse the named fields of a `{ … }` body, cursor on the `{`.
/// Consumes through the closing `}`.
fn parse_named_fields(p: &mut Parser<'_>, out: &mut Vec<Field>) {
    p.bump(); // `{`
    loop {
        p.skip_attrs();
        if p.is_punct(0, '}') {
            p.bump();
            return;
        }
        if p.is_kw(0, "pub") {
            p.bump();
            if p.is_punct(0, '(') {
                p.skip_balanced('(', ')');
            }
        }
        // A named field is `ident :` with a single colon (`::` would
        // be a path, which cannot start a field).
        let is_field = matches!(p.peek(0), Some(t) if matches!(t.tok, Tok::Ident(_)))
            && p.is_punct(1, ':')
            && !p.is_punct(2, ':');
        if is_field {
            if let Some((name, line)) = p.ident() {
                out.push(Field {
                    name: name.to_string(),
                    line,
                });
            }
            p.bump(); // `:`
            p.skip_field_type();
        } else if p.bump().is_none() {
            return;
        }
    }
}

fn parse_struct(p: &mut Parser<'_>, items: &mut FileItems) {
    p.bump(); // `struct`
    let (name, line) = match p.ident() {
        Some(x) => x,
        None => return,
    };
    if p.is_punct(0, '<') {
        p.skip_generics();
    }
    let mut fields = Vec::new();
    if p.is_punct(0, '(') {
        // Tuple struct: skip the tuple, then everything up to `;`.
        p.skip_balanced('(', ')');
        while let Some(t) = p.peek(0) {
            if t.tok == Tok::Punct(';') {
                p.bump();
                break;
            }
            p.bump();
        }
    } else {
        // Optional where clause before the body.
        while let Some(t) = p.peek(0) {
            match &t.tok {
                Tok::Punct('{') | Tok::Punct(';') => break,
                Tok::Punct('<') => {
                    p.skip_generics();
                }
                _ => {
                    p.bump();
                }
            }
        }
        if p.is_punct(0, '{') {
            parse_named_fields(p, &mut fields);
        } else {
            p.bump(); // unit struct `;`
        }
    }
    items.structs.push(StructItem {
        name: name.to_string(),
        line,
        fields,
    });
}

fn parse_enum(p: &mut Parser<'_>, items: &mut FileItems) {
    p.bump(); // `enum`
    let (name, line) = match p.ident() {
        Some(x) => x,
        None => return,
    };
    if p.is_punct(0, '<') {
        p.skip_generics();
    }
    while let Some(t) = p.peek(0) {
        match &t.tok {
            Tok::Punct('{') => break,
            Tok::Punct('<') => {
                p.skip_generics();
            }
            _ => {
                p.bump();
            }
        }
    }
    if !p.is_punct(0, '{') {
        return;
    }
    p.bump(); // `{`
    let mut variants = Vec::new();
    loop {
        p.skip_attrs();
        if p.is_punct(0, '}') {
            p.bump();
            break;
        }
        let (vname, vline) = match p.ident() {
            Some(x) => x,
            None => {
                if p.bump().is_none() {
                    break;
                }
                continue;
            }
        };
        let mut fields = Vec::new();
        if p.is_punct(0, '(') {
            p.skip_balanced('(', ')');
        } else if p.is_punct(0, '{') {
            parse_named_fields(p, &mut fields);
        }
        if p.is_punct(0, '=') {
            // Explicit discriminant: skip to the variant separator.
            while let Some(t) = p.peek(0) {
                match t.tok {
                    Tok::Punct(',') | Tok::Punct('}') => break,
                    _ => {
                        p.bump();
                    }
                }
            }
        }
        if p.is_punct(0, ',') {
            p.bump();
        }
        variants.push(Variant {
            name: vname.to_string(),
            line: vline,
            fields,
        });
    }
    items.enums.push(EnumItem {
        name: name.to_string(),
        line,
        variants,
    });
}

/// Walk backwards from the `fn` token over visibility and qualifier
/// tokens (`const` / `async` / `unsafe` / `extern "C"` and a
/// `pub(…)` restriction) to decide whether the fn is `pub`.
fn pub_before(toks: &[Token], fn_idx: usize) -> bool {
    let mut j = fn_idx;
    loop {
        if j == 0 {
            return false;
        }
        j -= 1;
        match &toks[j].tok {
            Tok::Ident(w)
                if w == "const" || w == "async" || w == "unsafe" || w == "extern" =>
            {
                continue
            }
            // The masked string view leaves `extern "C"` as bare quotes.
            Tok::Punct('"') => continue,
            Tok::Punct(')') => {
                // Rewind over a `( crate )` / `( in path )` restriction.
                let mut depth = 0i64;
                while j > 0 {
                    match toks[j].tok {
                        Tok::Punct(')') => depth += 1,
                        Tok::Punct('(') => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j -= 1;
                }
                continue;
            }
            Tok::Ident(w) if w == "pub" => return true,
            _ => return false,
        }
    }
}

fn parse_fn(
    p: &mut Parser<'_>,
    items: &mut FileItems,
    owner: Option<String>,
    trait_of: Option<String>,
    is_pub: bool,
) {
    p.bump(); // `fn`
    let (name, line) = match p.ident() {
        Some(x) => x,
        None => return, // `fn`-pointer type in expression position
    };
    if p.is_punct(0, '<') {
        p.skip_generics();
    }
    if !p.is_punct(0, '(') {
        return;
    }
    p.skip_balanced('(', ')');
    // Return type / where clause: scan to the body `{` or a trait
    // signature's `;`.
    let mut body = None;
    loop {
        match p.peek(0) {
            None => break,
            Some(t) => match &t.tok {
                Tok::Punct(';') => {
                    p.bump();
                    break;
                }
                Tok::Punct('{') => {
                    // Find the matching close by lookahead without
                    // consuming — the main loop walks *into* fn
                    // bodies so nested items and match arms are
                    // still extracted.
                    let start = t.line;
                    let mut d = 0i64;
                    let mut end = start;
                    for tt in &p.toks[p.i..] {
                        match tt.tok {
                            Tok::Punct('{') => d += 1,
                            Tok::Punct('}') => {
                                d -= 1;
                                if d == 0 {
                                    end = tt.line;
                                    break;
                                }
                            }
                            _ => {}
                        }
                    }
                    body = Some((start, end));
                    break;
                }
                Tok::Punct('<') => {
                    p.skip_generics();
                }
                Tok::Punct('(') => {
                    p.skip_balanced('(', ')');
                }
                Tok::Punct('[') => {
                    p.skip_balanced('[', ']');
                }
                _ => {
                    p.bump();
                }
            },
        }
    }
    items.fns.push(FnItem {
        name: name.to_string(),
        owner,
        trait_of,
        is_pub,
        line,
        body,
    });
}

/// Parse an `impl` header up to — but not through — its `{`, and
/// return `(implementing type, trait)` as last path segments:
/// `impl Foo` yields `(Foo, None)`; `impl fmt::Display for Foo`
/// yields `(Foo, Some(Display))`.
fn parse_impl_header(p: &mut Parser<'_>) -> (Option<String>, Option<String>) {
    p.bump(); // `impl`
    if p.is_punct(0, '<') {
        p.skip_generics();
    }
    let mut owner: Option<String> = None;
    let mut trait_of: Option<String> = None;
    let mut done = false;
    while let Some(t) = p.peek(0) {
        match &t.tok {
            Tok::Punct('{') => break,
            Tok::Punct(';') => break, // `impl Trait for Type;` (never written, be safe)
            Tok::Punct('<') => {
                p.skip_generics();
            }
            Tok::Punct('(') => {
                p.skip_balanced('(', ')');
            }
            Tok::Ident(w) if w == "for" => {
                trait_of = owner.take();
                p.bump();
            }
            Tok::Ident(w) if w == "where" => {
                done = true;
                p.bump();
            }
            Tok::Ident(w) if w == "dyn" => {
                p.bump();
            }
            Tok::Ident(w) => {
                if !done {
                    owner = Some(w.clone());
                }
                p.bump();
            }
            _ => {
                p.bump();
            }
        }
    }
    (owner, trait_of)
}

fn parse_const(p: &mut Parser<'_>, items: &mut FileItems) {
    // `const fn` is a fn; leave the `fn` for the main loop.
    if p.is_kw(1, "fn") {
        p.bump();
        return;
    }
    let start = p.peek(0).map(|t| t.line).unwrap_or(1);
    p.bump(); // `const`
    let (name, line) = match p.ident() {
        Some(x) => x,
        None => return,
    };
    let name = name.to_string();
    // Consume to the terminating `;` at depth 0; a `;` inside the
    // value's braces/brackets (const blocks, `[u8; 4]` types) is
    // nested and doesn't terminate.
    let mut paren = 0i64;
    let mut bracket = 0i64;
    let mut brace = 0i64;
    let mut end = line;
    while let Some(t) = p.bump() {
        end = t.line;
        match t.tok {
            Tok::Punct('(') => paren += 1,
            Tok::Punct(')') => paren -= 1,
            Tok::Punct('[') => bracket += 1,
            Tok::Punct(']') => bracket -= 1,
            Tok::Punct('{') => brace += 1,
            Tok::Punct('}') => brace -= 1,
            Tok::Punct(';') if paren == 0 && bracket == 0 && brace == 0 => break,
            _ => {}
        }
    }
    items.consts.push(ConstItem {
        name,
        line,
        span: (start, end),
    });
}

fn parse_match(p: &mut Parser<'_>, items: &mut FileItems) {
    p.bump(); // `match`
    // Scrutinee: Rust forbids bare struct literals here, so the first
    // `{` outside parens/brackets opens the arm body.
    let mut paren = 0i64;
    let mut bracket = 0i64;
    loop {
        match p.peek(0) {
            None => return,
            Some(t) => match t.tok {
                Tok::Punct('{') if paren == 0 && bracket == 0 => break,
                Tok::Punct('(') => {
                    paren += 1;
                    p.bump();
                }
                Tok::Punct(')') => {
                    paren -= 1;
                    p.bump();
                }
                Tok::Punct('[') => {
                    bracket += 1;
                    p.bump();
                }
                Tok::Punct(']') => {
                    bracket -= 1;
                    p.bump();
                }
                _ => {
                    p.bump();
                }
            },
        }
    }
    p.bump(); // `{`
    loop {
        p.skip_attrs();
        if p.is_punct(0, '}') {
            p.bump();
            return;
        }
        // Head: tokens up to `=>` at depth 0 (struct patterns may
        // nest braces; tuple/slice patterns nest parens/brackets).
        let mut head = String::new();
        let mut head_line = 0usize;
        let mut paren = 0i64;
        let mut bracket = 0i64;
        let mut brace = 0i64;
        loop {
            match p.peek(0) {
                None => return,
                Some(t) => {
                    if paren == 0 && bracket == 0 && brace == 0 {
                        if t.tok == Tok::Punct('=') && p.is_punct(1, '>') {
                            p.bump();
                            p.bump();
                            break;
                        }
                        if t.tok == Tok::Punct('}') {
                            // Malformed arm; let the outer loop close.
                            break;
                        }
                    }
                    if head_line == 0 {
                        head_line = t.line;
                    }
                    match t.tok {
                        Tok::Punct('(') => paren += 1,
                        Tok::Punct(')') => paren -= 1,
                        Tok::Punct('[') => bracket += 1,
                        Tok::Punct(']') => bracket -= 1,
                        Tok::Punct('{') => brace += 1,
                        Tok::Punct('}') => brace -= 1,
                        _ => {}
                    }
                    if !head.is_empty() {
                        head.push(' ');
                    }
                    match &t.tok {
                        Tok::Ident(s) => head.push_str(s),
                        Tok::Punct(c) => head.push(*c),
                    }
                    p.bump();
                }
            }
        }
        if head_line != 0 {
            items.match_arms.push(MatchArm {
                line: head_line,
                head,
            });
        }
        // Arm body: a braced block, else an expression up to the `,`
        // (or the match's closing `}`).
        if p.is_punct(0, '{') {
            p.skip_balanced('{', '}');
            if p.is_punct(0, ',') {
                p.bump();
            }
        } else {
            let mut paren = 0i64;
            let mut bracket = 0i64;
            let mut brace = 0i64;
            loop {
                match p.peek(0) {
                    None => return,
                    Some(t) => {
                        if paren == 0 && bracket == 0 && brace == 0 {
                            if t.tok == Tok::Punct(',') {
                                p.bump();
                                break;
                            }
                            if t.tok == Tok::Punct('}') {
                                break;
                            }
                        }
                        match t.tok {
                            Tok::Punct('(') => paren += 1,
                            Tok::Punct(')') => paren -= 1,
                            Tok::Punct('[') => bracket += 1,
                            Tok::Punct(']') => bracket -= 1,
                            Tok::Punct('{') => brace += 1,
                            Tok::Punct('}') => brace -= 1,
                            _ => {}
                        }
                        p.bump();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenize::mask;

    fn parse(src: &str) -> FileItems {
        parse_items(&mask(src).code)
    }

    #[test]
    fn struct_fields_with_lines() {
        let src = "\
pub struct RunnerCheckpoint {
    pub cfg: ExperimentConfig,
    pub cursor: usize,
    net: BTreeMap<usize, f64>,
    pub(crate) blob: Vec<u8>,
}
";
        let items = parse(src);
        let s = items.struct_named("RunnerCheckpoint").unwrap();
        assert_eq!(s.line, 1);
        let names: Vec<&str> = s.fields.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["cfg", "cursor", "net", "blob"]);
        assert_eq!(s.fields[2].line, 4);
    }

    #[test]
    fn tuple_and_unit_structs_have_no_named_fields() {
        let items = parse("struct Wrap(f64, usize);\nstruct Marker;\nstruct G<T>(T);\n");
        assert!(items.struct_named("Wrap").unwrap().fields.is_empty());
        assert!(items.struct_named("Marker").unwrap().fields.is_empty());
        assert!(items.struct_named("G").unwrap().fields.is_empty());
    }

    #[test]
    fn enum_variants_and_variant_fields() {
        let src = "\
pub enum Strategy {
    FedAvg { rng: Rng, n_sample: usize },
    HierFl,
    SeqFl { order: Vec<usize>, cursor: usize },
    Tagged(u32),
}
";
        let items = parse(src);
        let e = items.enum_named("Strategy").unwrap();
        let names: Vec<&str> = e.variants.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(names, ["FedAvg", "HierFl", "SeqFl", "Tagged"]);
        assert_eq!(e.variants[0].fields.len(), 2);
        assert_eq!(e.variants[0].fields[1].name, "n_sample");
        assert!(e.variants[1].fields.is_empty());
        assert_eq!(e.variants[2].fields[1].name, "cursor");
        assert!(e.variants[3].fields.is_empty());
    }

    #[test]
    fn fns_carry_impl_owner_and_body_span() {
        let src = "\
impl RunnerCheckpoint {
    pub fn to_json(&self) -> String {
        let a = 1;
        format(a)
    }
    fn helper() {}
}
impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write(f)
    }
}
fn free() -> usize {
    3
}
";
        let items = parse(src);
        let to_json = items.fn_named("to_json", Some("RunnerCheckpoint")).unwrap();
        assert_eq!(to_json.line, 2);
        assert_eq!(to_json.body, Some((2, 5)));
        let fmt = items.fn_named("fmt", Some("Diagnostic")).unwrap();
        assert_eq!(fmt.body, Some((9, 11)));
        let free = items.fn_named("free", None).unwrap();
        assert_eq!(free.owner, None);
        assert_eq!(free.body, Some((13, 15)));
    }

    #[test]
    fn fn_visibility_and_impl_trait_are_recorded() {
        let src = "\
impl LocalUpdateHandle for NativeLocalUpdate {
    fn run(&self) -> usize {
        0
    }
}
impl Engine {
    pub fn load() {}
    pub(crate) const fn k() -> usize { 1 }
    fn private() {}
}
pub async fn drive() {}
pub unsafe extern \"C\" fn hook() {}
fn plain() {}
";
        let items = parse(src);
        let run = items.fn_named("run", Some("NativeLocalUpdate")).unwrap();
        assert_eq!(run.trait_of.as_deref(), Some("LocalUpdateHandle"));
        assert!(!run.is_pub);
        let load = items.fn_named("load", Some("Engine")).unwrap();
        assert!(load.is_pub);
        assert_eq!(load.trait_of, None);
        assert!(items.fn_named("k", Some("Engine")).unwrap().is_pub);
        assert!(!items.fn_named("private", Some("Engine")).unwrap().is_pub);
        assert!(items.fn_named("drive", None).unwrap().is_pub);
        assert!(items.fn_named("hook", None).unwrap().is_pub);
        assert!(!items.fn_named("plain", None).unwrap().is_pub);
    }

    #[test]
    fn qualified_trait_paths_keep_last_segment() {
        let src = "\
impl fmt::Display for Diagnostic {
    fn fmt(&self) {}
}
";
        let items = parse(src);
        let f = items.fn_named("fmt", Some("Diagnostic")).unwrap();
        assert_eq!(f.trait_of.as_deref(), Some("Display"));
    }

    #[test]
    fn trait_signatures_have_no_body() {
        let src = "\
trait Backend {
    fn validate(&self, cfg: &Config) -> Result<()>;
    fn run(&self) -> usize {
        0
    }
}
";
        let items = parse(src);
        assert_eq!(items.fn_named("validate", None).unwrap().body, None);
        assert_eq!(items.fn_named("run", None).unwrap().body, Some((3, 5)));
    }

    #[test]
    fn consts_span_multiline_values() {
        let src = "\
pub const METRICS_CSV_HEADER: [&str; 3] = [
    \"round\",
    \"cluster\",
    \"loss\",
];
const K: usize = 4;
";
        let items = parse(src);
        let h = items.const_named("METRICS_CSV_HEADER").unwrap();
        assert_eq!(h.span, (1, 5));
        assert_eq!(items.const_named("K").unwrap().span, (6, 6));
    }

    #[test]
    fn match_arm_heads() {
        let src = "\
fn pick(x: Option<usize>) -> usize {
    match x {
        Some(v) if v > 2 => v,
        Some(v) => {
            v + 1
        }
        None => 0,
    }
}
";
        let items = parse(src);
        let heads: Vec<&str> =
            items.match_arms.iter().map(|a| a.head.as_str()).collect();
        assert_eq!(heads, ["Some ( v ) if v > 2", "Some ( v )", "None"]);
        assert_eq!(items.match_arms[0].line, 3);
    }

    #[test]
    fn items_inside_fn_bodies_are_still_seen() {
        let src = "\
fn outer() {
    struct Local { x: usize }
    const INNER: usize = 1;
    let v = Local { x: INNER };
    drop(v);
}
";
        let items = parse(src);
        assert!(items.struct_named("Local").is_some());
        assert!(items.const_named("INNER").is_some());
        // `Local { x: INNER }` is an expression, not a second struct.
        assert_eq!(items.structs.len(), 1);
    }

    #[test]
    fn const_fn_is_a_fn_not_a_const() {
        let items = parse("const fn gcd(a: usize, b: usize) -> usize {\n    a + b\n}\n");
        assert!(items.consts.is_empty());
        assert!(items.fn_named("gcd", None).is_some());
    }

    #[test]
    fn generic_fields_keep_commas_inside() {
        let src = "\
struct S {
    map: BTreeMap<usize, (f64, f64)>,
    arr: [u8; 4],
    last: f64,
}
";
        let items = parse(src);
        let names: Vec<&str> = items.struct_named("S").unwrap().fields
            .iter()
            .map(|f| f.name.as_str())
            .collect();
        assert_eq!(names, ["map", "arr", "last"]);
    }
}
