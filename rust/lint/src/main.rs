//! CLI for `edgeflow-lint`.
//!
//! ```text
//! cargo run -p edgeflow-lint -- --check
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations found, 2 = usage/I-O error.

use std::env;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use edgeflow_lint::{lint_paths, lint_tree, scope, Report, Rule};

const USAGE: &str = "\
edgeflow-lint: static analysis for EdgeFLow's determinism & robustness contracts

USAGE:
    edgeflow-lint [--check] [--root <dir>] [PATH ...]
    edgeflow-lint --list-rules
    edgeflow-lint --help

With no PATHs (or with --check), lints the whole repo tree:
rust/src, rust/tests, rust/benches, examples, rust/lint/src.
Explicit PATHs (files or directories) restrict the scan.

OPTIONS:
    --check         Lint the full tree (the default when no PATHs given)
    --root <dir>    Repo root to resolve scopes against (default: auto-detect)
    --list-rules    Print each rule id and its scope, then exit 0
    --help          Print this help, then exit 0

Suppress a finding with a justified inline pragma on (or in the
comment block directly above) the offending line; the reason is
mandatory and unexplained suppressions are themselves violations.

EXIT CODES:
    0    no violations
    1    violations found (each printed as file:line:rule: message)
    2    usage or I/O error";

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(msg) => {
            eprintln!("edgeflow-lint: error: {msg}");
            eprintln!("run with --help for usage");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<bool, String> {
    let mut root: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--check" => {}
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(true);
            }
            "--list-rules" => {
                for rule in Rule::ENFORCED {
                    println!("{:<20} {}", rule.id(), scope::describe(rule));
                }
                println!("{:<20} {}", Rule::Pragma.id(), scope::describe(Rule::Pragma));
                return Ok(true);
            }
            "--root" => {
                let dir = args
                    .next()
                    .ok_or_else(|| "--root requires a directory argument".to_string())?;
                root = Some(PathBuf::from(dir));
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag {other:?}"));
            }
            other => paths.push(PathBuf::from(other)),
        }
    }

    let root = match root {
        Some(r) => r,
        None => find_repo_root()?,
    };
    if !root.join("rust").join("src").is_dir() {
        return Err(format!(
            "{} does not look like the repo root (no rust/src); pass --root",
            root.display()
        ));
    }

    let report = if paths.is_empty() {
        lint_tree(&root)
    } else {
        lint_paths(&root, &paths)
    }
    .map_err(|e| format!("scan failed: {e}"))?;

    print_report(&report);
    Ok(report.clean())
}

fn print_report(report: &Report) {
    for diag in &report.diagnostics {
        println!("{diag}");
    }
    println!(
        "edgeflow-lint: {} violation(s), {} suppressed by pragmas, {} file(s) scanned",
        report.diagnostics.len(),
        report.suppressed,
        report.files_scanned
    );
}

/// Locate the repo root: the nearest ancestor (of this crate's
/// manifest dir under `cargo run`, else the cwd) containing
/// `rust/src`.
fn find_repo_root() -> Result<PathBuf, String> {
    let mut starts: Vec<PathBuf> = Vec::new();
    if let Ok(manifest) = env::var("CARGO_MANIFEST_DIR") {
        starts.push(PathBuf::from(manifest));
    }
    if let Ok(cwd) = env::current_dir() {
        starts.push(cwd);
    }
    for start in &starts {
        let mut dir: &Path = start;
        loop {
            if dir.join("rust").join("src").is_dir() {
                return Ok(dir.to_path_buf());
            }
            match dir.parent() {
                Some(parent) => dir = parent,
                None => break,
            }
        }
    }
    Err("could not locate the repo root (no ancestor with rust/src); pass --root".into())
}
