//! CLI for `edgeflow-lint`.
//!
//! ```text
//! cargo run -p edgeflow-lint -- --check
//! cargo run -p edgeflow-lint -- --check --format json --out lint-report.json
//! cargo run -p edgeflow-lint -- --check --baseline lint-baseline.json
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations found, 2 = usage/I-O error.

use std::env;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use edgeflow_lint::{lint_paths, lint_tree, report, scope, Report, Rule};

const USAGE: &str = "\
edgeflow-lint: static analysis for EdgeFLow's determinism & robustness contracts

USAGE:
    edgeflow-lint [--check] [--root <dir>] [OPTIONS] [PATH ...]
    edgeflow-lint --list-rules
    edgeflow-lint --help

With no PATHs (or with --check), lints the whole repo tree:
rust/src, rust/tests, rust/benches, examples, rust/lint/src —
including the cross-file contract rules and the stale-pragma pass.
Explicit PATHs (files or directories) restrict the scan to the local
single-file rules (contract verdicts need the whole tree).

OPTIONS:
    --check             Lint the full tree (the default when no PATHs given)
    --root <dir>        Repo root to resolve scopes against (default: auto-detect)
    --format <fmt>      Output format: text (default) or json (stable schema,
                        version 1: rule, file, line, pragma state, message,
                        snippet, plus a summary block)
    --out <file>        Also write the report to <file> in the chosen format
                        (CI uploads the json form as a build artifact)
    --baseline <file>   Diff against a previous --format json report: exit 1
                        only on findings NOT present in the baseline, keyed by
                        (rule, file, snippet) so pure line shifts don't fail
    --list-rules        Print each rule id and its scope, then exit 0
    --help              Print this help, then exit 0

Suppress a finding with a justified inline pragma on (or in the
comment block directly above) the offending line; the reason is
mandatory and unexplained suppressions are themselves violations.
A pragma that stops suppressing anything is flagged by stale-pragma.

EXIT CODES:
    0    no violations (or none beyond the baseline)
    1    violations found (each printed as file:line:rule: message)
    2    usage or I/O error";

#[derive(Clone, Copy, PartialEq, Eq)]
enum Format {
    Text,
    Json,
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(msg) => {
            eprintln!("edgeflow-lint: error: {msg}");
            eprintln!("run with --help for usage");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<bool, String> {
    let mut root: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut format = Format::Text;
    let mut out_file: Option<PathBuf> = None;
    let mut baseline_file: Option<PathBuf> = None;
    let mut args = env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--check" => {}
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(true);
            }
            "--list-rules" => {
                for rule in Rule::ENFORCED {
                    println!("{:<22} {}", rule.id(), scope::describe(rule));
                }
                println!("{:<22} {}", Rule::Pragma.id(), scope::describe(Rule::Pragma));
                return Ok(true);
            }
            "--root" => {
                let dir = args
                    .next()
                    .ok_or_else(|| "--root requires a directory argument".to_string())?;
                root = Some(PathBuf::from(dir));
            }
            "--format" => {
                let fmt = args
                    .next()
                    .ok_or_else(|| "--format requires an argument (text|json)".to_string())?;
                format = match fmt.as_str() {
                    "text" => Format::Text,
                    "json" => Format::Json,
                    other => return Err(format!("unknown format {other:?} (text|json)")),
                };
            }
            "--out" => {
                let f = args
                    .next()
                    .ok_or_else(|| "--out requires a file argument".to_string())?;
                out_file = Some(PathBuf::from(f));
            }
            "--baseline" => {
                let f = args
                    .next()
                    .ok_or_else(|| "--baseline requires a file argument".to_string())?;
                baseline_file = Some(PathBuf::from(f));
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag {other:?}"));
            }
            other => paths.push(PathBuf::from(other)),
        }
    }

    let root = match root {
        Some(r) => r,
        None => find_repo_root()?,
    };
    if !root.join("rust").join("src").is_dir() {
        return Err(format!(
            "{} does not look like the repo root (no rust/src); pass --root",
            root.display()
        ));
    }

    let lint_report = if paths.is_empty() {
        lint_tree(&root)
    } else {
        lint_paths(&root, &paths)
    }
    .map_err(|e| format!("scan failed: {e}"))?;

    let rendered_json = report::render_json(&lint_report);
    match format {
        Format::Text => print_report(&lint_report),
        Format::Json => print!("{rendered_json}"),
    }
    if let Some(path) = &out_file {
        let body = match format {
            Format::Text => text_report(&lint_report),
            Format::Json => rendered_json,
        };
        std::fs::write(path, body)
            .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    }

    match baseline_file {
        None => Ok(lint_report.clean()),
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("cannot read baseline {}: {e}", path.display()))?;
            let baseline = report::parse_baseline(&text)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            let fresh = report::new_findings(&lint_report, &baseline);
            let tolerated = lint_report.diagnostics.len() - fresh.len();
            if fresh.is_empty() {
                eprintln!(
                    "edgeflow-lint: baseline ok ({} pre-existing finding(s) tolerated)",
                    tolerated
                );
                Ok(true)
            } else {
                eprintln!(
                    "edgeflow-lint: {} NEW finding(s) beyond the baseline \
                     ({} tolerated):",
                    fresh.len(),
                    tolerated
                );
                for diag in fresh {
                    eprintln!("  NEW {diag}");
                }
                Ok(false)
            }
        }
    }
}

fn text_report(report: &Report) -> String {
    let mut out = String::new();
    for diag in &report.diagnostics {
        out.push_str(&diag.to_string());
        out.push('\n');
    }
    out.push_str(&format!(
        "edgeflow-lint: {} violation(s), {} suppressed by pragmas, {} file(s) scanned\n",
        report.diagnostics.len(),
        report.suppressed.len(),
        report.files_scanned
    ));
    out
}

fn print_report(report: &Report) {
    print!("{}", text_report(report));
}

/// Locate the repo root: the nearest ancestor (of this crate's
/// manifest dir under `cargo run`, else the cwd) containing
/// `rust/src`.
fn find_repo_root() -> Result<PathBuf, String> {
    let mut starts: Vec<PathBuf> = Vec::new();
    if let Ok(manifest) = env::var("CARGO_MANIFEST_DIR") {
        starts.push(PathBuf::from(manifest));
    }
    if let Ok(cwd) = env::current_dir() {
        starts.push(cwd);
    }
    for start in &starts {
        let mut dir: &Path = start;
        loop {
            if dir.join("rust").join("src").is_dir() {
                return Ok(dir.to_path_buf());
            }
            match dir.parent() {
                Some(parent) => dir = parent,
                None => break,
            }
        }
    }
    Err("could not locate the repo root (no ancestor with rust/src); pass --root".into())
}
