//! CLI for `edgeflow-lint`.
//!
//! ```text
//! cargo run -p edgeflow-lint -- --check
//! cargo run -p edgeflow-lint -- --check --format json --out lint-report.json
//! cargo run -p edgeflow-lint -- --check --baseline lint-baseline.json
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations found, 2 = usage/I-O error.

use std::env;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use edgeflow_lint::{lint_paths, lint_tree, report, scope, Report, Rule};

const USAGE: &str = "\
edgeflow-lint: static analysis for EdgeFLow's determinism & robustness contracts

USAGE:
    edgeflow-lint [--check] [--root <dir>] [OPTIONS] [PATH ...]
    edgeflow-lint --list-rules
    edgeflow-lint --help

With no PATHs (or with --check), lints the whole repo tree:
rust/src, rust/tests, rust/benches, examples, rust/lint/src —
including the cross-file contract rules and the stale-pragma pass.
Explicit PATHs (files or directories) restrict the scan to the local
single-file rules (contract verdicts need the whole tree).

OPTIONS:
    --check             Lint the full tree (the default when no PATHs given)
    --root <dir>        Repo root to resolve scopes against (default: auto-detect)
    --format <fmt>      Output format: text (default) or json (stable schema,
                        version 2: rule, file, line, pragma state, message,
                        snippet, witness call chain, plus a summary block with
                        per-rule suppression counts)
    --out <file>        Also write the report to <file> in the chosen format
                        (CI uploads the json form as a build artifact)
    --effects-out <f>   Write the interprocedural effects artifact to <f>:
                        every fn with a non-empty direct/transitive effect
                        set, plus every call the resolver could not map to an
                        in-tree fn (whole-tree scans only; empty otherwise)
    --baseline <file>   Diff against a previous --format json report: exit 1
                        only on findings NOT present in the baseline, keyed by
                        (rule, file, snippet) so pure line shifts don't fail
    --explain <rule>    Explain one rule — scope, rationale — and walk every
                        current finding of it hop by hop (witness call chains
                        for the interprocedural rules); always exits 0
    --list-rules        Print each rule id and its scope, then exit 0
    --help              Print this help, then exit 0

The three interprocedural rules (transitive-wall-clock,
panic-reachability, pure-local-update) reason over the whole call
graph: each finding lands on the *root* fn and carries a witness
chain root -> ... -> effect site.  Suppress at the root fn's
signature line, or at the effect's seed site (which un-taints every
chain through it).

Suppress a finding with a justified inline pragma on (or in the
comment block directly above) the offending line; the reason is
mandatory and unexplained suppressions are themselves violations.
A pragma that stops suppressing anything is flagged by stale-pragma.

EXIT CODES:
    0    no violations (or none beyond the baseline)
    1    violations found (each printed as file:line:rule: message)
    2    usage or I/O error";

#[derive(Clone, Copy, PartialEq, Eq)]
enum Format {
    Text,
    Json,
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(msg) => {
            eprintln!("edgeflow-lint: error: {msg}");
            eprintln!("run with --help for usage");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<bool, String> {
    let mut root: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut format = Format::Text;
    let mut out_file: Option<PathBuf> = None;
    let mut effects_file: Option<PathBuf> = None;
    let mut baseline_file: Option<PathBuf> = None;
    let mut explain: Option<String> = None;
    let mut args = env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--check" => {}
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(true);
            }
            "--list-rules" => {
                for rule in Rule::ENFORCED {
                    println!("{:<22} {}", rule.id(), scope::describe(rule));
                }
                println!("{:<22} {}", Rule::Pragma.id(), scope::describe(Rule::Pragma));
                return Ok(true);
            }
            "--root" => {
                let dir = args
                    .next()
                    .ok_or_else(|| "--root requires a directory argument".to_string())?;
                root = Some(PathBuf::from(dir));
            }
            "--format" => {
                let fmt = args
                    .next()
                    .ok_or_else(|| "--format requires an argument (text|json)".to_string())?;
                format = match fmt.as_str() {
                    "text" => Format::Text,
                    "json" => Format::Json,
                    other => return Err(format!("unknown format {other:?} (text|json)")),
                };
            }
            "--out" => {
                let f = args
                    .next()
                    .ok_or_else(|| "--out requires a file argument".to_string())?;
                out_file = Some(PathBuf::from(f));
            }
            "--baseline" => {
                let f = args
                    .next()
                    .ok_or_else(|| "--baseline requires a file argument".to_string())?;
                baseline_file = Some(PathBuf::from(f));
            }
            "--effects-out" => {
                let f = args
                    .next()
                    .ok_or_else(|| "--effects-out requires a file argument".to_string())?;
                effects_file = Some(PathBuf::from(f));
            }
            "--explain" => {
                let r = args
                    .next()
                    .ok_or_else(|| "--explain requires a rule id argument".to_string())?;
                explain = Some(r);
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag {other:?}"));
            }
            other => paths.push(PathBuf::from(other)),
        }
    }

    let root = match root {
        Some(r) => r,
        None => find_repo_root()?,
    };
    if !root.join("rust").join("src").is_dir() {
        return Err(format!(
            "{} does not look like the repo root (no rust/src); pass --root",
            root.display()
        ));
    }

    let lint_report = if paths.is_empty() {
        lint_tree(&root)
    } else {
        lint_paths(&root, &paths)
    }
    .map_err(|e| format!("scan failed: {e}"))?;

    if let Some(path) = &effects_file {
        std::fs::write(path, lint_report.effects.render_json())
            .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    }

    if let Some(rule_id) = explain {
        let rule = Rule::from_id(&rule_id)
            .ok_or_else(|| format!("unknown rule {rule_id:?}; see --list-rules"))?;
        print!("{}", explain_report(&lint_report, rule));
        return Ok(true);
    }

    let rendered_json = report::render_json(&lint_report);
    match format {
        Format::Text => print_report(&lint_report),
        Format::Json => print!("{rendered_json}"),
    }
    if let Some(path) = &out_file {
        let body = match format {
            Format::Text => text_report(&lint_report),
            Format::Json => rendered_json,
        };
        std::fs::write(path, body)
            .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    }

    match baseline_file {
        None => Ok(lint_report.clean()),
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("cannot read baseline {}: {e}", path.display()))?;
            let baseline = report::parse_baseline(&text)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            let fresh = report::new_findings(&lint_report, &baseline);
            let tolerated = lint_report.diagnostics.len() - fresh.len();
            if fresh.is_empty() {
                eprintln!(
                    "edgeflow-lint: baseline ok ({} pre-existing finding(s) tolerated)",
                    tolerated
                );
                Ok(true)
            } else {
                eprintln!(
                    "edgeflow-lint: {} NEW finding(s) beyond the baseline \
                     ({} tolerated):",
                    fresh.len(),
                    tolerated
                );
                for diag in fresh {
                    eprintln!("  NEW {diag}");
                }
                Ok(false)
            }
        }
    }
}

fn text_report(report: &Report) -> String {
    let mut out = String::new();
    for diag in &report.diagnostics {
        out.push_str(&diag.to_string());
        out.push('\n');
        out.push_str(&witness_lines(diag, "    "));
    }
    out.push_str(&format!(
        "edgeflow-lint: {} violation(s), {} suppressed by pragmas, {} file(s) scanned\n",
        report.diagnostics.len(),
        report.suppressed.len(),
        report.files_scanned
    ));
    let by_rule = report::suppressed_by_rule(report);
    if !by_rule.is_empty() {
        let parts: Vec<String> = by_rule
            .iter()
            .map(|(rule, n)| format!("{rule}={n}"))
            .collect();
        out.push_str(&format!(
            "edgeflow-lint: suppressions by rule: {}\n",
            parts.join(", ")
        ));
    }
    out
}

/// Render a diagnostic's witness chain, one hop per line: intermediate
/// hops show the call site into the next hop, the terminal hop (`=>`)
/// shows the effect site itself.
fn witness_lines(diag: &edgeflow_lint::Diagnostic, indent: &str) -> String {
    let mut out = String::new();
    for (k, hop) in diag.witness.iter().enumerate() {
        let arrow = if k + 1 == diag.witness.len() { "=>" } else { "->" };
        out.push_str(&format!(
            "{indent}{arrow} {} ({}:{})\n",
            hop.func, hop.file, hop.line
        ));
    }
    out
}

/// The `--explain <rule>` view: scope, rationale, then every current
/// finding of the rule walked hop by hop.
fn explain_report(report: &Report, rule: Rule) -> String {
    let mut out = String::new();
    out.push_str(&format!("rule: {}\n", rule.id()));
    out.push_str(&format!("scope: {}\n", scope::describe(rule)));
    out.push_str(&format!("rationale: {}\n", rationale(rule)));
    let hits: Vec<_> = report
        .diagnostics
        .iter()
        .filter(|d| d.rule == rule)
        .collect();
    let allowed: Vec<_> = report
        .suppressed
        .iter()
        .filter(|d| d.rule == rule)
        .collect();
    out.push_str(&format!(
        "\ncurrent findings: {} violation(s), {} suppressed by pragmas\n",
        hits.len(),
        allowed.len()
    ));
    for diag in hits {
        out.push_str(&format!("\n{diag}\n"));
        out.push_str(&witness_lines(diag, "    "));
    }
    for diag in allowed {
        out.push_str(&format!("\n[allowed by pragma] {diag}\n"));
        out.push_str(&witness_lines(diag, "    "));
    }
    out
}

/// One-paragraph rationale per rule, for `--explain`.
fn rationale(rule: Rule) -> &'static str {
    match rule {
        Rule::FloatOrdering => {
            "NaN-unsound comparisons make sort order depend on data; the \
             bit-identity contract needs total orders everywhere."
        }
        Rule::WallClockInSim => {
            "simulated-time modules that read the wall clock produce \
             run-to-run different traces; NetSim's clock is the only time \
             source there."
        }
        Rule::UnorderedIteration => {
            "HashMap/HashSet iteration order is unspecified, so any \
             serialization or aggregation driven by it breaks bit-identity."
        }
        Rule::UnwrapInLibrary => {
            "library layers must surface typed util::error Results; a panic \
             in the training loop takes the whole run down."
        }
        Rule::UnsafeAudit => {
            "every unsafe block needs a SAFETY: comment stating the \
             invariant that makes it sound."
        }
        Rule::CheckpointParity => {
            "checkpointed types must serialize every field they carry, or \
             resume silently diverges from the uninterrupted run."
        }
        Rule::CsvSchemaParity => {
            "the CSV header, the record struct and the row encoder must \
             agree column for column."
        }
        Rule::ConfigSurfaceParity => {
            "config fields must round-trip through JSON emit/parse and the \
             CLI override surface, or experiments silently drop settings."
        }
        Rule::TransitiveWallClock => {
            "a wall-clock read is no safer two calls deep: any fn a \
             determinism-critical surface can reach must not read \
             Instant/SystemTime outside obs::wallclock.  The witness chain \
             shows one shortest path from the surface fn to the read; fix \
             the seed site, or justify it (or the root) with \
             lint:allow(transitive-wall-clock)."
        }
        Rule::PanicReachability => {
            "public fl/ and runtime/ API fns promise typed errors; this \
             rule walks the call graph to find panic sites their callees \
             can still reach.  The witness chain is one shortest path from \
             the public fn to the panic."
        }
        Rule::PureLocalUpdate => {
            "a LocalUpdateHandle::run impl is the unit of migration replay: \
             it must be a pure function of (state, batch, lr), so no \
             wall-clock, RNG-construction or ambient-state effect may be \
             reachable from it at any depth."
        }
        Rule::StalePragma => {
            "a lint:allow whose finding disappeared is dead weight that \
             rots; delete it or justify keeping it."
        }
        Rule::Pragma => {
            "suppressions are part of the contract surface: every \
             lint:allow must name known rules and carry a reason."
        }
    }
}

fn print_report(report: &Report) {
    print!("{}", text_report(report));
}

/// Locate the repo root: the nearest ancestor (of this crate's
/// manifest dir under `cargo run`, else the cwd) containing
/// `rust/src`.
fn find_repo_root() -> Result<PathBuf, String> {
    let mut starts: Vec<PathBuf> = Vec::new();
    if let Ok(manifest) = env::var("CARGO_MANIFEST_DIR") {
        starts.push(PathBuf::from(manifest));
    }
    if let Ok(cwd) = env::current_dir() {
        starts.push(cwd);
    }
    for start in &starts {
        let mut dir: &Path = start;
        loop {
            if dir.join("rust").join("src").is_dir() {
                return Ok(dir.to_path_buf());
            }
            match dir.parent() {
                Some(parent) => dir = parent,
                None => break,
            }
        }
    }
    Err("could not locate the repo root (no ancestor with rust/src); pass --root".into())
}
